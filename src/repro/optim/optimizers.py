"""Optimizer substrate (no external deps): SGD / momentum / Adam(W) and the
paper's learning-rate schedules.

The FL server update (eq. 11) is plain SGD on the OTA-aggregated direction;
``repro.fed.runtime`` plugs any ``Optimizer`` in as the *server-side*
optimizer (``FLConfig.server_opt``), and the mesh training path supports
Adam for the beyond-paper runs.  ``update`` accepts an optional per-call
``lr`` override so a caller that already computed the round's eta_t (the FL
runtime threads the paper's Case-I/II schedules through its scan carry) can
drive any optimizer with it; the optimizer's own schedule applies otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree          # first moment / momentum (zeros-like or None-like)
    nu: PyTree          # second moment (Adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """``update(grads, state, params, lr=None) -> (new_params, new_state)``;
    ``lr`` overrides the constructor's schedule for that call."""

    init: Callable[[PyTree], OptState]
    update: Callable[..., Tuple[PyTree, OptState]]
    name: str = "sgd"


def _zeros_like(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd(lr: Callable[[jax.Array], jax.Array] | float,
        momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        mu = _zeros_like(params) if momentum else jnp.zeros(())
        return OptState(jnp.zeros((), jnp.int32), mu, jnp.zeros(()))

    def update(grads, state, params, lr=None):
        step = state.step + 1
        eta = lr_fn(step) if lr is None else jnp.asarray(lr)
        if momentum:
            mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state.mu, grads)
            upd = mu
        else:
            mu = state.mu
            upd = grads
        new = jax.tree_util.tree_map(
            lambda p, u: p - (eta * u).astype(p.dtype), params, upd)
        return new, OptState(step, mu, state.nu)

    return Optimizer(init, update, "sgd")


def adamw(lr: Callable[[jax.Array], jax.Array] | float, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like(params), _zeros_like(params))

    def update(grads, state, params, lr=None):
        step = state.step + 1
        t = step.astype(jnp.float32)
        eta = lr_fn(step) if lr is None else jnp.asarray(lr)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)), state.nu, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return p - (eta * u).astype(p.dtype)

        new = jax.tree_util.tree_map(upd, params, mu, nu)
        return new, OptState(step, mu, nu)

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# schedules


def inverse_power_schedule(p: float, eta0: float = 1.0):
    """The paper's Case-I schedule: eta_t = eta0 / t^p, 1/2 < p < 1."""
    if not (0.5 < p < 1.0):
        raise ValueError("p must lie in (1/2, 1)")

    def sched(step):
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        return eta0 / t ** p

    return sched


def constant_schedule(eta: float):
    """The paper's Case-II schedule: eta_t = eta."""
    return lambda step: jnp.asarray(eta, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        t = step.astype(jnp.float32)
        warm = peak * t / max(warmup, 1)
        prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(t < warmup, warm, cos)

    return sched
