"""Mesh train-step builder: the paper's OTA aggregation as the data-parallel
gradient collective of a production training step.

Two paths:

* ``scheme='mean'`` — standard pjit data parallelism (+ optional FSDP); this
  is the non-FL baseline and the only option when FSDP must span the same
  axis that would otherwise separate FL clients (llama3-405b on one pod —
  DESIGN.md §5).
* OTA schemes — ``jax.shard_map`` with the FL-client axes *manual* and the
  ``model`` axis auto (GSPMD tensor parallelism inside each client), the
  gradient collective being ``ota_psum``.  Any scheme registered in
  ``repro.core.schemes`` works here unchanged, and the per-client gradient
  statistics default to the blocked Pallas kernels
  (``ota_stats_impl='kernels'``) — the kernel backend's HBM-bound reduction
  inside the mesh backend's collective.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import ota_collectives as oc
from repro.distribution import sharding as sh
from repro.models import transformer as T
from repro.optim.optimizers import Optimizer, sgd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OTARunParams:
    """Concrete per-run OTA parameters (from repro.core.amplification)."""
    h: Any                       # [K] channel draws
    b: Any                       # [K] amplification factors
    a: float = 1.0
    noise_var: float = 0.0
    grad_bound: Optional[float] = None
    # §Perf lever: dtype for the superposition psum (None = fp32, faithful)
    reduce_dtype: Optional[str] = None


def build_train_step(cfg: ModelConfig, mesh, *, scheme: str = "normalized",
                     aggregation_axes: Optional[Sequence[str]] = None,
                     fsdp_axis: Optional[str] = None,
                     ota: Optional[OTARunParams] = None,
                     optimizer: Optional[Optimizer] = None,
                     ota_stats_impl: str = "kernels"):
    """Returns (train_step, in_shardings_fn).

    train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics)

    ``in_shardings_fn(params_like, opt_like, batch_like)`` produces the
    matching in_shardings pytree for jax.jit.
    """
    opt = optimizer or sgd(1e-2)

    def param_sharding_specs(params_like):
        return sh.param_specs(params_like, model_axis="model", fsdp_axis=fsdp_axis)

    if scheme == "mean" or not aggregation_axes:
        batch_axes = tuple(a for a in mesh.axis_names if a != "model")

        def train_step(params, opt_state, batch, rng):
            def loss_fn(p):
                loss, metrics = T.forward_loss(p, cfg, batch)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt_state = opt.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss)
            return params, opt_state, metrics

        def in_shardings_fn(params_like, opt_like, batch_like):
            ps = sh.named_shardings(mesh, param_sharding_specs(params_like), params_like)
            os_ = sh.named_shardings(mesh, param_sharding_specs(opt_like), opt_like) \
                if opt_like is not None else None
            bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, batch_axes), batch_like)
            return ps, os_, bs

        return train_step, in_shardings_fn

    # ----- OTA path -----
    axes = tuple(aggregation_axes)
    if ota is None:
        raise ValueError("OTA schemes need OTARunParams")
    h_arr = jnp.asarray(ota.h, jnp.float32)
    b_arr = jnp.asarray(ota.b, jnp.float32)

    def per_client(params, opt_state, batch, rng):
        def loss_fn(p):
            loss, metrics = T.forward_loss(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        y = oc.ota_psum(grads, scheme=scheme, axes=axes, h=h_arr, b=b_arr,
                        a=ota.a, noise_var=ota.noise_var, key=rng,
                        grad_bound=ota.grad_bound,
                        reduce_dtype=ota.reduce_dtype,
                        stats_impl=ota_stats_impl)
        params, opt_state = opt.update(y, opt_state, params)
        k_total = 1
        for ax in axes:
            k_total *= jax.lax.axis_size(ax)
        metrics = dict(metrics, loss=jax.lax.psum(loss, axes) / k_total,
                       grad_norm=jnp.sqrt(oc.tree_sq_norm(grads)))
        return params, opt_state, metrics

    def train_step(params, opt_state, batch, rng):
        batch_specs = sh.batch_specs(batch, axes)
        f = jax.shard_map(per_client, mesh=mesh,
                          in_specs=(P(), P(), batch_specs, P()),
                          out_specs=(P(), P(), P()),
                          axis_names=set(axes), check_vma=False)
        return f(params, opt_state, batch, rng)

    # Outer (pjit-level) batch sharding: the FL-client axes plus, when FSDP is
    # on, the fsdp axis (batch is then data-parallel *within* each client too).
    outer_batch_axes = axes + ((fsdp_axis,) if fsdp_axis and fsdp_axis not in axes
                               else ())

    def in_shardings_fn(params_like, opt_like, batch_like):
        ps = sh.named_shardings(mesh, param_sharding_specs(params_like), params_like)
        os_ = sh.named_shardings(mesh, param_sharding_specs(opt_like), opt_like) \
            if opt_like is not None else None
        bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, outer_batch_axes), batch_like)
        return ps, os_, bs

    return train_step, in_shardings_fn


def instrument_train_step(step_fn, recorder, *, manifest=None):
    """Wrap a built ``train_step`` with host-side flight recording.

    Returns a drop-in replacement with the same signature.  Each call is
    timed, annotated for the profiler (``repro.obs.profiling.annotate_chunk``)
    and emitted to ``recorder`` as one chunk + one round event carrying the
    step's metrics as host floats.  Recording happens strictly AFTER the
    step returns, on transferred copies — params/opt_state pass through
    untouched, so the trajectory is bitwise-identical with or without the
    wrapper.  The metric transfer does synchronize the host with the device
    each step (that is what makes the numbers live); leave the wrapper off
    for pure-throughput runs.
    """
    import time

    import numpy as np

    from repro.obs import profiling as obsprof

    if manifest is not None:
        recorder.on_manifest(manifest)
    counter = [0]

    def instrumented(params, opt_state, batch, rng):
        i = counter[0]
        counter[0] += 1
        t0 = time.perf_counter()
        with obsprof.annotate_chunk(i):
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 rng)
            host = {k: np.asarray(jax.device_get(v),
                                  np.float64).reshape(-1)[:1]
                    for k, v in metrics.items()}
        recorder.on_chunk(i, [i], host,
                          wall_time_s=time.perf_counter() - t0,
                          dispatches=1, rss_mb=obsprof.rss_mb())
        return params, opt_state, metrics

    return instrumented


def make_batch_from_specs(specs, cfg: ModelConfig):
    """Turn concrete model inputs (``configs.registry.input_specs`` layout)
    into a loss-ready batch dict.

    When ``labels`` are absent they default to the shifted-token convention
    ``forward_loss`` expects for LM-style next-token training: position i
    predicts token i+1, and the final position (which has no target) is
    excluded via ``loss_mask``.  A caller-provided ``loss_mask`` is combined
    with the shift mask rather than overwritten.
    """
    batch = dict(specs)
    if "labels" not in batch and "tokens" in batch:
        tokens = jnp.asarray(batch["tokens"])
        batch["labels"] = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        shift_mask = jnp.concatenate(
            [jnp.ones(tokens[:, 1:].shape, jnp.float32),
             jnp.zeros(tokens[:, :1].shape, jnp.float32)], axis=1)
        prior = batch.get("loss_mask")
        batch["loss_mask"] = (shift_mask if prior is None
                              else shift_mask * jnp.asarray(prior, jnp.float32))
    return batch
