import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices exist; tests and benchmarks see the real single CPU device.
"""Multi-pod dry-run launcher (deliverable (e)).

For every (architecture x input shape x mesh) combination this lowers and
compiles the real train/prefill/decode step on the production mesh —
16x16 = 256 chips single-pod and 2x16x16 = 512 chips multi-pod — with
ShapeDtypeStruct stand-ins (zero allocation), then records
``memory_analysis()`` / ``cost_analysis()`` / per-collective bytes for the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline as rl
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import registry as reg
from repro.core import amplification as amp
from repro.core import channel as chan
from repro.launch import mesh as mesh_lib
from repro.launch import serve as serve_lib
from repro.launch import train as train_lib
from repro.models import transformer as T
from repro.optim.optimizers import sgd
from repro.distribution import sharding as sh
from jax.sharding import NamedSharding, PartitionSpec as P


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def plan_for(cfg: ModelConfig, shape: InputShape, mesh, scheme: str):
    """Decide aggregation axes / fsdp / context-parallel per DESIGN.md §5."""
    multi_pod = "pod" in mesh.axis_names
    plan = dict(scheme=scheme, aggregation_axes=None, fsdp_axis=None,
                context_parallel=False)
    if shape.kind == "train":
        if cfg.name.startswith("llama3-405b"):
            # params+grads per FL client exceed a 16-chip client: OTA clients
            # would have to be pods with FSDP *inside* each client, but FSDP
            # param sharding under a partial-manual shard_map trips an XLA
            # SPMD-partitioner check failure (DESIGN.md §8; Shardy too).
            # Default: mean + FSDP (proves the mesh shards & fits).  The
            # OTA-over-pod schedule is recorded separately via
            # scheme='normalized' (no FSDP; memory overflow flagged).
            if scheme == "mean" or not multi_pod:
                plan.update(scheme="mean",
                            fsdp_axis=("pod", "data") if multi_pod else "data")
            else:
                plan.update(aggregation_axes=("pod",), fsdp_axis=None)
        else:
            plan.update(aggregation_axes=("pod", "data") if multi_pod else ("data",))
    elif shape.kind == "decode" and shape.name == "long_500k":
        # context-parallel KV cache only for hybrids (jamba); SWA and pure-
        # recurrent archs have O(window)/O(1) state.
        if cfg.is_hybrid:
            plan.update(context_parallel=True)
    return plan


def ota_params_for(cfg: ModelConfig, mesh, axes) -> train_lib.OTARunParams:
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    ch = chan.ChannelConfig(num_devices=k, channel_mean=1e-3)
    h = np.asarray(chan.draw_channel(jax.random.PRNGKey(0), ch))
    sol = amp.solve_problem3(h, ch.noise_var, min(cfg.param_count(), 10 ** 9),
                             ch.b_max, tol=1e-8)
    a_gain = 1.0 / float(np.sum(h * sol.b))
    return train_lib.OTARunParams(h=h, b=sol.b, a=a_gain,
                                  noise_var=ch.noise_var)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              scheme: str = "normalized",
              overrides: Optional[dict] = None,
              perf: Optional[dict] = None) -> dict:
    """Lower + compile one (arch, shape, mesh) combination; returns a record.

    ``overrides`` patches ModelConfig fields; ``perf`` carries the builder-
    level §Perf levers: {"shard_cache_seq": bool, "reduce_dtype": str}.
    """
    perf = perf or {}
    cfg = reg.get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    skip = reg.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "scheme": scheme,
           "status": "skip", "skip_reason": skip}
    if skip:
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    plan = plan_for(cfg, shape, mesh, scheme)
    rec["plan"] = {k: (list(v) if isinstance(v, tuple) else v) for k, v in plan.items()}
    params_like = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = reg.input_specs(cfg, shape)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt = sgd(1e-2)
            opt_like = jax.eval_shape(lambda: opt.init(
                jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                       params_like)))
            ota = (ota_params_for(cfg, mesh, plan["aggregation_axes"])
                   if plan["aggregation_axes"] else None)
            if ota is not None and perf.get("reduce_dtype"):
                import dataclasses as _dc
                ota = _dc.replace(ota, reduce_dtype=perf["reduce_dtype"])
            step, in_sh_fn = train_lib.build_train_step(
                cfg, mesh, scheme=plan["scheme"],
                aggregation_axes=plan["aggregation_axes"],
                fsdp_axis=plan["fsdp_axis"], ota=ota, optimizer=opt)
            batch_like = dict(specs)
            rng_like = jax.ShapeDtypeStruct((2,), jnp.uint32)
            ps, os_, bs = in_sh_fn(params_like, opt_like, batch_like)
            jitted = jax.jit(step,
                             in_shardings=(ps, os_, bs, NamedSharding(mesh, P())),
                             out_shardings=(ps, os_, None))
            lowered = jitted.lower(params_like, opt_like, batch_like, rng_like)
        elif shape.kind == "prefill":
            step, in_sh_fn = serve_lib.build_prefill_step(cfg, mesh)
            ps, bs = in_sh_fn(params_like, specs)
            jitted = jax.jit(step, in_shardings=(ps, bs))
            lowered = jitted.lower(params_like, specs)
        else:  # decode
            b = shape.global_batch
            dp = mesh_lib.dp_axes(mesh)
            n_dp = int(np.prod([mesh.shape[a] for a in dp]))
            batch_axes = dp if b % n_dp == 0 and b >= n_dp else ()
            cache_like = jax.eval_shape(
                lambda: T.init_cache(cfg, b, shape.seq_len,
                                     cp_shards=mesh.shape["data"]
                                     if plan["context_parallel"] else 1))
            step, in_sh_fn = serve_lib.build_decode_step(
                cfg, mesh, context_parallel=plan["context_parallel"],
                cache_len=shape.seq_len,
                shard_cache_seq=perf.get("shard_cache_seq", False))
            tokens_like = {"tokens": specs["tokens"], "pos": specs["pos"]}
            if cfg.is_encoder_decoder:
                enc_like = jax.ShapeDtypeStruct(
                    (b, specs["src_embeds"].shape[1], cfg.d_model),
                    jnp.dtype(cfg.dtype))
                sh_tuple = in_sh_fn(params_like, cache_like, tokens_like,
                                    {"enc": enc_like})
                ps, cs, bs = sh_tuple[:3]
                es = NamedSharding(mesh, P(tuple(batch_axes) if batch_axes else None))

                def step_ed(params, cache, tokens, pos, enc_out):
                    return step(params, cache, tokens, pos, enc_out=enc_out)

                ts = NamedSharding(mesh, P(tuple(batch_axes) if batch_axes else None))
                jitted = jax.jit(step_ed, in_shardings=(
                    ps, cs, bs["tokens"], bs["pos"], es),
                    out_shardings=(ts, cs))
                lowered = jitted.lower(params_like, cache_like,
                                       tokens_like["tokens"], tokens_like["pos"],
                                       enc_like)
            else:
                ps, cs, bs = in_sh_fn(params_like, cache_like, tokens_like)
                ts = NamedSharding(mesh, P(tuple(batch_axes) if batch_axes else None))
                jitted = jax.jit(step, in_shardings=(ps, cs, bs["tokens"], bs["pos"]),
                                 out_shardings=(ts, cs))
                lowered = jitted.lower(params_like, cache_like,
                                       tokens_like["tokens"], tokens_like["pos"])

        compiled = lowered.compile()

    n_active = cfg.active_param_count()
    report = rl.analyze(f"{arch}/{shape_name}", compiled, chips,
                        rl.model_flops_for(cfg, shape, n_active))
    rec.update(status="ok",
               lower_compile_s=round(time.time() - t0, 1),
               roofline=report.to_dict(),
               params=cfg.param_count(), active_params=n_active)
    print(compiled.memory_analysis())
    return rec


def _depth_overrides(cfg: ModelConfig, mult: int, shape: InputShape) -> dict:
    """Shrink a config to ``mult`` superblocks (encoder scaled alongside) and
    bound every chunk-loop's trip count so the unrolled HLO stays small
    (total op counts are chunking-invariant; only loop structure changes)."""
    s = shape.seq_len
    ov = {"num_layers": len(cfg.block_pattern) * mult, "unroll": True,
          "attn_q_chunk": max(s // 4, 512),
          "loss_seq_chunk": max(s // 4, 512),
          "mlstm_chunk": max(s // 4, 512),
          "mamba_chunk": max(s // 4, 512)}
    if cfg.is_encoder_decoder:
        ov["num_encoder_layers"] = mult
    return ov


def _slstm_missing_flops(cfg: ModelConfig, shape: InputShape, chips: int) -> float:
    """Analytic correction for the one loop we cannot unroll: the sLSTM
    time recurrence (S sequential steps; cost_analysis counts the body once).
    Per step per layer: block-diag recurrent matmuls 8*B*di*dh + O(B*di)
    elementwise.  Train counts fwd+recompute+bwd ~ 4x fwd (remat)."""
    if not cfg.is_xlstm or shape.kind == "decode":
        return 0.0
    from repro.models.xlstm import xlstm_inner_dim
    di = xlstm_inner_dim(cfg)
    dh = di // cfg.num_heads
    n_slstm = cfg.num_layers // cfg.slstm_every
    b, s = shape.global_batch, shape.seq_len
    per_step = 8.0 * b * di * dh + 24.0 * b * di
    factor = 4.0 if shape.kind == "train" else 1.0
    return factor * n_slstm * (s - 1) * per_step / chips


def analyze_one(arch: str, shape_name: str, *, multi_pod: bool = False,
                scheme: str = "normalized",
                overrides: Optional[dict] = None,
                perf: Optional[dict] = None,
                depths=(1, 2)) -> dict:
    """Roofline-grade analysis: lower UNROLLED at 1 and 2 superblocks, fit
    the per-superblock slope, extrapolate to full depth (EXPERIMENTS.md
    §Methodology — XLA cost_analysis counts while-loop bodies once, so the
    scanned production lowering cannot be used for op counts)."""
    cfg = reg.get_config(arch)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    skip = reg.applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "mode": "analysis", "status": "skip", "skip_reason": skip}
    chips = 512 if multi_pod else 256
    meas = []
    for mult in depths:
        depth_ov = _depth_overrides(cfg, mult, shape)
        # caller-specified levers win over the analysis chunk defaults
        for k_ov in (overrides or {}):
            depth_ov.pop(k_ov, None)
        ov = dict(overrides or {})
        ov.update(depth_ov)
        rec = lower_one(arch, shape_name, multi_pod=multi_pod, scheme=scheme,
                        overrides=ov, perf=perf)
        if rec["status"] != "ok":
            rec["mode"] = "analysis"
            return rec
        meas.append(rec["roofline"])

    n_full = cfg.num_superblocks
    d1, d2 = depths
    def extrap(key):
        v1, v2 = meas[0][key], meas[1][key]
        slope = (v2 - v1) / (d2 - d1)
        return max(v1 + slope * (n_full - d1), 0.0)

    flops = extrap("flops_per_chip") + _slstm_missing_flops(cfg, shape, chips)
    byts = extrap("bytes_per_chip")
    coll = extrap("coll_bytes_per_chip")
    breakdown = {k: meas[0]["coll_breakdown"][k]
                 + (meas[1]["coll_breakdown"][k] - meas[0]["coll_breakdown"][k])
                 * (n_full - 1) for k in meas[0]["coll_breakdown"]}
    rep = rl.RooflineReport(
        name=f"{arch}/{shape_name}", chips=chips, flops_per_chip=flops,
        bytes_per_chip=byts, coll_bytes_per_chip=int(coll),
        coll_breakdown=breakdown,
        model_flops=rl.model_flops_for(cfg, shape, cfg.active_param_count()),
        memory_analysis="<see fits-run record>").finalize()
    return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "mode": "analysis", "scheme": scheme, "status": "ok",
            "depth_points": [meas[0]["flops_per_chip"], meas[1]["flops_per_chip"]],
            "n_superblocks": n_full, "roofline": rep.to_dict(),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}


def run_isolated(pairs, args) -> list:
    """Run each pair in its own subprocess (XLA partitioner check-failures
    abort the process; isolation keeps the sweep alive) and merge records."""
    import subprocess, sys, tempfile
    records = []
    for arch, shape in pairs:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
            tmp = tf.name
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--scheme", args.scheme, "--out", tmp]
        if args.multi_pod:
            cmd.append("--multi-pod")
        if args.analysis:
            cmd.append("--analysis")
        try:
            r = subprocess.run(cmd, capture_output=True, text=True, timeout=3000)
            recs = []
            if os.path.exists(tmp) and os.path.getsize(tmp):
                with open(tmp) as f:
                    recs = json.load(f)
            if recs:
                records.extend(recs)
            else:
                records.append({
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                    "status": "error",
                    "error": f"subprocess exit {r.returncode}",
                    "stderr_tail": r.stderr[-1200:]})
        except subprocess.TimeoutExpired:
            records.append({"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if args.multi_pod else "16x16",
                            "status": "error", "error": "timeout"})
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        last = records[-1]
        print(f"[{last['status']:5s}] {arch} x {shape} (isolated)", flush=True)
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--isolate", action="store_true",
                    help="run every pair in its own subprocess")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled depth-extrapolated roofline analysis")
    ap.add_argument("--scheme", default="normalized")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in reg.ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        pairs.append((args.arch, args.shape))

    if args.isolate:
        records = run_isolated(pairs, args)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(records, f, indent=2)
            print(f"wrote {args.out}")
        return

    records = []
    for arch, shape in pairs:
        try:
            if args.analysis:
                rec = analyze_one(arch, shape, multi_pod=args.multi_pod,
                                  scheme=args.scheme)
            else:
                rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                                scheme=args.scheme)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-2000:]}
        records.append(rec)
        ok = rec["status"]
        extra = ""
        if ok == "ok":
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms"
                     f" coll={r['collective_s']*1e3:.2f}ms bottleneck={r['bottleneck']}")
        elif ok == "skip":
            extra = f" ({rec['skip_reason']})"
        elif ok == "error":
            extra = f" {rec['error'][:120]}"
        print(f"[{ok:5s}] {arch} x {shape} on {rec['mesh']}{extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
