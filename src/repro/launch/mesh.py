"""Production mesh definition (deliverable (e)).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import and only then calls this.

Axes:
  single-pod:  (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod:   (pod=2, data=16, model=16)     — 512 chips (two pods)

FL-device mapping (DESIGN.md §2/§5): the OTA "mobile devices" are the shards
of the aggregation axes — ('data',) on one pod (16 clients), ('pod',) or
('pod','data') across pods.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (forced) host devices exist — tests."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry the batch (and the FL devices): ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_fl_devices(mesh, aggregation_axes: Optional[Tuple[str, ...]] = None) -> int:
    axes = aggregation_axes or dp_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
