"""Serving step builders: prefill and single-token decode (+ context-parallel
long-context decode for the sub-quadratic architectures).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import sharding as sh
from repro.models import transformer as T

PyTree = Any


def _reshard_kv_seq(cache_specs_tree, batch_axes, seq_axis: str):
    """Rewrite kv-cache specs [n_sb,B,S,Hkv,dh] to shard S over seq_axis."""
    def one(spec):
        if isinstance(spec, P) and len(spec) == 5:
            return P(None, tuple(batch_axes) or None, seq_axis, None, None)
        return spec
    return jax.tree_util.tree_map(one, cache_specs_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def build_prefill_step(cfg: ModelConfig, mesh):
    """prefill_step(params, batch) -> next-token ids [B].

    Runs the full forward over the prompt and greedily samples the first new
    token (KV-cache writing is accounted separately — EXPERIMENTS.md §Dry-run).
    """
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")

    def prefill_step(params, batch):
        x = T.forward_hidden(params, cfg, batch)
        from repro.models import layers as L
        last = x[:, -1, :]
        logits = (last @ L.unembed_matrix(params["emb"], cfg)).astype(jnp.float32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def in_shardings_fn(params_like, batch_like):
        ps = sh.named_shardings(mesh, sh.param_specs(params_like, model_axis="model"), params_like)
        bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, batch_axes), batch_like)
        return ps, bs

    return prefill_step, in_shardings_fn


def build_prefill_cache_step(cfg: ModelConfig, mesh, cache_len: int):
    """prefill_cache_step(params, batch) -> (first new token ids [B], cache).

    The production prefill: runs the prompt forward AND writes the decode
    cache (exact handoff — tests/test_models.py::test_prefill_cache_handoff).
    """
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")

    def prefill_cache_step(params, batch):
        from repro.models import layers as L
        x, cache = T.prefill_with_cache(params, cfg, batch, cache_len)
        last = x[:, -1, :]
        logits = (last @ L.unembed_matrix(params["emb"], cfg)).astype(jnp.float32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def in_shardings_fn(params_like, batch_like):
        ps = sh.named_shardings(mesh, sh.param_specs(params_like, model_axis="model"), params_like)
        bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, batch_axes), batch_like)
        return ps, bs

    return prefill_cache_step, in_shardings_fn


def build_decode_step(cfg: ModelConfig, mesh, *, context_parallel: bool = False,
                      cache_len: int = 0, shard_cache_seq: bool = False):
    """decode_step(params, cache, tokens, pos[, enc_out]) ->
    (next_tokens [B], new_cache).

    ``context_parallel=True`` shards the KV-cache *sequence* axis over the
    'data' mesh axis with a flash-decoding (shifted-softmax psum) merge — the
    long_500k path for hybrid models whose KV cache cannot fit otherwise.

    ``shard_cache_seq=True`` (beyond-paper §Perf lever): when the kv heads
    cannot shard over the model axis, shard the cache *sequence* dim over it
    instead (GSPMD-auto; requires cfg.decode_cache_update='select' so the
    slot write stays gather-free).
    """
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    model_size = mesh.shape["model"]

    if not context_parallel:
        seq_axis = ("model" if shard_cache_seq
                    and cfg.num_kv_heads % model_size != 0 else None)
        if seq_axis:
            import dataclasses as _dc
            cfg = _dc.replace(cfg, decode_cache_seq_axis=seq_axis)

        def decode_step(params, cache, tokens, pos, enc_out=None):
            logits, new_cache = T.decode_step(params, cfg, cache, tokens, pos,
                                              enc_out=enc_out)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        def in_shardings_fn(params_like, cache_like, batch_like,
                            enc_like=None):
            ps = sh.named_shardings(mesh, sh.param_specs(params_like, model_axis="model"), params_like)
            cache_sp = sh.cache_specs(
                cache_like, batch_axes=batch_axes, model_axis="model",
                num_kv_heads=cfg.num_kv_heads, model_size=model_size)
            if seq_axis:
                cache_sp = _reshard_kv_seq(cache_sp, batch_axes, seq_axis)
            cs = sh.named_shardings(mesh, cache_sp, cache_like)
            bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, batch_axes), batch_like)
            out = [ps, cs, bs]
            if enc_like is not None:
                out.append(sh.named_shardings(mesh, sh.batch_specs(enc_like, batch_axes), enc_like))
            return tuple(out)

        return decode_step, in_shardings_fn

    # ----- context-parallel long decode -----
    data_size = mesh.shape["data"]
    local_len = cache_len // data_size

    def per_shard(params, cache, tokens, pos):
        offset = jax.lax.axis_index("data") * local_len
        logits, new_cache = T.decode_step(params, cfg, cache, tokens, pos,
                                          axis_name="data", shard_offset=offset)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    def cache_manual_specs(cache_like):
        # kv caches: seq axis (2 after the stack axis) manually sharded over data
        def one_path(path, leaf):
            name = ""
            for entry in reversed(path):
                if isinstance(entry, jax.tree_util.DictKey):
                    name = str(entry.key)
                    break
            if name in ("k", "v") and jnp.ndim(leaf) == 5:
                return P(None, None, "data", None, None)
            return P()
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
        return jax.tree_util.tree_unflatten(
            treedef, [one_path(p, l) for p, l in flat])

    def decode_step(params, cache, tokens, pos, enc_out=None):
        specs = cache_manual_specs(cache)
        f = jax.shard_map(per_shard, mesh=mesh,
                          in_specs=(P(), specs, P(), P()),
                          out_specs=(P(), specs),
                          axis_names={"data"}, check_vma=False)
        return f(params, cache, tokens, pos)

    def in_shardings_fn(params_like, cache_like, batch_like, enc_like=None):
        ps = sh.named_shardings(mesh, sh.param_specs(params_like, model_axis="model"), params_like)
        cs = sh.named_shardings(mesh, sh.cache_specs(
            cache_like, batch_axes=(), model_axis="model",
            num_kv_heads=cfg.num_kv_heads, model_size=model_size,
            seq_axis="data"), cache_like)
        bs = sh.named_shardings(mesh, sh.batch_specs(batch_like, ()), batch_like)
        return ps, cs, bs

    return decode_step, in_shardings_fn


# ---------------------------------------------------------------------------
# Live metrics (PR 10): a minimal pull endpoint over the in-memory recorder


def serve_metrics(recorder, host: str = "127.0.0.1", port: int = 0):
    """Serve a ``MemoryRecorder``'s latest snapshot as JSON over HTTP.

    ``GET /metrics`` (also ``/`` and ``/metrics/latest``) returns
    ``recorder.latest()`` — event count plus the most recent manifest /
    round / eval / chunk events — so a long OTA-FL run driven with
    ``Experiment.run(recorder=...)`` can be watched from a second terminal:

        rec = obs.make("memory")
        server = serve_metrics(rec)          # port=0 -> OS-assigned
        host, port = server.server_address
        # ... e.run(n, recorder=rec) in the main thread ...
        # curl http://host:port/metrics

    The server runs ``serve_forever`` on a daemon thread and is returned to
    the caller (read ``server.server_address`` for the bound port, call
    ``server.shutdown()`` to stop).  Reads are snapshot-cheap: the handler
    only serializes the recorder's latest-event dict, never the full log,
    so polling cannot grow with run length.
    """
    import json
    import threading
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/metrics",
                                             "/metrics/latest"):
                self.send_response(404)
                self.end_headers()
                return
            snap = recorder.latest() if hasattr(recorder, "latest") else {}
            body = json.dumps(snap, default=str).encode()
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):    # keep the run's stdout clean
            pass

    server = HTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="repro-obs-metrics")
    thread.start()
    return server
