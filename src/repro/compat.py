"""Forward-compatibility shims: run the new-style jax mesh API on older jax.

The codebase is written against the current jax surface — ``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=...)``.
Older jaxlibs (such as the 0.4.x pinned in this container) expose the same
machinery under ``jax.experimental.shard_map`` with slightly different
spellings (``check_rep``/``auto`` instead of ``check_vma``/``axis_names``,
``Mesh`` as its own context manager instead of ``set_mesh``).  This module
installs thin adapters onto the ``jax`` namespace when — and only when — the
modern names are missing, so every other module can use one API.

Imported for its side effects from ``repro.__init__``; it never touches
device state (safe to import before XLA_FLAGS-dependent initialization).
"""
from __future__ import annotations

import enum
import inspect

import jax


if not hasattr(jax.sharding, "AxisType"):
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        # old jax has no axis-type concept at mesh level; Auto is its default
        return _make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        # Old jax lowers axis_index under a *partial*-auto shard_map to a
        # PartitionId instruction the SPMD partitioner rejects; run fully
        # manual instead.  Axes the caller left auto are then replicated
        # (numerically identical, no tensor parallelism on old jax), and the
        # with_sharding_constraint shim below drops the now-unsatisfiable
        # auto-axis placement hints.
        del axis_names
        return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=bool(check_vma), auto=frozenset())

    jax.shard_map = shard_map

    _wsc = jax.lax.with_sharding_constraint

    def _spec_axis_names(s):
        entries = getattr(s, "spec", s)
        names = set()
        for e in entries or ():
            if e is None:
                continue
            names.update(e if isinstance(e, (tuple, list)) else (e,))
        return names

    def with_sharding_constraint(x, shardings):
        # Constraints naming an axis that is manual in the current trace (all
        # mesh axes, under the fully-manual shard_map above) fail at lowering
        # on old jax; they are placement hints, not semantics — drop them.
        from jax._src import core as _core
        from jax.sharding import PartitionSpec as _P
        bound = set(_core.get_axis_env().axis_sizes)
        if bound:
            is_leaf = lambda s: isinstance(s, (_P, jax.sharding.Sharding))
            referenced = set()
            for s in jax.tree_util.tree_leaves(shardings, is_leaf=is_leaf):
                referenced |= _spec_axis_names(s)
            if referenced & bound:
                return x
        return _wsc(x, shardings)

    jax.lax.with_sharding_constraint = with_sharding_constraint


if not hasattr(jax.lax, "axis_size"):
    def axis_size(axis_name):
        # psum of the literal 1 is constant-folded to the axis size at trace
        # time, which is exactly the old-jax idiom for this query
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


if not hasattr(jax, "set_mesh"):
    def set_mesh(mesh):
        # old Mesh objects are themselves context managers (resource env)
        return mesh

    jax.set_mesh = set_mesh
