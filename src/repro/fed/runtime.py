"""Federated-learning runtime (paper Sec. II, Steps 1-3, iterated).

The K devices are a ``jax.vmap`` axis; one round (local computation -> OTA
superposition -> server update -> broadcast) is a single jittable program.
``FLConfig.backend`` selects which execution backend the aggregation routes
through — ``vmap`` (pure XLA), ``kernels`` (fused Pallas path; the default
for benchmarks), or ``mesh`` (shard_map/psum over local devices; needs >= K
of them).  The production mesh train-step builder (devices = data shards of
a TPU mesh) lives in ``repro.launch.train``.

Beyond the paper's eq. 10-11 round, the round math carries three scenario
axes, all spec fields (no new positional arguments — the declarative
``repro.fl.ExperimentSpec`` facade is the intended front door):

``server_opt``      the server applies a pluggable ``optim.Optimizer`` to
                    the OTA-aggregated direction, its state threaded through
                    the scan carry (donated buffers).  ``'sgd'`` (default,
                    momentum 0) IS eq. 11, ``w <- w - eta_t y``, exactly.
``local_steps``     H > 1: each client takes H local SGD steps (FedAvg-style,
                    arXiv:2310.10089) and transmits the accumulated model
                    delta ``(w - w_k^H) / (H * local_lr)`` — an average local
                    gradient — through the unchanged scheme registry (the
                    ``normalized`` scheme then aggregates the *normalized*
                    accumulated delta).
``participation``   per-round Bernoulli or fixed-fraction device masks
                    (arXiv:2409.07822-style partial client participation),
                    folded into the superposition weights AND the eq.-8
                    energy accounting via ``ota.participation_fold`` — a
                    masked device transmits nothing and spends nothing.

Two round-loop drivers (``run(..., driver=...)``):

``scan``   (default) the compiled multi-round engine: ``jax.lax.scan`` over
           rounds, dispatched in chunks whose param buffers are donated and
           whose per-round history lands in on-device arrays transferred
           once per chunk.  Under block fading the channel redraw
           (``core.channel.channel_for_round``) AND the Problem-3
           re-optimization (``core.amplification.solve_problem3_jax``, a
           ``lax.while_loop`` bisection) run *inside* the scan — the whole
           trajectory is one XLA program per chunk, no host callbacks.
``python`` the host-loop fallback: one jitted round per dispatch, history
           appended eagerly.  Use it when an ``eval_fn`` must observe every
           round or for step-debugging; it computes the identical numbers
           (tests/test_engine.py holds the two drivers to fp32 parity on
           every backend, fixed and block-fading).

Beyond single experiments, ``run_batched`` vectorizes the scan engine over a
leading *experiment* axis: E structurally-identical configs (same scheme /
case / backend / scenario axes — see ``structural_config``) that differ only
in *batchable numerics* (seed, eta, s_target, grad_bound, noise_var,
channel_mean, b_max, ...) compile into ONE program via ``jax.vmap`` through
``_round_math`` — including channel redraws and the Problem-3 bisection
under block fading — and the experiment axis is sharded across local
devices (``distribution.sharding.experiment_mesh``) when a mesh is
available.  ``repro.fl.sweep`` is the declarative front door that expands a
grid, groups points by structural signature, and dispatches here.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amplification as amp
from repro.core import channel as chan
from repro.core import ota
from repro.core import schemes
from repro.optim import optimizers as optim

PyTree = Any
GradFn = Callable[[PyTree, Any], PyTree]   # (params, device_batch) -> grads

DRIVERS = ("scan", "python")
SERVER_OPTS = ("sgd", "adamw")
PARTICIPATION_MODES = ("bernoulli", "fixed")
# per-round scalar diagnostics recorded by BOTH drivers (same device-side
# math, so the drivers' histories agree exactly)
DIAG_KEYS = ("grad_norm_mean", "grad_norm_min", "grad_norm_max", "eta",
             "update_norm", "tx_energy", "num_participants")
# key-derivation salt separating the participation draw from the channel
# noise (both are folded from the same per-run key at the same round t)
_MASK_SALT = 0x5EED

# Compiled-executable cache size for the round/chunk builders below.  Large
# sweeps walk many (config, grad_fn) pairs; a too-small LRU silently evicts
# and re-traces mid-sweep, so the size is configurable without a code change
# (REPRO_ENGINE_CACHE_SIZE) and ``cache_info()`` exposes hit/miss/trace
# counters so benchmarks can assert zero re-traces.
ENGINE_CACHE_SIZE = int(os.environ.get("REPRO_ENGINE_CACHE_SIZE", "64"))

# incremented inside the traced bodies (tracing executes them; cached
# executions do not), so re-traces are observable even when they happen
# inside jax's own jit cache rather than the lru builders
TRACE_COUNTS: collections.Counter = collections.Counter()


def _engine_cache(fn):
    return functools.lru_cache(maxsize=ENGINE_CACHE_SIZE)(fn)


def cache_info() -> Dict[str, Any]:
    """Introspection for the compiled-executable caches: per-builder
    ``lru_cache`` statistics plus cumulative trace counts (``TRACE_COUNTS``).
    The sweep benchmark asserts the trace counters stay flat across repeated
    grid runs — i.e. zero re-traces once warm."""
    return {
        "cache_size": ENGINE_CACHE_SIZE,
        "builders": {name: fn.cache_info()._asdict()
                     for name, fn in _CACHED_BUILDERS.items()},
        "traces": dict(TRACE_COUNTS),
    }


def clear_compile_caches() -> None:
    """Drop every cached builder (and its jitted executables) and reset the
    trace counters — test isolation / memory-pressure escape hatch."""
    for fn in _CACHED_BUILDERS.values():
        fn.cache_clear()
    TRACE_COUNTS.clear()


# FLConfig fields a batched (vmapped) run can vary per experiment: they are
# either consumed only by host-side ``setup`` (folded into the stacked
# h/b/a/eta0 inputs) or threaded through the compiled program as traced
# per-experiment scalars (``BatchAxes``).  Everything else — scheme, case,
# backend, schedule exponent, scenario axes — changes the traced program
# and is therefore *structural*: vary it across compiles, not lanes.
BATCHED_FL_FIELDS = ("seed", "eta", "s_target", "epsilon_target",
                     "grad_bound", "smoothness_L", "strong_convexity_M",
                     "expected_loss_drop", "theta_th")
BATCHED_CHANNEL_FIELDS = ("noise_var", "channel_mean", "b_max")


class BatchAxes(NamedTuple):
    """Per-experiment traced scalars of a batched run (each field is [E] at
    the ``run_batched`` boundary and a scalar inside the vmapped body).
    ``None`` fields fall back to the baked ``FLConfig`` value — the
    single-experiment drivers pass ``over=None`` everywhere, so their traces
    (and compiled executables) are untouched by the batching refactor."""

    noise_var: Optional[jax.Array] = None       # sigma^2 at the ES
    grad_bound: Optional[jax.Array] = None      # G (schemes that need it)
    b_max: Optional[jax.Array] = None           # per-device cap, block fading
    rayleigh_scale: Optional[jax.Array] = None  # channel redraw, block fading


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    backend: str = "vmap"             # 'vmap' | 'kernels' | 'mesh' (see core.ota)
    case: str = "I"                   # 'I' (eta_t = 1/t^p) or 'II' (constant eta)
    p: float = 0.75                   # Case-I schedule exponent (paper: 0.75)
    eta: float = 0.01                 # Case-II constant learning rate (paper: 0.01)
    theta_th: float = chan.DEFAULT_THETA_TH
    channel: chan.ChannelConfig = None
    seed: int = 0
    # amplification policy: 'optimal' (Algorithm 1 / Problem 3) or 'bmax'
    # (the no-optimization comparison of Fig. 1(a)/2(a): every b_k = b_k^max)
    amplification: str = "optimal"
    grad_bound: Optional[float] = None   # G, needed by benchmark1 + Case II
    # Case-II target: pick exactly one (s wins if both set)
    s_target: Optional[float] = None
    epsilon_target: Optional[float] = None
    # Case-I optimal-S inputs
    smoothness_L: float = 1.0
    strong_convexity_M: float = 1.0
    expected_loss_drop: float = 1.0
    # --- scenario axes (defaults reproduce the paper's round exactly) ------
    # server-side optimizer applied to the OTA-aggregated direction:
    # 'sgd' (momentum 0 == eq. 11) or 'adamw'
    server_opt: str = "sgd"
    server_momentum: float = 0.0
    server_b1: float = 0.9
    server_b2: float = 0.95
    server_eps: float = 1e-8
    server_weight_decay: float = 0.0
    # H local SGD steps per client per round; the transmitted quantity for
    # H > 1 is the accumulated model delta (w - w_k^H) / (H * local_lr)
    local_steps: int = 1
    local_lr: float = 0.01
    # expected participating fraction per round; 'bernoulli' masks each
    # device independently, 'fixed' schedules exactly round(p*K) devices
    participation: float = 1.0
    participation_mode: str = "bernoulli"

    def __post_init__(self):
        if self.channel is None:
            object.__setattr__(self, "channel",
                               chan.ChannelConfig(num_devices=self.num_devices))
        if self.backend not in ota.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {ota.BACKENDS}")
        schemes.get(self.scheme)   # raises ValueError naming the registry
        if self.case not in ("I", "II"):
            raise ValueError(f"unknown case {self.case!r}; one of ('I', 'II')")
        if self.amplification not in ("optimal", "bmax"):
            raise ValueError(f"unknown amplification {self.amplification!r}; "
                             "one of ('optimal', 'bmax')")
        if self.server_opt not in SERVER_OPTS:
            raise ValueError(f"unknown server_opt {self.server_opt!r}; "
                             f"one of {SERVER_OPTS}")
        if self.local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {self.local_steps}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must lie in (0, 1], got "
                             f"{self.participation}")
        if self.participation_mode not in PARTICIPATION_MODES:
            raise ValueError(
                f"unknown participation_mode {self.participation_mode!r}; "
                f"one of {PARTICIPATION_MODES}")


def structural_config(cfg: FLConfig) -> FLConfig:
    """The *structural signature* of a config: every batchable numeric field
    (``BATCHED_FL_FIELDS`` / ``BATCHED_CHANNEL_FIELDS``) collapsed to a fixed
    sentinel.  Two configs are batchable into one compiled program iff their
    structural signatures are equal; the batched chunk builder is cached on
    this signature, so every sub-batch of a sweep that shares a structure
    shares one executable.  ``grad_bound`` keeps its None-ness (present vs
    absent changes the traced program), not its value."""
    channel = dataclasses.replace(cfg.channel, noise_var=0.0,
                                  channel_mean=1.0, b_max=1.0)
    return dataclasses.replace(
        cfg, seed=0, eta=0.01, s_target=None, epsilon_target=None,
        grad_bound=None if cfg.grad_bound is None else 1.0,
        smoothness_L=1.0, strong_convexity_M=1.0, expected_loss_drop=1.0,
        theta_th=chan.DEFAULT_THETA_TH, channel=channel)


@dataclasses.dataclass
class FLState:
    params: PyTree
    h: np.ndarray
    b: np.ndarray
    a: float
    eta0: float                       # eta for case II; eta_t = eta0/t^p for case I
    round: int = 0
    # the real model dimension N, recorded at setup() time so block-fading
    # re-optimization solves Problem 3 with the true n (not a placeholder)
    model_dim: int = 0
    # server-side optimizer state (initialized lazily by run() for states
    # built before the server_opt axis existed); step counts rounds, so
    # Adam bias correction stays consistent across resumed runs
    opt_state: Optional[optim.OptState] = None


def server_optimizer(cfg: FLConfig) -> optim.Optimizer:
    """The pluggable server-side optimizer of ``cfg.server_opt``.  The
    learning rate is always passed per-call (the paper's eta_t schedules live
    in ``_eta_t``), so the constructor lr is a placeholder."""
    if cfg.server_opt == "adamw":
        return optim.adamw(0.0, b1=cfg.server_b1, b2=cfg.server_b2,
                           eps=cfg.server_eps,
                           weight_decay=cfg.server_weight_decay)
    return optim.sgd(0.0, momentum=cfg.server_momentum)


def setup(cfg: FLConfig, params0: PyTree, model_dim: int) -> FLState:
    """Draw the channel and run the paper's parameter optimization."""
    key = jax.random.PRNGKey(cfg.seed)
    h = np.asarray(chan.draw_channel(key, cfg.channel), np.float64)
    b_max = np.full(cfg.num_devices, cfg.channel.b_max)

    if cfg.amplification == "bmax":
        b = b_max.copy()
        # comparison method of Fig. 1(a): same a * sum(h b) as the optimized run
        sol = amp.solve_problem3(h, cfg.channel.noise_var, model_dim, b_max)
        if cfg.case == "I":
            s_opt = amp.optimal_S(sol.Z, cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
            a = 1.0 / (s_opt * float(np.sum(h * sol.b)))
            a = a * float(np.sum(h * sol.b)) / float(np.sum(h * b))
            eta0 = 1.0
        else:
            c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                                    cfg.smoothness_L, cfg.strong_convexity_M,
                                    cfg.grad_bound, cfg.theta_th,
                                    s=cfg.s_target, epsilon=cfg.epsilon_target)
            a_eta = c2.a_eta * float(np.sum(h * c2.b)) / float(np.sum(h * b))
            a, eta0 = a_eta / cfg.eta, cfg.eta
        return FLState(params0, h, b, a, eta0, model_dim=model_dim)

    if cfg.case == "I":
        c1 = amp.optimize_case1(h, cfg.channel.noise_var, model_dim, b_max,
                                cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
        return FLState(params0, h, c1.b, c1.a, 1.0, model_dim=model_dim)
    c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                            cfg.smoothness_L, cfg.strong_convexity_M,
                            cfg.grad_bound, cfg.theta_th,
                            s=cfg.s_target, epsilon=cfg.epsilon_target)
    return FLState(params0, h, c2.b, c2.a_eta / cfg.eta, cfg.eta,
                   model_dim=model_dim)


def _eta_t(cfg: FLConfig, eta0, t: jax.Array) -> jax.Array:
    if cfg.case == "I":
        return eta0 / jnp.maximum(t.astype(jnp.float32), 1.0) ** cfg.p
    return jnp.asarray(eta0, jnp.float32)


def _participation_mask(cfg: FLConfig, key, t) -> jax.Array:
    """[K] 0/1 per-round participation draw.  ``bernoulli`` masks each device
    independently with probability p; ``fixed`` schedules exactly
    ``round(p * K)`` devices uniformly at random."""
    mk = jax.random.fold_in(jax.random.fold_in(key, t), _MASK_SALT)
    k = cfg.num_devices
    if cfg.participation_mode == "bernoulli":
        return jax.random.bernoulli(mk, cfg.participation, (k,)).astype(
            jnp.float32)
    m = max(1, int(round(cfg.participation * k)))
    perm = jax.random.permutation(mk, k)
    return jnp.zeros((k,), jnp.float32).at[perm[:m]].set(1.0)


def _local_transmit(cfg: FLConfig, grad_fn: GradFn, params, batch) -> PyTree:
    """The quantity each device hands to the scheme's transform: its local
    gradient for ``local_steps == 1`` (the paper), else the accumulated model
    delta of H local SGD steps, ``(w - w_k^H) / (H * local_lr)`` — the average
    local gradient along the trajectory, so its magnitude is comparable to a
    single gradient and ``grad_bound``-based schemes stay calibrated."""
    if cfg.local_steps == 1:
        return jax.vmap(lambda db: grad_fn(params, db))(batch)

    def one_device(db):
        def step(p, _):
            g = grad_fn(p, db)
            return jax.tree_util.tree_map(
                lambda w, gg: w - jnp.asarray(cfg.local_lr, w.dtype)
                * gg.astype(w.dtype), p, g), None

        p_h, _ = jax.lax.scan(step, params, None, length=cfg.local_steps)
        inv = 1.0 / (cfg.local_steps * cfg.local_lr)
        return jax.tree_util.tree_map(
            lambda w0, wh: (w0 - wh) * jnp.asarray(inv, w0.dtype), params, p_h)

    return jax.vmap(one_device)(batch)


def _round_math(cfg: FLConfig, sch, opt, grad_fn: GradFn, params, opt_state,
                batch, h, b, a, eta0, t, key,
                over: Optional[BatchAxes] = None):
    """One FL round (local computation -> OTA aggregate -> server optimizer
    step) plus the scalar diagnostics of ``DIAG_KEYS``.  Pure; traced
    identically by both drivers.  ``over`` carries the per-experiment traced
    scalars of a batched run (None — the single-experiment default — bakes
    the ``cfg`` values into the trace exactly as before)."""
    noise_var = cfg.channel.noise_var
    grad_bound = cfg.grad_bound
    if over is not None:
        if over.noise_var is not None:
            noise_var = over.noise_var
        if over.grad_bound is not None:
            grad_bound = over.grad_bound
    stacked = _local_transmit(cfg, grad_fn, params, batch)
    if cfg.participation < 1.0:
        mask = _participation_mask(cfg, key, t)
        b_eff, a_eff = ota.participation_fold(h, b, a, mask)
    else:
        mask = None
        b_eff, a_eff = b, a
    if mask is not None and sch.baseline:
        # baseline schemes bypass the channel (plain mean on every backend),
        # so the mask cannot reach them through b_eff — average over the
        # participants only, or the ideal reference would silently use all K
        # devices while the diagnostics report a partial cohort
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        y = jax.tree_util.tree_map(
            lambda l: jnp.tensordot(w, l.astype(jnp.float32), axes=(0, 0)),
            stacked)
    else:
        ocfg = ota.OTAConfig(scheme=cfg.scheme, a=a_eff,
                             noise_var=noise_var,
                             grad_bound=grad_bound, backend=cfg.backend)
        y = ota.aggregate(ocfg, stacked, h, b_eff,
                          jax.random.fold_in(key, t))
    if mask is not None:
        # an empty round (possible under bernoulli draws) applies no update:
        # participation_fold zeroed the gain, but server_post schemes can
        # re-shift y, so the update direction is gated too
        any_part = (jnp.sum(mask) > 0).astype(jnp.float32)
        y = jax.tree_util.tree_map(
            lambda l: l * any_part.astype(l.dtype), y)
    eta = _eta_t(cfg, eta0, t)
    new_params, new_opt_state = opt.update(y, opt_state, params, lr=eta)
    if mask is not None:
        # ...and so is the state transition itself: a stateful server
        # optimizer (momentum / adam moments, even weight decay) must not
        # move the model or its moments on a round nobody transmitted in
        keep = jnp.sum(mask) > 0
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(keep, n, o), new_params, params)
        new_opt_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(keep, n, o), new_opt_state, opt_state)
    # one stats pass feeds BOTH diagnostics (grad norms and the eq. 8
    # transmit-energy accounting); the aggregate above keeps its own internal
    # stats — folding the two would need aggregate() to return them
    stats = schemes.compute_stats(stacked, sch, batched=True)
    norms = jnp.sqrt(stats.sq_norm)
    tx = schemes.transmit_energy(sch, stats, b_eff, grad_bound, mask)
    diag = {
        "grad_norm_mean": jnp.mean(norms),
        "grad_norm_min": jnp.min(norms),
        "grad_norm_max": jnp.max(norms),
        "eta": eta,
        "update_norm": jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                    for l in jax.tree_util.tree_leaves(y))),
        # total transmit energy sum_k b_k^2 ||x_k||^2 (eq. 8 budget) via the
        # scheme's analytic accounting; masked-out devices spend nothing
        "tx_energy": jnp.sum(tx),
        "num_participants": (jnp.sum(mask) if mask is not None
                             else jnp.asarray(float(cfg.num_devices),
                                              jnp.float32)),
    }
    return new_params, new_opt_state, diag


def _fading_refresh(cfg: FLConfig, model_dim: int, eff_gain, chan_key, t,
                    over: Optional[BatchAxes] = None):
    """Block fading (beyond the paper, which holds h_k fixed): redraw the
    round-t channel and RE-RUN the Problem-3 optimization, entirely in JAX —
    Algorithm 1 is cheap (O(log(1/eps)(K+1)^3)) relative to a round of local
    training, and ``solve_problem3_jax`` makes it scan-safe (and vmap-safe,
    which is how a batched run re-optimizes every experiment's b_t in one
    program).  The effective receiver-side gain a*sum(h_k b_k) (what the
    bounds see) is held at its optimized value."""
    noise_var = cfg.channel.noise_var
    b_max = cfg.channel.b_max
    scale = None
    if over is not None:
        if over.noise_var is not None:
            noise_var = over.noise_var
        if over.b_max is not None:
            b_max = over.b_max
        scale = over.rayleigh_scale
    h = chan.channel_for_round(chan_key, cfg.channel, t,
                               scale=scale).astype(jnp.float32)
    if cfg.amplification == "optimal":
        sol = amp.solve_problem3_jax(h, noise_var, model_dim, b_max)
        b = sol.b.astype(jnp.float32)
    else:
        b = jnp.broadcast_to(jnp.asarray(b_max, jnp.float32), h.shape)
    a = (eff_gain / jnp.sum(h * b)).astype(jnp.float32)
    return h, b, a


@_engine_cache
def _make_fading_refresh(cfg: FLConfig, model_dim: int):
    """Jitted per-round channel/Problem-3 refresh for the python driver
    (the scan driver inlines ``_fading_refresh`` in its scan body)."""
    def refresh(eff_gain, chan_key, t):
        TRACE_COUNTS["fading_refresh"] += 1
        return _fading_refresh(cfg, model_dim, eff_gain, chan_key, t)

    return jax.jit(refresh)


@_engine_cache
def make_round_step(cfg: FLConfig, grad_fn: GradFn):
    """Builds the jitted one-round function (the ``python`` driver's unit).

    round_step(params, opt_state, device_batches, h, b, a, eta0, t, key)
        -> (new_params, new_opt_state, diagnostics)
    device_batches: pytree with leading [K, ...] axis (per-device minibatches).

    Cached on (cfg, grad_fn) — ``FLConfig`` is a frozen dataclass and
    functions/bound methods hash stably — so repeated ``run`` calls (resume,
    benchmark sweeps) reuse the compiled executable instead of re-tracing.
    """
    sch = schemes.get(cfg.scheme)
    opt = server_optimizer(cfg)

    @jax.jit
    def round_step(params, opt_state, device_batches, h, b, a, eta0, t, key):
        TRACE_COUNTS["round_step"] += 1
        return _round_math(cfg, sch, opt, grad_fn, params, opt_state,
                           device_batches, h, b, a, eta0, t, key)

    return round_step


def _make_chunk_scan(cfg: FLConfig, grad_fn: GradFn, model_dim: int,
                     trace_counter: str):
    """The one chunk-scan body BOTH engine builders share: ``lax.scan`` of
    ``_round_math`` (+ the block-fading refresh) over a chunk of rounds.
    ``over=None`` bakes the config numerics into the trace (the
    single-experiment engine); a ``BatchAxes`` of traced scalars is the
    vmapped sweep engine's per-experiment lane."""
    sch = schemes.get(cfg.scheme)
    opt = server_optimizer(cfg)
    block_fading = cfg.channel.block_fading

    def run_one(params, opt_state, h, b, a, eta0, key, chan_key, eff_gain,
                over, ts, batches):
        TRACE_COUNTS[trace_counter] += 1

        def body(carry, xs):
            params, opt_state, h, b, a = carry
            t, batch = xs
            if block_fading:
                h, b, a = _fading_refresh(cfg, model_dim, eff_gain,
                                          chan_key, t, over)
            params, opt_state, diag = _round_math(
                cfg, sch, opt, grad_fn, params, opt_state, batch,
                h, b, a, eta0, t, key, over)
            return (params, opt_state, h, b, a), diag

        (params, opt_state, h, b, a), hist = jax.lax.scan(
            body, (params, opt_state, h, b, a), (ts, batches))
        return params, opt_state, h, b, a, hist

    return run_one


@_engine_cache
def _make_run_chunk(cfg: FLConfig, grad_fn: GradFn, model_dim: int):
    """Builds the compiled multi-round engine: one ``lax.scan`` over a chunk
    of rounds.  Param and server-optimizer buffers are donated (in-place
    across chunks) and the per-round diagnostics come back as [chunk] device
    arrays — one host transfer per chunk, not one per round.  Cached like
    ``make_round_step``.
    """
    run_one = _make_chunk_scan(cfg, grad_fn, model_dim, "run_chunk")

    def run_chunk(params, opt_state, h, b, a, eta0, key, chan_key, eff_gain,
                  ts, batches):
        return run_one(params, opt_state, h, b, a, eta0, key, chan_key,
                       eff_gain, None, ts, batches)

    return jax.jit(run_chunk, donate_argnums=(0, 1))


@_engine_cache
def _make_run_chunk_batched(cfg: FLConfig, grad_fn: GradFn, model_dim: int):
    """The vectorized sweep engine's unit: the SAME chunk scan as
    ``_make_run_chunk`` (one shared ``_make_chunk_scan`` body), wrapped in
    ``jax.vmap`` over a leading experiment axis E.  Per-experiment state
    (params, optimizer moments, channel h/b/a, eta0, PRNG keys, the
    ``BatchAxes`` traced numerics) is batched; the round schedule ``ts`` and
    the device batches are shared across experiments (in_axes=None), so a
    sub-batch that shares a task shares one host->device batch transfer per
    chunk.

    ``cfg`` must be the *structural* representative of the sub-batch
    (``structural_config``): every per-experiment numeric arrives through the
    batched inputs, never through the baked config, so all sub-batches with
    one structure share this cache entry AND its compiled executables.
    Block-fading chunks redraw every experiment's channel and re-run the
    Problem-3 bisection (``amp.solve_problem3_jax``) inside the vmapped scan
    — ``lax.while_loop``'s batching rule freezes converged lanes, so each
    lane's bisection is identical to its solo run."""
    run_one = _make_chunk_scan(cfg, grad_fn, model_dim, "run_chunk_batched")
    batched = jax.vmap(run_one, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
                                         None, None))
    return jax.jit(batched, donate_argnums=(0, 1))


# name -> lru-cached builder, for cache_info()/clear_compile_caches()
_CACHED_BUILDERS = {
    "round_step": make_round_step,
    "run_chunk": _make_run_chunk,
    "run_chunk_batched": _make_run_chunk_batched,
    "fading_refresh": _make_fading_refresh,
}


def _plan_chunks(t0: int, num_rounds: int, eval_every: Optional[int],
                 chunk_size: int) -> List[List[int]]:
    """Group rounds ``t0+1 .. t0+num_rounds`` into scan chunks.  Every round
    the python driver would eval on (t == 1 or t % eval_every == 0) ends a
    chunk, so the scan driver observes params at identical rounds."""
    chunks: List[List[int]] = []
    cur: List[int] = []
    for t in range(t0 + 1, t0 + num_rounds + 1):
        cur.append(t)
        if (len(cur) >= chunk_size
                or (eval_every is not None
                    and (t == 1 or t % eval_every == 0))):
            chunks.append(cur)
            cur = []
    if cur:
        chunks.append(cur)
    return chunks


def _stack_batches(batch_provider, ts: Sequence[int]) -> PyTree:
    """One [chunk, K, ...] stacked batch pytree per chunk (a single host ->
    device transfer feeds the whole scan)."""
    per_round = [batch_provider(t) for t in ts]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_round)


def _locked_eval_keys(metrics: Dict[str, float],
                      eval_keys: Optional[Tuple[str, ...]], t,
                      where: str = "") -> Tuple[str, ...]:
    """The metric key set is LOCKED on the first eval: an eval_fn that
    returns a key only on some rounds (or, batched, some experiments) would
    silently misalign that metric's history with hist['eval_round'].  Both
    ``run`` and ``run_batched`` share this contract."""
    if eval_keys is None:
        return tuple(metrics)
    if set(metrics) != set(eval_keys):
        raise ValueError(
            f"eval_fn returned metric keys {sorted(metrics)} at round "
            f"{t}{where}, but the history locked {sorted(eval_keys)} on the "
            "first eval — per-round metric lists must stay aligned with "
            "hist['eval_round']")
    return eval_keys


def run(cfg: FLConfig, state: FLState, grad_fn: GradFn,
        batch_provider: Callable[[int], Any], num_rounds: int,
        eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
        eval_every: int = 10, *, driver: str = "scan",
        chunk_size: int = 16,
        chunk_batch_provider: Optional[Callable[[Sequence[int]], Any]] = None,
        ) -> Tuple[FLState, Dict[str, List]]:
    """Run ``num_rounds`` FL rounds on the selected driver.

    ``batch_provider(t)`` returns the per-device minibatch pytree (leading K
    axis) for round t.  ``driver='scan'`` (default) runs the compiled chunked
    engine; ``driver='python'`` the per-round host loop (see module
    docstring).  Both evaluate ``eval_fn`` at t == 1 and every
    ``eval_every``-th round, produce the same history keys, and persist the
    final channel state (h, b, a under block fading) plus the round counter
    back into ``state`` so a second ``run`` resumes seamlessly.

    ``chunk_batch_provider(ts)``, when given, supplies a whole chunk's
    batches as one [T, K, ...] pytree (a single gather + transfer), replacing
    the scan driver's default of stacking T ``batch_provider`` calls.

    This signature is the stable compatibility surface; new scenario axes
    (server optimizer, local steps, participation) are ``FLConfig`` fields,
    and ``repro.fl.Experiment`` is the declarative front door that builds
    cfg/state/providers from one spec and calls here.
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r}; one of {DRIVERS}")
    opt = server_optimizer(cfg)
    if state.opt_state is None:
        # states built before the server-optimizer axis (or restored from
        # old checkpoints): initialize, with step = rounds already taken so
        # Adam bias correction matches an unbroken run
        state.opt_state = opt.init(state.params)._replace(
            step=jnp.asarray(state.round, jnp.int32))
    opt_state = state.opt_state
    key = jax.random.PRNGKey(cfg.seed + 1)
    h = jnp.asarray(state.h, jnp.float32)
    b = jnp.asarray(state.b, jnp.float32)
    a = jnp.asarray(state.a, jnp.float32)
    eta0 = jnp.asarray(state.eta0, jnp.float32)
    block_fading = cfg.channel.block_fading
    chan_key = jax.random.PRNGKey(cfg.seed + 2)
    eff_gain = jnp.zeros((), jnp.float32)
    if block_fading:
        if state.model_dim <= 0:
            raise ValueError("block fading re-solves Problem 3 with the real "
                             "model dimension; FLState.model_dim is unset — "
                             "build the state via setup()")
        eff_gain = jnp.asarray(
            state.a * float(np.sum(np.asarray(state.h, np.float64)
                                   * np.asarray(state.b, np.float64))),
            jnp.float32)

    hist: Dict[str, List] = {"round": [], "eval_round": []}
    for k in DIAG_KEYS:
        hist[k] = []

    eval_keys: Optional[Tuple[str, ...]] = None

    def record_eval(params, t):
        nonlocal eval_keys
        metrics = eval_fn(params)
        eval_keys = _locked_eval_keys(metrics, eval_keys, t)
        for mk in eval_keys:
            hist.setdefault(mk, []).append(metrics[mk])
        hist["eval_round"].append(t)

    t0 = state.round
    if driver == "python":
        round_step = make_round_step(cfg, grad_fn)
        fading_refresh = _make_fading_refresh(cfg, state.model_dim)
        params = state.params
        for t in range(t0 + 1, t0 + num_rounds + 1):
            if block_fading:
                h, b, a = fading_refresh(eff_gain, chan_key, jnp.asarray(t))
            batch = batch_provider(t)
            params, opt_state, diag = round_step(params, opt_state, batch,
                                                 h, b, a, eta0,
                                                 jnp.asarray(t), key)
            hist["round"].append(t)
            for k in DIAG_KEYS:
                hist[k].append(float(diag[k]))
            if eval_fn is not None and (t % eval_every == 0 or t == 1):
                record_eval(params, t)
    else:
        run_chunk = _make_run_chunk(cfg, grad_fn, state.model_dim)
        # params and optimizer state are donated chunk-to-chunk; copy once so
        # the CALLER's pytrees (often reused across runs, e.g. the benchmark
        # experiments) survive
        params = jax.tree_util.tree_map(jnp.copy, state.params)
        opt_state = jax.tree_util.tree_map(jnp.copy, opt_state)
        for ts in _plan_chunks(t0, num_rounds,
                               eval_every if eval_fn is not None else None,
                               chunk_size):
            batches = (chunk_batch_provider(ts) if chunk_batch_provider
                       else _stack_batches(batch_provider, ts))
            params, opt_state, h, b, a, chunk_hist = run_chunk(
                params, opt_state, h, b, a, eta0, key, chan_key, eff_gain,
                jnp.asarray(ts, jnp.int32), batches)
            chunk_hist = jax.device_get(chunk_hist)   # ONE sync per chunk
            hist["round"].extend(ts)
            for k in DIAG_KEYS:
                hist[k].extend(np.asarray(chunk_hist[k]).astype(float).tolist())
            t_end = ts[-1]
            if eval_fn is not None and (t_end % eval_every == 0 or t_end == 1):
                record_eval(params, t_end)

    state.params = params
    state.opt_state = opt_state
    if block_fading:
        # persist the final channel/gain so a second run(cfg, state, ...)
        # resumes from round t0+num_rounds, not the stale round-0 draw
        state.h = np.asarray(jax.device_get(h), np.float64)
        state.b = np.asarray(jax.device_get(b), np.float64)
        state.a = float(a)
    state.round += num_rounds
    return state, hist


def _stack_trees(trees: Sequence[PyTree]) -> PyTree:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _slice_tree(tree: PyTree, e: int) -> PyTree:
    return jax.tree_util.tree_map(lambda l: l[e], tree)


def run_batched(cfgs: Sequence[FLConfig], states: Sequence[FLState],
                grad_fn: GradFn, batch_provider: Callable[[int], Any],
                num_rounds: int,
                eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
                eval_every: int = 10, *, chunk_size: int = 16,
                chunk_batch_provider: Optional[
                    Callable[[Sequence[int]], Any]] = None,
                shard: bool = True) -> Tuple[List[FLState], Dict[str, Any]]:
    """Run E experiments as ONE compiled program: the vectorized twin of
    ``run(driver='scan')``.

    The configs must be *structurally identical* (equal
    ``structural_config``): same scheme / case / backend / scenario axes /
    fading mode, differing only in the batchable numerics
    (``BATCHED_FL_FIELDS`` / ``BATCHED_CHANNEL_FIELDS``) — those travel as
    per-experiment traced inputs through ``BatchAxes`` and the stacked
    h/b/a/eta0 channel state, so E grid points cost one trace and one
    dispatch per chunk.  All experiments share ``grad_fn`` and the batch
    providers (one task), the round counter, and the eval schedule.

    When multiple local devices are available and E divides their count, the
    experiment axis is sharded across them
    (``distribution.sharding.experiment_mesh``) — grid points run on
    different devices with no further code change.

    Returns ``(states, hist)`` where each per-round diagnostic in ``hist``
    is an ``np.ndarray`` of shape [E, num_rounds] (same ``DIAG_KEYS`` as
    ``run`` plus the leading experiment axis), eval metrics are
    [E, num_evals], and ``hist['round']`` / ``hist['eval_round']`` stay flat
    lists shared by every experiment.  ``states`` is updated in place per
    experiment exactly like ``run`` updates its single state.

    The mesh backend is not batchable (its device axis IS the mesh); callers
    (``repro.fl.sweep``) fall back to sequential runs there.
    """
    if len(cfgs) != len(states) or not cfgs:
        raise ValueError("need equal, nonzero numbers of configs and states")
    num_exp = len(cfgs)
    cfg0 = cfgs[0]
    if cfg0.backend == "mesh":
        raise ValueError("the mesh backend reserves the device axis for the "
                         "FL devices; run mesh experiments sequentially")
    sig = structural_config(cfg0)
    for c in cfgs[1:]:
        if structural_config(c) != sig:
            raise ValueError(
                "configs in a batched run must be structurally identical "
                "(they may differ only in "
                f"{BATCHED_FL_FIELDS + BATCHED_CHANNEL_FIELDS}); got "
                f"{structural_config(c)} vs {sig}")
    t0s = {s.round for s in states}
    if len(t0s) != 1:
        raise ValueError(f"states disagree on the round counter: {t0s}")
    t0 = t0s.pop()
    dims = {s.model_dim for s in states}
    if len(dims) != 1:
        raise ValueError(f"states disagree on model_dim: {dims} — a batched "
                         "run shares one task")
    model_dim = dims.pop()

    opt = server_optimizer(cfg0)
    for s in states:
        if s.opt_state is None:
            s.opt_state = opt.init(s.params)._replace(
                step=jnp.asarray(s.round, jnp.int32))

    # assemble the per-experiment numerics in NumPy — ONE host->device
    # transfer per stacked array, not one dispatch per experiment (the
    # stacking cost is per run_sweep call, so it must stay off the grid's
    # critical path)
    params = _stack_trees([s.params for s in states])
    opt_state = _stack_trees([s.opt_state for s in states])
    h = jnp.asarray(np.stack([np.asarray(s.h) for s in states]), jnp.float32)
    b = jnp.asarray(np.stack([np.asarray(s.b) for s in states]), jnp.float32)
    a = jnp.asarray(np.asarray([s.a for s in states]), jnp.float32)
    eta0 = jnp.asarray(np.asarray([s.eta0 for s in states]), jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(c.seed + 1) for c in cfgs])
    chan_keys = jnp.stack([jax.random.PRNGKey(c.seed + 2) for c in cfgs])
    block_fading = cfg0.channel.block_fading
    eff_gain = jnp.zeros((num_exp,), jnp.float32)
    if block_fading:
        if model_dim <= 0:
            raise ValueError("block fading re-solves Problem 3 with the real "
                             "model dimension; FLState.model_dim is unset — "
                             "build the states via setup()")
        eff_gain = jnp.asarray(
            np.asarray([s.a * float(np.sum(np.asarray(s.h, np.float64)
                                           * np.asarray(s.b, np.float64)))
                        for s in states]), jnp.float32)
    over = BatchAxes(
        noise_var=jnp.asarray(
            np.asarray([c.channel.noise_var for c in cfgs]), jnp.float32),
        grad_bound=(None if cfg0.grad_bound is None else jnp.asarray(
            np.asarray([c.grad_bound for c in cfgs]), jnp.float32)),
        b_max=(jnp.asarray(np.asarray([c.channel.b_max for c in cfgs]),
                           jnp.float32) if block_fading else None),
        rayleigh_scale=(jnp.asarray(
            np.asarray([c.channel.rayleigh_scale() for c in cfgs]),
            jnp.float32) if block_fading else None),
    )

    if shard:
        from repro.distribution import sharding as shardlib
        mesh = shardlib.experiment_mesh(num_exp)
        if mesh is not None:
            (params, opt_state, h, b, a, eta0, keys, chan_keys, eff_gain,
             over) = shardlib.shard_experiment_axis(
                 (params, opt_state, h, b, a, eta0, keys, chan_keys,
                  eff_gain, over), mesh)

    hist: Dict[str, Any] = {"round": [], "eval_round": []}
    diag_chunks: Dict[str, List[np.ndarray]] = {k: [] for k in DIAG_KEYS}
    eval_chunks: Dict[str, List[List[float]]] = {}
    eval_keys: Optional[Tuple[str, ...]] = None

    def record_eval(params, t):
        nonlocal eval_keys
        per_exp: Dict[str, List[float]] = {}
        for e in range(num_exp):
            metrics = eval_fn(_slice_tree(params, e))
            eval_keys = _locked_eval_keys(metrics, eval_keys, t,
                                          where=f" (experiment {e})")
            for mk in eval_keys:
                per_exp.setdefault(mk, []).append(metrics[mk])
        for mk in eval_keys:
            eval_chunks.setdefault(mk, []).append(per_exp[mk])
        hist["eval_round"].append(t)

    run_chunk = _make_run_chunk_batched(sig, grad_fn, model_dim)
    for ts in _plan_chunks(t0, num_rounds,
                           eval_every if eval_fn is not None else None,
                           chunk_size):
        batches = (chunk_batch_provider(ts) if chunk_batch_provider
                   else _stack_batches(batch_provider, ts))
        params, opt_state, h, b, a, chunk_hist = run_chunk(
            params, opt_state, h, b, a, eta0, keys, chan_keys, eff_gain,
            over, jnp.asarray(ts, jnp.int32), batches)
        chunk_hist = jax.device_get(chunk_hist)   # ONE sync per chunk
        hist["round"].extend(ts)
        for k in DIAG_KEYS:
            diag_chunks[k].append(np.asarray(chunk_hist[k], np.float64))
        t_end = ts[-1]
        if eval_fn is not None and (t_end % eval_every == 0 or t_end == 1):
            record_eval(params, t_end)

    for k in DIAG_KEYS:
        hist[k] = np.concatenate(diag_chunks[k], axis=1)       # [E, T]
    for mk, cols in eval_chunks.items():
        hist[mk] = np.asarray(cols, np.float64).T              # [E, evals]

    for e, s in enumerate(states):
        s.params = _slice_tree(params, e)
        s.opt_state = _slice_tree(opt_state, e)
        if block_fading:
            s.h = np.asarray(jax.device_get(h[e]), np.float64)
            s.b = np.asarray(jax.device_get(b[e]), np.float64)
            s.a = float(a[e])
        s.round += num_rounds
    return list(states), hist
