"""Federated-learning runtime (paper Sec. II, Steps 1-3, iterated).

The K devices are a ``jax.vmap`` axis; one round (local gradients -> OTA
superposition -> server update -> broadcast) is a single jittable program.
``FLConfig.backend`` selects which execution backend the aggregation routes
through — ``vmap`` (pure XLA), ``kernels`` (fused Pallas path; the default
for benchmarks), or ``mesh`` (shard_map/psum over local devices; needs >= K
of them).  The production mesh train-step builder (devices = data shards of
a TPU mesh) lives in ``repro.launch.train``.

Two round-loop drivers (``run(..., driver=...)``):

``scan``   (default) the compiled multi-round engine: ``jax.lax.scan`` over
           rounds, dispatched in chunks whose param buffers are donated and
           whose per-round history lands in on-device arrays transferred
           once per chunk.  Under block fading the channel redraw
           (``core.channel.channel_for_round``) AND the Problem-3
           re-optimization (``core.amplification.solve_problem3_jax``, a
           ``lax.while_loop`` bisection) run *inside* the scan — the whole
           trajectory is one XLA program per chunk, no host callbacks.
``python`` the host-loop fallback: one jitted round per dispatch, history
           appended eagerly.  Use it when an ``eval_fn`` must observe every
           round or for step-debugging; it computes the identical numbers
           (tests/test_engine.py holds the two drivers to fp32 parity on
           every backend, fixed and block-fading).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amplification as amp
from repro.core import channel as chan
from repro.core import ota
from repro.core import schemes

PyTree = Any
GradFn = Callable[[PyTree, Any], PyTree]   # (params, device_batch) -> grads

DRIVERS = ("scan", "python")
# per-round scalar diagnostics recorded by BOTH drivers (same device-side
# math, so the drivers' histories agree exactly)
DIAG_KEYS = ("grad_norm_mean", "grad_norm_min", "grad_norm_max", "eta",
             "update_norm", "tx_energy")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    backend: str = "vmap"             # 'vmap' | 'kernels' | 'mesh' (see core.ota)
    case: str = "I"                   # 'I' (eta_t = 1/t^p) or 'II' (constant eta)
    p: float = 0.75                   # Case-I schedule exponent (paper: 0.75)
    eta: float = 0.01                 # Case-II constant learning rate (paper: 0.01)
    theta_th: float = chan.DEFAULT_THETA_TH
    channel: chan.ChannelConfig = None
    seed: int = 0
    # amplification policy: 'optimal' (Algorithm 1 / Problem 3) or 'bmax'
    # (the no-optimization comparison of Fig. 1(a)/2(a): every b_k = b_k^max)
    amplification: str = "optimal"
    grad_bound: Optional[float] = None   # G, needed by benchmark1 + Case II
    # Case-II target: pick exactly one (s wins if both set)
    s_target: Optional[float] = None
    epsilon_target: Optional[float] = None
    # Case-I optimal-S inputs
    smoothness_L: float = 1.0
    strong_convexity_M: float = 1.0
    expected_loss_drop: float = 1.0

    def __post_init__(self):
        if self.channel is None:
            object.__setattr__(self, "channel",
                               chan.ChannelConfig(num_devices=self.num_devices))
        if self.backend not in ota.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {ota.BACKENDS}")


@dataclasses.dataclass
class FLState:
    params: PyTree
    h: np.ndarray
    b: np.ndarray
    a: float
    eta0: float                       # eta for case II; eta_t = eta0/t^p for case I
    round: int = 0
    # the real model dimension N, recorded at setup() time so block-fading
    # re-optimization solves Problem 3 with the true n (not a placeholder)
    model_dim: int = 0


def setup(cfg: FLConfig, params0: PyTree, model_dim: int) -> FLState:
    """Draw the channel and run the paper's parameter optimization."""
    key = jax.random.PRNGKey(cfg.seed)
    h = np.asarray(chan.draw_channel(key, cfg.channel), np.float64)
    b_max = np.full(cfg.num_devices, cfg.channel.b_max)

    if cfg.amplification == "bmax":
        b = b_max.copy()
        # comparison method of Fig. 1(a): same a * sum(h b) as the optimized run
        sol = amp.solve_problem3(h, cfg.channel.noise_var, model_dim, b_max)
        if cfg.case == "I":
            s_opt = amp.optimal_S(sol.Z, cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
            a = 1.0 / (s_opt * float(np.sum(h * sol.b)))
            a = a * float(np.sum(h * sol.b)) / float(np.sum(h * b))
            eta0 = 1.0
        else:
            c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                                    cfg.smoothness_L, cfg.strong_convexity_M,
                                    cfg.grad_bound, cfg.theta_th,
                                    s=cfg.s_target, epsilon=cfg.epsilon_target)
            a_eta = c2.a_eta * float(np.sum(h * c2.b)) / float(np.sum(h * b))
            a, eta0 = a_eta / cfg.eta, cfg.eta
        return FLState(params0, h, b, a, eta0, model_dim=model_dim)

    if cfg.case == "I":
        c1 = amp.optimize_case1(h, cfg.channel.noise_var, model_dim, b_max,
                                cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
        return FLState(params0, h, c1.b, c1.a, 1.0, model_dim=model_dim)
    c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                            cfg.smoothness_L, cfg.strong_convexity_M,
                            cfg.grad_bound, cfg.theta_th,
                            s=cfg.s_target, epsilon=cfg.epsilon_target)
    return FLState(params0, h, c2.b, c2.a_eta / cfg.eta, cfg.eta,
                   model_dim=model_dim)


def _eta_t(cfg: FLConfig, eta0, t: jax.Array) -> jax.Array:
    if cfg.case == "I":
        return eta0 / jnp.maximum(t.astype(jnp.float32), 1.0) ** cfg.p
    return jnp.asarray(eta0, jnp.float32)


def _round_math(cfg: FLConfig, sch, grad_fn: GradFn, params, batch,
                h, b, a, eta0, t, key):
    """One FL round (local grads -> OTA aggregate -> update) plus the scalar
    diagnostics of ``DIAG_KEYS``.  Pure; traced identically by both drivers."""
    stacked = jax.vmap(lambda db: grad_fn(params, db))(batch)
    ocfg = ota.OTAConfig(scheme=cfg.scheme, a=a,
                         noise_var=cfg.channel.noise_var,
                         grad_bound=cfg.grad_bound, backend=cfg.backend)
    y = ota.aggregate(ocfg, stacked, h, b, jax.random.fold_in(key, t))
    eta = _eta_t(cfg, eta0, t)
    new_params = ota.apply_update(params, y, eta)
    # one stats pass feeds BOTH diagnostics (grad norms and the eq. 8
    # transmit-energy accounting); the aggregate above keeps its own internal
    # stats — folding the two would need aggregate() to return them
    stats = schemes.compute_stats(stacked, sch, batched=True)
    norms = jnp.sqrt(stats.sq_norm)
    tx = (jnp.square(b.astype(jnp.float32))
          * sch.transmit_sq_norm(stats, cfg.grad_bound))
    diag = {
        "grad_norm_mean": jnp.mean(norms),
        "grad_norm_min": jnp.min(norms),
        "grad_norm_max": jnp.max(norms),
        "eta": eta,
        "update_norm": jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                    for l in jax.tree_util.tree_leaves(y))),
        # total transmit energy sum_k b_k^2 ||x_k||^2 (eq. 8 budget) via the
        # scheme's analytic accounting
        "tx_energy": jnp.sum(tx),
    }
    return new_params, diag


def _fading_refresh(cfg: FLConfig, model_dim: int, eff_gain, chan_key, t):
    """Block fading (beyond the paper, which holds h_k fixed): redraw the
    round-t channel and RE-RUN the Problem-3 optimization, entirely in JAX —
    Algorithm 1 is cheap (O(log(1/eps)(K+1)^3)) relative to a round of local
    training, and ``solve_problem3_jax`` makes it scan-safe.  The effective
    receiver-side gain a*sum(h_k b_k) (what the bounds see) is held at its
    optimized value."""
    h = chan.channel_for_round(chan_key, cfg.channel, t).astype(jnp.float32)
    if cfg.amplification == "optimal":
        sol = amp.solve_problem3_jax(h, cfg.channel.noise_var, model_dim,
                                     cfg.channel.b_max)
        b = sol.b.astype(jnp.float32)
    else:
        b = jnp.full(h.shape, cfg.channel.b_max, jnp.float32)
    a = (eff_gain / jnp.sum(h * b)).astype(jnp.float32)
    return h, b, a


@functools.lru_cache(maxsize=32)
def _make_fading_refresh(cfg: FLConfig, model_dim: int):
    """Jitted per-round channel/Problem-3 refresh for the python driver
    (the scan driver inlines ``_fading_refresh`` in its scan body)."""
    return jax.jit(partial(_fading_refresh, cfg, model_dim))


@functools.lru_cache(maxsize=32)
def make_round_step(cfg: FLConfig, grad_fn: GradFn):
    """Builds the jitted one-round function (the ``python`` driver's unit).

    round_step(params, device_batches, h, b, a, eta0, t, key)
        -> (new_params, diagnostics)
    device_batches: pytree with leading [K, ...] axis (per-device minibatches).

    Cached on (cfg, grad_fn) — ``FLConfig`` is a frozen dataclass and
    functions/bound methods hash stably — so repeated ``run`` calls (resume,
    benchmark sweeps) reuse the compiled executable instead of re-tracing.
    """
    sch = schemes.get(cfg.scheme)

    @jax.jit
    def round_step(params, device_batches, h, b, a, eta0, t, key):
        return _round_math(cfg, sch, grad_fn, params, device_batches,
                           h, b, a, eta0, t, key)

    return round_step


@functools.lru_cache(maxsize=32)
def _make_run_chunk(cfg: FLConfig, grad_fn: GradFn, model_dim: int):
    """Builds the compiled multi-round engine: one ``lax.scan`` over a chunk
    of rounds.  Param buffers are donated (in-place across chunks) and the
    per-round diagnostics come back as [chunk] device arrays — one host
    transfer per chunk, not one per round.  Cached like ``make_round_step``.
    """
    sch = schemes.get(cfg.scheme)
    block_fading = cfg.channel.block_fading

    def run_chunk(params, h, b, a, eta0, key, chan_key, eff_gain, ts, batches):
        def body(carry, xs):
            params, h, b, a = carry
            t, batch = xs
            if block_fading:
                h, b, a = _fading_refresh(cfg, model_dim, eff_gain,
                                          chan_key, t)
            params, diag = _round_math(cfg, sch, grad_fn, params, batch,
                                       h, b, a, eta0, t, key)
            return (params, h, b, a), diag

        (params, h, b, a), hist = jax.lax.scan(body, (params, h, b, a),
                                               (ts, batches))
        return params, h, b, a, hist

    return jax.jit(run_chunk, donate_argnums=(0,))


def _plan_chunks(t0: int, num_rounds: int, eval_every: Optional[int],
                 chunk_size: int) -> List[List[int]]:
    """Group rounds ``t0+1 .. t0+num_rounds`` into scan chunks.  Every round
    the python driver would eval on (t == 1 or t % eval_every == 0) ends a
    chunk, so the scan driver observes params at identical rounds."""
    chunks: List[List[int]] = []
    cur: List[int] = []
    for t in range(t0 + 1, t0 + num_rounds + 1):
        cur.append(t)
        if (len(cur) >= chunk_size
                or (eval_every is not None
                    and (t == 1 or t % eval_every == 0))):
            chunks.append(cur)
            cur = []
    if cur:
        chunks.append(cur)
    return chunks


def _stack_batches(batch_provider, ts: Sequence[int]) -> PyTree:
    """One [chunk, K, ...] stacked batch pytree per chunk (a single host ->
    device transfer feeds the whole scan)."""
    per_round = [batch_provider(t) for t in ts]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_round)


def run(cfg: FLConfig, state: FLState, grad_fn: GradFn,
        batch_provider: Callable[[int], Any], num_rounds: int,
        eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
        eval_every: int = 10, *, driver: str = "scan",
        chunk_size: int = 16,
        chunk_batch_provider: Optional[Callable[[Sequence[int]], Any]] = None,
        ) -> Tuple[FLState, Dict[str, List]]:
    """Run ``num_rounds`` FL rounds on the selected driver.

    ``batch_provider(t)`` returns the per-device minibatch pytree (leading K
    axis) for round t.  ``driver='scan'`` (default) runs the compiled chunked
    engine; ``driver='python'`` the per-round host loop (see module
    docstring).  Both evaluate ``eval_fn`` at t == 1 and every
    ``eval_every``-th round, produce the same history keys, and persist the
    final channel state (h, b, a under block fading) plus the round counter
    back into ``state`` so a second ``run`` resumes seamlessly.

    ``chunk_batch_provider(ts)``, when given, supplies a whole chunk's
    batches as one [T, K, ...] pytree (a single gather + transfer), replacing
    the scan driver's default of stacking T ``batch_provider`` calls.
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r}; one of {DRIVERS}")
    key = jax.random.PRNGKey(cfg.seed + 1)
    h = jnp.asarray(state.h, jnp.float32)
    b = jnp.asarray(state.b, jnp.float32)
    a = jnp.asarray(state.a, jnp.float32)
    eta0 = jnp.asarray(state.eta0, jnp.float32)
    block_fading = cfg.channel.block_fading
    chan_key = jax.random.PRNGKey(cfg.seed + 2)
    eff_gain = jnp.zeros((), jnp.float32)
    if block_fading:
        if state.model_dim <= 0:
            raise ValueError("block fading re-solves Problem 3 with the real "
                             "model dimension; FLState.model_dim is unset — "
                             "build the state via setup()")
        eff_gain = jnp.asarray(
            state.a * float(np.sum(np.asarray(state.h, np.float64)
                                   * np.asarray(state.b, np.float64))),
            jnp.float32)

    hist: Dict[str, List] = {"round": [], "eval_round": []}
    for k in DIAG_KEYS:
        hist[k] = []

    def record_eval(params, t):
        metrics = eval_fn(params)
        for mk, v in metrics.items():
            hist.setdefault(mk, []).append(v)
        hist["eval_round"].append(t)

    t0 = state.round
    if driver == "python":
        round_step = make_round_step(cfg, grad_fn)
        fading_refresh = _make_fading_refresh(cfg, state.model_dim)
        params = state.params
        for t in range(t0 + 1, t0 + num_rounds + 1):
            if block_fading:
                h, b, a = fading_refresh(eff_gain, chan_key, jnp.asarray(t))
            batch = batch_provider(t)
            params, diag = round_step(params, batch, h, b, a, eta0,
                                      jnp.asarray(t), key)
            hist["round"].append(t)
            for k in DIAG_KEYS:
                hist[k].append(float(diag[k]))
            if eval_fn is not None and (t % eval_every == 0 or t == 1):
                record_eval(params, t)
    else:
        run_chunk = _make_run_chunk(cfg, grad_fn, state.model_dim)
        # params are donated chunk-to-chunk; copy once so the CALLER's pytree
        # (often reused across runs, e.g. the benchmark experiments) survives
        params = jax.tree_util.tree_map(jnp.copy, state.params)
        for ts in _plan_chunks(t0, num_rounds,
                               eval_every if eval_fn is not None else None,
                               chunk_size):
            batches = (chunk_batch_provider(ts) if chunk_batch_provider
                       else _stack_batches(batch_provider, ts))
            params, h, b, a, chunk_hist = run_chunk(
                params, h, b, a, eta0, key, chan_key, eff_gain,
                jnp.asarray(ts, jnp.int32), batches)
            chunk_hist = jax.device_get(chunk_hist)   # ONE sync per chunk
            hist["round"].extend(ts)
            for k in DIAG_KEYS:
                hist[k].extend(np.asarray(chunk_hist[k]).astype(float).tolist())
            t_end = ts[-1]
            if eval_fn is not None and (t_end % eval_every == 0 or t_end == 1):
                record_eval(params, t_end)

    state.params = params
    if block_fading:
        # persist the final channel/gain so a second run(cfg, state, ...)
        # resumes from round t0+num_rounds, not the stale round-0 draw
        state.h = np.asarray(jax.device_get(h), np.float64)
        state.b = np.asarray(jax.device_get(b), np.float64)
        state.a = float(a)
    state.round += num_rounds
    return state, hist
