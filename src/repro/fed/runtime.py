"""Federated-learning runtime (paper Sec. II, Steps 1-3, iterated).

The K devices are a ``jax.vmap`` axis; one round (local gradients -> OTA
superposition -> server update -> broadcast) is a single jitted program.
``FLConfig.backend`` selects which execution backend the aggregation routes
through — ``vmap`` (pure XLA), ``kernels`` (fused Pallas path; the default
for benchmarks), or ``mesh`` (shard_map/psum over local devices; needs >= K
of them).  The production mesh train-step builder (devices = data shards of
a TPU mesh) lives in ``repro.launch.train``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amplification as amp
from repro.core import channel as chan
from repro.core import ota
from repro.core import schemes
from repro.core.convergence import variance_term

PyTree = Any
GradFn = Callable[[PyTree, Any], PyTree]   # (params, device_batch) -> grads


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    backend: str = "vmap"             # 'vmap' | 'kernels' | 'mesh' (see core.ota)
    case: str = "I"                   # 'I' (eta_t = 1/t^p) or 'II' (constant eta)
    p: float = 0.75                   # Case-I schedule exponent (paper: 0.75)
    eta: float = 0.01                 # Case-II constant learning rate (paper: 0.01)
    theta_th: float = chan.DEFAULT_THETA_TH
    channel: chan.ChannelConfig = None
    seed: int = 0
    # amplification policy: 'optimal' (Algorithm 1 / Problem 3) or 'bmax'
    # (the no-optimization comparison of Fig. 1(a)/2(a): every b_k = b_k^max)
    amplification: str = "optimal"
    grad_bound: Optional[float] = None   # G, needed by benchmark1 + Case II
    # Case-II target: pick exactly one (s wins if both set)
    s_target: Optional[float] = None
    epsilon_target: Optional[float] = None
    # Case-I optimal-S inputs
    smoothness_L: float = 1.0
    strong_convexity_M: float = 1.0
    expected_loss_drop: float = 1.0

    def __post_init__(self):
        if self.channel is None:
            object.__setattr__(self, "channel",
                               chan.ChannelConfig(num_devices=self.num_devices))
        if self.backend not in ota.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {ota.BACKENDS}")


@dataclasses.dataclass
class FLState:
    params: PyTree
    h: np.ndarray
    b: np.ndarray
    a: float
    eta0: float                       # eta for case II; eta_t = eta0/t^p for case I
    round: int = 0
    # the real model dimension N, recorded at setup() time so block-fading
    # re-optimization solves Problem 3 with the true n (not a placeholder)
    model_dim: int = 0


def setup(cfg: FLConfig, params0: PyTree, model_dim: int) -> FLState:
    """Draw the channel and run the paper's parameter optimization."""
    key = jax.random.PRNGKey(cfg.seed)
    h = np.asarray(chan.draw_channel(key, cfg.channel), np.float64)
    b_max = np.full(cfg.num_devices, cfg.channel.b_max)

    if cfg.amplification == "bmax":
        b = b_max.copy()
        # comparison method of Fig. 1(a): same a * sum(h b) as the optimized run
        sol = amp.solve_problem3(h, cfg.channel.noise_var, model_dim, b_max)
        if cfg.case == "I":
            s_opt = amp.optimal_S(sol.Z, cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
            a = 1.0 / (s_opt * float(np.sum(h * sol.b)))
            a = a * float(np.sum(h * sol.b)) / float(np.sum(h * b))
            eta0 = 1.0
        else:
            c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                                    cfg.smoothness_L, cfg.strong_convexity_M,
                                    cfg.grad_bound, cfg.theta_th,
                                    s=cfg.s_target, epsilon=cfg.epsilon_target)
            a_eta = c2.a_eta * float(np.sum(h * c2.b)) / float(np.sum(h * b))
            a, eta0 = a_eta / cfg.eta, cfg.eta
        return FLState(params0, h, b, a, eta0, model_dim=model_dim)

    if cfg.case == "I":
        c1 = amp.optimize_case1(h, cfg.channel.noise_var, model_dim, b_max,
                                cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
        return FLState(params0, h, c1.b, c1.a, 1.0, model_dim=model_dim)
    c2 = amp.optimize_case2(h, cfg.channel.noise_var, model_dim, b_max,
                            cfg.smoothness_L, cfg.strong_convexity_M,
                            cfg.grad_bound, cfg.theta_th,
                            s=cfg.s_target, epsilon=cfg.epsilon_target)
    return FLState(params0, h, c2.b, c2.a_eta / cfg.eta, cfg.eta,
                   model_dim=model_dim)


def _eta_t(cfg: FLConfig, eta0: float, t: jax.Array) -> jax.Array:
    if cfg.case == "I":
        return eta0 / jnp.maximum(t.astype(jnp.float32), 1.0) ** cfg.p
    return jnp.asarray(eta0, jnp.float32)


def make_round_step(cfg: FLConfig, grad_fn: GradFn):
    """Builds the jitted one-round function.

    round_step(params, device_batches, h, b, a, eta0, t, key)
        -> (new_params, diagnostics)
    device_batches: pytree with leading [K, ...] axis (per-device minibatches).
    """
    ota_cfg_base = dict(scheme=cfg.scheme, noise_var=cfg.channel.noise_var,
                        grad_bound=cfg.grad_bound, backend=cfg.backend)

    sch = schemes.get(cfg.scheme)

    @jax.jit
    def round_step(params, device_batches, h, b, a, eta0, t, key):
        stacked = jax.vmap(lambda db: grad_fn(params, db))(device_batches)
        ocfg = ota.OTAConfig(a=a, **ota_cfg_base)
        y = ota.aggregate(ocfg, stacked, h, b, jax.random.fold_in(key, t))
        eta = _eta_t(cfg, eta0, t)
        new_params = ota.apply_update(params, y, eta)
        # one stats pass feeds BOTH diagnostics (grad norms and the eq. 8
        # transmit-energy accounting) — no second reduction over the grads
        stats = schemes.compute_stats(stacked, sch, batched=True)
        diag = {
            "grad_norms": jnp.sqrt(stats.sq_norm),
            "update_norm": jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                        for l in jax.tree_util.tree_leaves(y))),
            "eta": eta,
            # per-device transmit energy b_k^2 ||x_k||^2 (eq. 8 budget) via
            # the scheme's analytic accounting
            "tx_energy": (jnp.square(b.astype(jnp.float32))
                          * sch.transmit_sq_norm(stats, cfg.grad_bound)),
        }
        return new_params, diag

    return round_step


def run(cfg: FLConfig, state: FLState, grad_fn: GradFn,
        batch_provider: Callable[[int], Any], num_rounds: int,
        eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
        eval_every: int = 10) -> Tuple[FLState, Dict[str, List]]:
    """Run ``num_rounds`` FL rounds.  ``batch_provider(t)`` returns the
    per-device minibatch pytree (leading K axis) for round t."""
    round_step = make_round_step(cfg, grad_fn)
    key = jax.random.PRNGKey(cfg.seed + 1)
    h = jnp.asarray(state.h, jnp.float32)
    b = jnp.asarray(state.b, jnp.float32)
    a = state.a
    # Block fading (beyond the paper, which holds h_k fixed): redraw the
    # channel every round and RE-RUN the Problem-3 optimization — Algorithm 1
    # is cheap (O(log(1/eps)(K+1)^3)) relative to a round of local training.
    # The effective receiver-side gain a*sum(h_k b_k) (what the bounds see)
    # is held at its optimized value.
    block_fading = cfg.channel.block_fading
    if block_fading:
        if state.model_dim <= 0:
            raise ValueError("block fading re-solves Problem 3 with the real "
                             "model dimension; FLState.model_dim is unset — "
                             "build the state via setup()")
        eff_gain = state.a * float(np.sum(state.h * state.b))
        chan_key = jax.random.PRNGKey(cfg.seed + 2)
    hist: Dict[str, List] = {"round": [], "grad_norm_mean": [], "grad_norm_min": [],
                             "grad_norm_max": [], "eta": [], "eval_round": []}
    for t in range(state.round + 1, state.round + num_rounds + 1):
        if block_fading:
            h_np = np.asarray(chan.draw_channel(
                jax.random.fold_in(chan_key, t), cfg.channel), np.float64)
            if cfg.amplification == "optimal":
                sol = amp.solve_problem3(h_np, cfg.channel.noise_var,
                                         state.model_dim, cfg.channel.b_max,
                                         tol=1e-8)
                b_np = sol.b
            else:
                b_np = np.full(cfg.num_devices, cfg.channel.b_max)
            a = eff_gain / float(np.sum(h_np * b_np))
            h = jnp.asarray(h_np, jnp.float32)
            b = jnp.asarray(b_np, jnp.float32)
        batches = batch_provider(t)
        state.params, diag = round_step(state.params, batches, h, b,
                                        a, state.eta0, jnp.asarray(t), key)
        hist["round"].append(t)
        norms = np.asarray(diag["grad_norms"])
        hist["grad_norm_mean"].append(float(norms.mean()))
        hist["grad_norm_min"].append(float(norms.min()))
        hist["grad_norm_max"].append(float(norms.max()))
        hist["eta"].append(float(diag["eta"]))
        if eval_fn is not None and (t % eval_every == 0 or t == 1):
            metrics = eval_fn(state.params)
            for k, v in metrics.items():
                hist.setdefault(k, []).append(v)
            hist["eval_round"].append(t)
    state.round += num_rounds
    return state, hist
