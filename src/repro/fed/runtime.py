"""Federated-learning runtime (paper Sec. II, Steps 1-3, iterated).

The K devices are a ``jax.vmap`` axis; one round (local computation -> OTA
superposition -> server update -> broadcast) is a single jittable program.
``FLConfig.backend`` selects which execution backend the aggregation routes
through — ``vmap`` (pure XLA), ``kernels`` (fused Pallas path; the default
for benchmarks), or ``mesh`` (shard_map/psum over local devices; needs >= K
of them).  The production mesh train-step builder (devices = data shards of
a TPU mesh) lives in ``repro.launch.train``.

Beyond the paper's eq. 10-11 round, the round math carries three scenario
axes, all spec fields (no new positional arguments — the declarative
``repro.fl.ExperimentSpec`` facade is the intended front door):

``server_opt``      the server applies a pluggable ``optim.Optimizer`` to
                    the OTA-aggregated direction, its state threaded through
                    the scan carry (donated buffers).  ``'sgd'`` (default,
                    momentum 0) IS eq. 11, ``w <- w - eta_t y``, exactly.
``local_steps``     H > 1: each client takes H local SGD steps (FedAvg-style,
                    arXiv:2310.10089) and transmits the accumulated model
                    delta ``(w - w_k^H) / (H * local_lr)`` — an average local
                    gradient — through the unchanged scheme registry (the
                    ``normalized`` scheme then aggregates the *normalized*
                    accumulated delta).
``participation``   per-round Bernoulli or fixed-fraction device masks
                    (arXiv:2409.07822-style partial client participation),
                    folded into the superposition weights AND the eq.-8
                    energy accounting via ``ota.participation_fold`` — a
                    masked device transmits nothing and spends nothing.

The radio environment itself is an axis too (``repro.channels``, all fields
on ``FLConfig.channel``): the fading process comes from the channel-model
registry (``channel.model`` — i.i.d. Rayleigh, Rician, or time-correlated
AR(1) whose Gauss-Markov state threads the scan carry and ``FLState``),
per-device means from drawn cell geometry (``channel.geometry``), and
imperfect CSI (``channel.csi_error``) splits the TRUE ``h_t`` the air
superposes with from the server ESTIMATE ``h_hat_t`` on which Algorithm 1,
the receiver gain, the participation rescale, and the side-info folding
run; the effective-gain misalignment this induces is the per-round
``csi_gain_err`` diagnostic.  ``rho`` and ``csi_error`` are batchable sweep
lanes (``BATCHED_CHANNEL_FIELDS``); model/geometry/K-factor are structural.

Two round-loop drivers (``run(..., driver=...)``):

``scan``   (default) the compiled multi-round engine: ``jax.lax.scan`` over
           rounds, dispatched in chunks whose param buffers are donated and
           whose per-round history lands in on-device arrays transferred
           once per chunk.  Under block fading the channel redraw
           (``core.channel.channel_for_round``) AND the Problem-3
           re-optimization (``core.amplification.solve_problem3_jax``, a
           ``lax.while_loop`` bisection) run *inside* the scan — the whole
           trajectory is one XLA program per chunk, no host callbacks.
``python`` the host-loop fallback: one jitted round per dispatch, history
           appended eagerly.  Use it when an ``eval_fn`` must observe every
           round or for step-debugging; it computes the identical numbers
           (tests/test_engine.py holds the two drivers to fp32 parity on
           every backend, fixed and block-fading).

Beyond single experiments, ``run_batched`` vectorizes the scan engine over a
leading *experiment* axis: E structurally-identical configs (same scheme /
case / backend / scenario axes — see ``structural_config``) that differ only
in *batchable numerics* (seed, eta, s_target, grad_bound, noise_var,
channel_mean, b_max, ...) compile into ONE program via ``jax.vmap`` through
``_round_math`` — including channel redraws and the Problem-3 bisection
under block fading — and the experiment axis is sharded across local
devices (``distribution.sharding.experiment_mesh``) when a mesh is
available.  ``repro.fl.sweep`` is the declarative front door that expands a
grid, groups points by structural signature, and dispatches here.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import time
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import channels as chl
from repro.core import amplification as amp
from repro.core import channel as chan
from repro.core import ota
from repro.core import schemes
from repro.fl import clients as clientlib
from repro.obs import profiling as obsprof
from repro.optim import optimizers as optim

PyTree = Any
GradFn = Callable[[PyTree, Any], PyTree]   # (params, device_batch) -> grads

DRIVERS = ("scan", "python")
SERVER_OPTS = ("sgd", "adamw")
PARTICIPATION_MODES = ("bernoulli", "fixed")
# per-round scalar diagnostics recorded by BOTH drivers (same device-side
# math, so the drivers' histories agree exactly).  ``csi_gain_err`` is the
# relative misalignment of the realized effective gain a sum h_k b_k vs the
# one the server DESIGNED on its estimate, a sum h_hat_k b_k — exactly 0
# under perfect CSI, and the measurable cost of noisy/stale estimates.
DIAG_KEYS = ("grad_norm_mean", "grad_norm_min", "grad_norm_max", "eta",
             "update_norm", "tx_energy", "num_participants", "csi_gain_err")
# key-derivation salt separating the participation draw from the channel
# noise (both are folded from the same per-run key at the same round t)
_MASK_SALT = 0x5EED
# salt separating the CSI-estimation noise stream from the channel redraw
# (both fold from chan_key), and the geometry draw from the setup channel key
_CSI_SALT = 0xC51
_GEOM_SALT = 0x6E0
# salt separating the SECOND OTA transmission slot's channel-noise draw from
# the first's (multi-slot client algorithms, e.g. scaffold): slot 0 keeps
# the historical fold_in(key, t) BITWISE, slot 1 folds this salt on top —
# independent noise per slot, shared exactly by every backend and both the
# dense and streaming rounds
_SLOT_SALT = 0x510

# Compiled-executable cache size for the round/chunk builders below.  Large
# sweeps walk many (config, grad_fn) pairs; a too-small LRU silently evicts
# and re-traces mid-sweep, so the size is configurable without a code change
# (REPRO_ENGINE_CACHE_SIZE) and ``cache_info()`` exposes hit/miss/trace
# counters so benchmarks can assert zero re-traces.
ENGINE_CACHE_SIZE = int(os.environ.get("REPRO_ENGINE_CACHE_SIZE", "64"))

# incremented inside the traced bodies (tracing executes them; cached
# executions do not), so re-traces are observable even when they happen
# inside jax's own jit cache rather than the lru builders
TRACE_COUNTS: collections.Counter = collections.Counter()

# The documented, closed key set of ``TRACE_COUNTS`` — one key per cached
# builder in ``_CACHED_BUILDERS``.  Historically the chunk-scan counter key
# was a free-form string threaded through ``_make_chunk_scan``; normalizing
# to this enum-like set keeps the recorder's per-chunk re-trace attribution
# (and ``cache_info()['traces_delta']``) stable across refactors.
TRACE_KINDS = ("round_step", "run_chunk", "run_chunk_batched",
               "fading_refresh")

# per-kind counts at the last cache_info() call, for the delta report
_TRACE_SNAPSHOT: Dict[str, int] = {}


def _count_trace(kind: str) -> None:
    """Record one trace of a compiled builder body.  Runs at trace time
    (host-side, inside the traced function's Python execution); a key
    outside ``TRACE_KINDS`` is a programming error, not a new counter."""
    if kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {kind!r}; one of {TRACE_KINDS}")
    TRACE_COUNTS[kind] += 1


def trace_deltas(since: Dict[str, int]) -> Dict[str, int]:
    """Per-builder re-trace deltas vs a ``dict(TRACE_COUNTS)`` snapshot —
    the recorder's per-chunk retrace attribution."""
    return {k: int(TRACE_COUNTS[k]) - int(since.get(k, 0))
            for k in TRACE_KINDS}


def _engine_cache(fn):
    return functools.lru_cache(maxsize=ENGINE_CACHE_SIZE)(fn)


def cache_info() -> Dict[str, Any]:
    """Introspection for the compiled-executable caches: per-builder
    ``lru_cache`` statistics, cumulative trace counts (``TRACE_COUNTS``,
    keyed by ``TRACE_KINDS``), and ``traces_delta`` — the per-builder
    re-trace deltas since the previous ``cache_info()`` call (reset by
    ``clear_compile_caches``).  The sweep benchmark asserts the trace
    counters stay flat across repeated grid runs — i.e. zero re-traces once
    warm."""
    delta = trace_deltas(_TRACE_SNAPSHOT)
    _TRACE_SNAPSHOT.update({k: int(TRACE_COUNTS[k]) for k in TRACE_KINDS})
    return {
        "cache_size": ENGINE_CACHE_SIZE,
        "builders": {name: fn.cache_info()._asdict()
                     for name, fn in _CACHED_BUILDERS.items()},
        "traces": dict(TRACE_COUNTS),
        "traces_delta": delta,
    }


def clear_compile_caches() -> None:
    """Drop every cached builder (and its jitted executables) and reset the
    trace counters — test isolation / memory-pressure escape hatch."""
    for fn in _CACHED_BUILDERS.values():
        fn.cache_clear()
    TRACE_COUNTS.clear()
    _TRACE_SNAPSHOT.clear()


# FLConfig fields a batched (vmapped) run can vary per experiment: they are
# either consumed only by host-side ``setup`` (folded into the stacked
# h/b/a/eta0 inputs) or threaded through the compiled program as traced
# per-experiment scalars (``BatchAxes``).  Everything else — scheme, case,
# backend, schedule exponent, scenario axes — changes the traced program
# and is therefore *structural*: vary it across compiles, not lanes.
BATCHED_FL_FIELDS = ("seed", "eta", "s_target", "epsilon_target",
                     "grad_bound", "smoothness_L", "strong_convexity_M",
                     "expected_loss_drop", "theta_th")
BATCHED_CHANNEL_FIELDS = ("noise_var", "channel_mean", "b_max", "rho",
                          "csi_error")

# The structural complement: every FLConfig / ChannelConfig field must be
# claimed by exactly one of the BATCHED_* tables above or these tables
# (tracelint TL005 enforces the partition and that structural_config
# collapses precisely the batched lanes).  A new field that lands in neither
# is the "silently unbatched" bug: run_batched would accept configs that
# differ in it and fold them into one compiled program.
STRUCTURAL_FL_FIELDS = (
    "num_devices", "scheme", "backend", "case", "p", "channel",
    "amplification", "server_opt", "server_momentum", "server_b1",
    "server_b2", "server_eps", "server_weight_decay", "local_steps",
    "local_lr", "participation", "participation_mode", "k_block",
    "active_gather", "device_mesh", "client")
STRUCTURAL_CHANNEL_FIELDS = ("num_devices", "block_fading", "model",
                             "rician_k", "csi_error_model", "geometry")


class BatchAxes(NamedTuple):
    """Per-experiment traced scalars of a batched run (each field is [E] at
    the ``run_batched`` boundary and a scalar inside the vmapped body).
    ``None`` fields fall back to the baked ``FLConfig`` value — the
    single-experiment drivers pass ``over=None`` everywhere (geometry runs
    excepted: they thread their per-device [K] ``rayleigh_scale`` here), so
    default traces (and compiled executables) are untouched by the batching
    refactor."""

    noise_var: Optional[jax.Array] = None       # sigma^2 at the ES
    grad_bound: Optional[jax.Array] = None      # G (schemes that need it)
    b_max: Optional[jax.Array] = None           # per-device cap, time-varying
    rayleigh_scale: Optional[jax.Array] = None  # redraw scale: scalar or [K]
    rho: Optional[jax.Array] = None             # AR(1) per-round correlation
    csi_error: Optional[jax.Array] = None       # estimation-error magnitude
    client_mu: Optional[jax.Array] = None       # fedprox proximal strength
    client_alpha: Optional[jax.Array] = None    # feddyn regularization


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    backend: str = "vmap"             # 'vmap' | 'kernels' | 'mesh' (see core.ota)
    case: str = "I"                   # 'I' (eta_t = 1/t^p) or 'II' (constant eta)
    p: float = 0.75                   # Case-I schedule exponent (paper: 0.75)
    eta: float = 0.01                 # Case-II constant learning rate (paper: 0.01)
    theta_th: float = chan.DEFAULT_THETA_TH
    channel: chan.ChannelConfig = None
    seed: int = 0
    # amplification policy: 'optimal' (Algorithm 1 / Problem 3) or 'bmax'
    # (the no-optimization comparison of Fig. 1(a)/2(a): every b_k = b_k^max)
    amplification: str = "optimal"
    grad_bound: Optional[float] = None   # G, needed by benchmark1 + Case II
    # Case-II target: pick exactly one (s wins if both set)
    s_target: Optional[float] = None
    epsilon_target: Optional[float] = None
    # Case-I optimal-S inputs
    smoothness_L: float = 1.0
    strong_convexity_M: float = 1.0
    expected_loss_drop: float = 1.0
    # --- scenario axes (defaults reproduce the paper's round exactly) ------
    # server-side optimizer applied to the OTA-aggregated direction:
    # 'sgd' (momentum 0 == eq. 11) or 'adamw'
    server_opt: str = "sgd"
    server_momentum: float = 0.0
    server_b1: float = 0.9
    server_b2: float = 0.95
    server_eps: float = 1e-8
    server_weight_decay: float = 0.0
    # H local SGD steps per client per round; the transmitted quantity for
    # H > 1 is the accumulated model delta (w - w_k^H) / (H * local_lr)
    local_steps: int = 1
    local_lr: float = 0.01
    # expected participating fraction per round; 'bernoulli' masks each
    # device independently, 'fixed' schedules exactly round(p*K) devices
    participation: float = 1.0
    participation_mode: str = "bernoulli"
    # --- K-scale axes -------------------------------------------------------
    # Streaming round: compute gradients and fold them into the OTA
    # accumulator k_block devices at a time (lax.scan), so the round's
    # working set is O(k_block * N) instead of O(K * N).  None (default)
    # keeps the dense round bitwise-pinned.  Streaming == dense up to float
    # associativity of the blocked sums (tests/test_streaming.py).
    k_block: Optional[int] = None
    # Under fixed-mode partial participation, gather the scheduled
    # participants' batches BEFORE the local gradient computation, so
    # per-round compute scales with the active set m = round(p K), not K.
    # Bitwise-identical to the dense masked round (params, tx_energy,
    # num_participants); the grad-norm diagnostics then cover the
    # participants only (non-participants never compute a gradient).
    active_gather: bool = False
    # Sharded streaming (requires k_block): partition the round's K-blocks
    # over this many mesh shards.  The value DEFINES the hierarchical
    # accumulation order — each shard left-folds a contiguous run of
    # stream_length/k_block/device_mesh blocks, then ONE deterministic
    # cross-shard fold (``distribution.ota_collectives.fold_shards``) closes
    # eq. (10) — so the trajectory is a function of the config alone:
    # running on a physical mesh (``distribution.sharding.device_mesh``
    # finds the devices; shard_map) and the emulated single-device fallback
    # (outer lax.scan over shards) are BITWISE-identical, which is what lets
    # a checkpoint move between hosts with different device counts
    # (tests/test_sharded_streaming.py).  None (default) keeps the PR-6
    # flat left fold bitwise-pinned; device_mesh=D differs from it only by
    # the re-association of the blocked sums (documented-ulp, like
    # k_block itself vs dense).
    device_mesh: Optional[int] = None
    # --- client-algorithm axis (repro.fl.clients) --------------------------
    # what each device optimizes locally and transmits: 'sgd' (the paper's
    # round, bitwise-pinned default), 'fedprox', and the two-slot correctors
    # 'feddyn' / 'scaffold' (whose refreshed correction states ride a second
    # OTA slot to teach the server its state)
    client: clientlib.ClientConfig = None

    def __post_init__(self):
        if self.channel is None:
            object.__setattr__(self, "channel",
                               chan.ChannelConfig(num_devices=self.num_devices))
        if self.client is None:
            object.__setattr__(self, "client", clientlib.ClientConfig())
        alg = clientlib.get(self.client.algo)
        if alg.num_slots > 1:
            # the slot-2 scheme must exist AND be channel-borne: a baseline
            # (channel-bypassing) scheme has no superposition to de-gain
            if schemes.get(self.client.variate_scheme).baseline:
                raise ValueError(
                    f"variate_scheme {self.client.variate_scheme!r} is a "
                    "baseline (channel-bypassing) scheme; the second OTA "
                    "slot is a genuine transmission")
        if self.backend not in ota.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {ota.BACKENDS}")
        schemes.get(self.scheme)   # raises ValueError naming the registry
        if self.case not in ("I", "II"):
            raise ValueError(f"unknown case {self.case!r}; one of ('I', 'II')")
        if self.amplification not in ("optimal", "bmax"):
            raise ValueError(f"unknown amplification {self.amplification!r}; "
                             "one of ('optimal', 'bmax')")
        if self.server_opt not in SERVER_OPTS:
            raise ValueError(f"unknown server_opt {self.server_opt!r}; "
                             f"one of {SERVER_OPTS}")
        if self.local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {self.local_steps}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must lie in (0, 1], got "
                             f"{self.participation}")
        if self.participation_mode not in PARTICIPATION_MODES:
            raise ValueError(
                f"unknown participation_mode {self.participation_mode!r}; "
                f"one of {PARTICIPATION_MODES}")
        if self.active_gather:
            if self.participation_mode != "fixed":
                raise ValueError(
                    "active_gather needs a static active-set size: use "
                    "participation_mode='fixed' (bernoulli draws a random "
                    "count per round)")
            if self.participation >= 1.0:
                raise ValueError(
                    "active_gather requires participation < 1 (at p = 1 the "
                    "gather is a random permutation that reorders the K-way "
                    "sum; the dense path is the right tool)")
        if self.k_block is not None:
            if self.k_block < 1:
                raise ValueError(f"k_block must be >= 1, got {self.k_block}")
            if self.backend == "mesh":
                raise ValueError("the mesh backend's device axis IS the mesh "
                                 "— k_block streaming applies to the stacked "
                                 "(vmap/kernels) backends; to parallelize a "
                                 "streamed round over local devices use "
                                 "device_mesh (the sharded streaming engine)")
            s = self.stream_length()
            if s % min(self.k_block, s) != 0:
                raise ValueError(
                    f"k_block {self.k_block} must divide the streamed device "
                    f"axis ({s} = {'the active set' if self.active_gather else 'num_devices'})")
        if self.device_mesh is not None:
            if self.device_mesh < 1:
                raise ValueError(
                    f"device_mesh must be >= 1, got {self.device_mesh}")
            if self.k_block is None:
                raise ValueError(
                    "device_mesh shards the K-block stream — set k_block "
                    "(the dense round has no block axis to partition)")
            s = self.stream_length()
            nb = s // min(self.k_block, s)
            if nb % self.device_mesh != 0:
                raise ValueError(
                    f"device_mesh {self.device_mesh} must divide the "
                    f"stream's block count {nb} (= streamed axis {s} / "
                    f"k_block {min(self.k_block, s)}) — pick a k_block so "
                    "the block count is a multiple of the mesh size")

    def stream_length(self) -> int:
        """Length of the streamed device axis: the fixed active-set size
        ``round(p K)`` under ``active_gather``, else the full cohort K."""
        if self.active_gather:
            return max(1, int(round(self.participation * self.num_devices)))
        return self.num_devices


def structural_config(cfg: FLConfig) -> FLConfig:
    """The *structural signature* of a config: every batchable numeric field
    (``BATCHED_FL_FIELDS`` / ``BATCHED_CHANNEL_FIELDS``) collapsed to a fixed
    sentinel.  Two configs are batchable into one compiled program iff their
    structural signatures are equal; the batched chunk builder is cached on
    this signature, so every sub-batch of a sweep that shares a structure
    shares one executable.  ``grad_bound`` keeps its None-ness (present vs
    absent changes the traced program), not its value."""
    channel = dataclasses.replace(cfg.channel, noise_var=0.0,
                                  channel_mean=1.0, b_max=1.0, rho=0.0,
                                  csi_error=0.0)
    client = dataclasses.replace(cfg.client, mu=0.0, alpha=0.01)
    return dataclasses.replace(
        cfg, seed=0, eta=0.01, s_target=None, epsilon_target=None,
        grad_bound=None if cfg.grad_bound is None else 1.0,
        smoothness_L=1.0, strong_convexity_M=1.0, expected_loss_drop=1.0,
        theta_th=chan.DEFAULT_THETA_TH, channel=channel, client=client)


@dataclasses.dataclass
class FLState:
    params: PyTree
    h: np.ndarray
    b: np.ndarray
    a: float
    eta0: float                       # eta for case II; eta_t = eta0/t^p for case I
    round: int = 0
    # the real model dimension N, recorded at setup() time so block-fading
    # re-optimization solves Problem 3 with the true n (not a placeholder)
    model_dim: int = 0
    # server-side optimizer state (initialized lazily by run() for states
    # built before the server_opt axis existed); step counts rounds, so
    # Adam bias correction stays consistent across resumed runs
    opt_state: Optional[optim.OptState] = None
    # the server's channel ESTIMATE h_hat (imperfect CSI; None — states from
    # before the wireless-environment subsystem — means perfect CSI: h)
    h_hat: Optional[np.ndarray] = None
    # persistent fading-process state ([K, 2] Gauss-Markov I/Q pair for the
    # 'ar1' model; None for stateless models) — threads the scan carry and
    # checkpoints so run(5); run(5) continues run(10)'s correlated channel
    fad_state: Optional[np.ndarray] = None
    # per-device amplitude scales from the geometry subsystem ([K]; None
    # keeps the homogeneous scalar ChannelConfig.amplitude_scale())
    scale: Optional[np.ndarray] = None
    # client-algorithm state (repro.fl.clients): {"dev": [K, ...] stacked
    # per-client pytree or None, "srv": param-shaped server pytree or None};
    # None for stateless algorithms (sgd/fedprox) — the pre-registry carry
    # and checkpoint layout, bitwise.  Initialized lazily by run() for
    # states built before the client-algorithm axis existed.
    client_state: Optional[Dict[str, Any]] = None


def server_optimizer(cfg: FLConfig) -> optim.Optimizer:
    """The pluggable server-side optimizer of ``cfg.server_opt``.  The
    learning rate is always passed per-call (the paper's eta_t schedules live
    in ``_eta_t``), so the constructor lr is a placeholder."""
    if cfg.server_opt == "adamw":
        return optim.adamw(0.0, b1=cfg.server_b1, b2=cfg.server_b2,
                           eps=cfg.server_eps,
                           weight_decay=cfg.server_weight_decay)
    return optim.sgd(0.0, momentum=cfg.server_momentum)


def _setup_channel(cfg: FLConfig):
    """Host-side round-0 radio environment: per-device amplitude scales
    (geometry), the model's initial draw (+ fading state), and the server's
    CSI estimate ``h_hat``.  Returns ``(h, h_hat, fad_state, scale_vec)``
    with ``h``/``h_hat`` float64 [K]; ``h_hat`` IS ``h`` (same array) under
    perfect CSI, so the default path is bitwise-unchanged."""
    key = jax.random.PRNGKey(cfg.seed)
    ccfg = cfg.channel
    model = chl.get(ccfg.model)
    scale = ccfg.amplitude_scale()
    scale_vec = None
    if ccfg.geometry is not None:
        rel = chl.relative_gains(jax.random.fold_in(key, _GEOM_SALT),
                                 ccfg.geometry, cfg.num_devices)
        scale_vec = np.asarray(scale * rel, np.float64)
        scale = jnp.asarray(scale_vec, jnp.float32)
    h_jax, fad0 = model.init(ccfg, scale, key)
    h = np.asarray(h_jax, np.float64)
    fad_state = None if fad0 is None else np.asarray(fad0, np.float64)
    h_hat = h
    if ccfg.csi_error > 0.0:
        h_hat = np.asarray(chl.estimate(
            jnp.asarray(h, jnp.float32),
            jax.random.fold_in(key, _CSI_SALT), ccfg.csi_error, scale,
            ccfg.csi_error_model), np.float64)
    return h, h_hat, fad_state, scale_vec


def setup(cfg: FLConfig, params0: PyTree, model_dim: int) -> FLState:
    """Draw the radio environment and run the paper's parameter
    optimization.  Algorithm 1 (and the receiver-gain calibration) runs on
    the server's estimate ``h_hat`` — what the server can actually know —
    which is ``h`` itself under perfect CSI (``csi_error = 0``)."""
    h, h_hat, fad_state, scale_vec = _setup_channel(cfg)
    b_max = np.full(cfg.num_devices, cfg.channel.b_max)
    extra = dict(model_dim=model_dim, h_hat=h_hat, fad_state=fad_state,
                 scale=scale_vec,
                 client_state=clientlib.init_state(cfg.client, params0,
                                                   cfg.num_devices))

    if cfg.amplification == "bmax":
        b = b_max.copy()
        # comparison method of Fig. 1(a): same a * sum(h_hat b) as the
        # optimized run
        sol = amp.solve_problem3(h_hat, cfg.channel.noise_var, model_dim,
                                 b_max)
        if cfg.case == "I":
            s_opt = amp.optimal_S(sol.Z, cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
            a = 1.0 / (s_opt * float(np.sum(h_hat * sol.b)))
            a = a * float(np.sum(h_hat * sol.b)) / float(np.sum(h_hat * b))
            eta0 = 1.0
        else:
            c2 = amp.optimize_case2(h_hat, cfg.channel.noise_var, model_dim,
                                    b_max,
                                    cfg.smoothness_L, cfg.strong_convexity_M,
                                    cfg.grad_bound, cfg.theta_th,
                                    s=cfg.s_target, epsilon=cfg.epsilon_target)
            a_eta = c2.a_eta * float(np.sum(h_hat * c2.b)) / float(np.sum(h_hat * b))
            a, eta0 = a_eta / cfg.eta, cfg.eta
        return FLState(params0, h, b, a, eta0, **extra)

    if cfg.case == "I":
        c1 = amp.optimize_case1(h_hat, cfg.channel.noise_var, model_dim,
                                b_max,
                                cfg.smoothness_L, cfg.p, cfg.expected_loss_drop)
        return FLState(params0, h, c1.b, c1.a, 1.0, **extra)
    c2 = amp.optimize_case2(h_hat, cfg.channel.noise_var, model_dim, b_max,
                            cfg.smoothness_L, cfg.strong_convexity_M,
                            cfg.grad_bound, cfg.theta_th,
                            s=cfg.s_target, epsilon=cfg.epsilon_target)
    return FLState(params0, h, c2.b, c2.a_eta / cfg.eta, cfg.eta, **extra)


def _eta_t(cfg: FLConfig, eta0, t: jax.Array) -> jax.Array:
    if cfg.case == "I":
        return eta0 / jnp.maximum(t.astype(jnp.float32), 1.0) ** cfg.p
    return jnp.asarray(eta0, jnp.float32)


def _participation_mask(cfg: FLConfig, key, t) -> jax.Array:
    """[K] 0/1 per-round participation draw.  ``bernoulli`` masks each device
    independently with probability p; ``fixed`` schedules exactly
    ``round(p * K)`` devices uniformly at random."""
    mk = jax.random.fold_in(jax.random.fold_in(key, t), _MASK_SALT)
    k = cfg.num_devices
    if cfg.participation_mode == "bernoulli":
        return jax.random.bernoulli(mk, cfg.participation, (k,)).astype(
            jnp.float32)
    m = max(1, int(round(cfg.participation * k)))
    perm = jax.random.permutation(mk, k)
    return jnp.zeros((k,), jnp.float32).at[perm[:m]].set(1.0)


def _participation_mask_block(cfg: FLConfig, key, t, lo: int,
                              hi: int) -> jax.Array:
    """Lazy per-K-block participation draw for ``bernoulli`` mode: device
    ``i``'s coin folds from its own index, so any blocking of ``[0, K)``
    concatenates to the same mask — the 100k+-device path never materializes
    a [K] draw it won't use this block.  ``fixed`` mode needs the global
    permutation and has no lazy form (use ``active_gather`` there)."""
    if cfg.participation_mode != "bernoulli":
        raise ValueError("lazy per-block participation draws exist for "
                         "'bernoulli' only ('fixed' draws one global "
                         "permutation)")
    mk = jax.random.fold_in(jax.random.fold_in(key, t), _MASK_SALT)
    keys = jax.vmap(lambda i: jax.random.fold_in(mk, i))(jnp.arange(lo, hi))
    u = jax.vmap(lambda k_: jax.random.uniform(k_, ()))(keys)
    return (u < cfg.participation).astype(jnp.float32)


def _active_indices(cfg: FLConfig, key, t) -> jax.Array:
    """Sorted [m] indices of the round's fixed-mode participant set — the
    SAME permutation draw as ``_participation_mask``, so ``mask[idx] == 1``
    by construction, and ascending order keeps the gathered K-way sums in
    the dense path's reduction order (the bitwise-parity contract)."""
    mk = jax.random.fold_in(jax.random.fold_in(key, t), _MASK_SALT)
    m = max(1, int(round(cfg.participation * cfg.num_devices)))
    perm = jax.random.permutation(mk, cfg.num_devices)
    return jnp.sort(perm[:m])


# fences promoted to core.ota (the OTA-level sharded streaming path needs
# them too); the runtime names stay as aliases for their existing call sites
_fence_leaf = ota.fence_leaf
_fusion_fence = ota.fusion_fence


def _local_transmit(cfg: FLConfig, grad_fn: GradFn, params, batch,
                    corr=None, dev_state=None) -> PyTree:
    """The quantity each device hands to the scheme's transform: its local
    gradient for ``local_steps == 1`` (the paper), else the accumulated model
    delta of H local SGD steps, ``(w - w_k^H) / (H * local_lr)`` — the average
    local gradient along the trajectory, so its magnitude is comparable to a
    single gradient and ``grad_bound``-based schemes stay calibrated.

    ``corr(p, g, dev_state_k)`` is the client algorithm's local-objective
    correction (``repro.fl.clients``), applied to EVERY local gradient along
    the H-step trajectory; ``dev_state`` the stacked per-device state it
    reads (vmapped alongside the batch).  ``corr=None`` — the ``sgd``
    default — takes the pre-registry code path verbatim (bitwise)."""
    if corr is None:
        if cfg.local_steps == 1:
            return jax.vmap(lambda db: grad_fn(params, db))(batch)

        def one_device(db):
            def step(p, _):
                g = grad_fn(p, db)
                return jax.tree_util.tree_map(
                    lambda w, gg: w - jnp.asarray(cfg.local_lr, w.dtype)
                    * gg.astype(w.dtype), p, g), None

            p_h, _ = jax.lax.scan(step, params, None, length=cfg.local_steps)
            inv = 1.0 / (cfg.local_steps * cfg.local_lr)
            return jax.tree_util.tree_map(
                lambda w0, wh: (w0 - wh) * jnp.asarray(inv, w0.dtype),
                params, p_h)

        return jax.vmap(one_device)(batch)

    def local_grad(p, db, ds):
        return corr(p, grad_fn(p, db), ds)

    if cfg.local_steps == 1:
        if dev_state is None:
            return jax.vmap(lambda db: local_grad(params, db, None))(batch)
        return jax.vmap(lambda db, ds: local_grad(params, db, ds))(
            batch, dev_state)

    def one_device_corr(db, ds):
        def step(p, _):
            g = local_grad(p, db, ds)
            return jax.tree_util.tree_map(
                lambda w, gg: w - jnp.asarray(cfg.local_lr, w.dtype)
                * gg.astype(w.dtype), p, g), None

        p_h, _ = jax.lax.scan(step, params, None, length=cfg.local_steps)
        inv = 1.0 / (cfg.local_steps * cfg.local_lr)
        return jax.tree_util.tree_map(
            lambda w0, wh: (w0 - wh) * jnp.asarray(inv, w0.dtype),
            params, p_h)

    if dev_state is None:
        return jax.vmap(lambda db: one_device_corr(db, None))(batch)
    return jax.vmap(one_device_corr)(batch, dev_state)


def _round_math(cfg: FLConfig, sch, opt, grad_fn: GradFn, params, opt_state,
                batch, h, h_hat, b, a, eta0, t, key,
                over: Optional[BatchAxes] = None, client_state=None):
    """One FL round (local computation -> OTA aggregate(s) -> server
    optimizer step) plus the scalar diagnostics of ``DIAG_KEYS``.  Pure;
    traced identically by both drivers.  ``over`` carries the per-experiment
    traced scalars of a batched run (None — the single-experiment default —
    bakes the ``cfg`` values into the trace exactly as before).

    ``client_state`` is the client algorithm's state
    (``{"dev": [K, ...], "srv": ...}``, see ``repro.fl.clients``; None for
    stateless algorithms), threaded through the round alongside params:
    returns ``(params, opt_state, client_state, diag)``.  A multi-slot
    algorithm (scaffold) runs a SECOND OTA transmission after the gradient
    slot — its own normalization scheme, the same channel realization, an
    independent noise key (``_SLOT_SALT``), and its eq.-8 energy added to
    ``tx_energy``.

    ``h`` is the TRUE channel (the air superposes with it); ``h_hat`` the
    server's estimate — the participation rescale and the server-side
    post-transform run on ``h_hat`` (the server cannot know ``h``).  Under
    perfect CSI the caller passes ``h_hat=None``: the estimate aliases the
    SAME traced value as ``h``, so every CSI term collapses exactly (the
    ``csi_gain_err`` diagnostic is a hard 0, not a lowering residual)."""
    if h_hat is None:
        h_hat = h
    noise_var = cfg.channel.noise_var
    grad_bound = cfg.grad_bound
    if over is not None:
        if over.noise_var is not None:
            noise_var = over.noise_var
        if over.grad_bound is not None:
            grad_bound = over.grad_bound
    alg = clientlib.get(cfg.client.algo)
    cp = clientlib.resolve_params(
        cfg.client,
        over.client_mu if over is not None else None,
        over.client_alpha if over is not None else None)
    dev_state = client_state["dev"] if client_state is not None else None
    srv_state = client_state["srv"] if client_state is not None else None
    corr = None
    if alg.correction is not None:
        # w_round = params (the round's broadcast model); the closure is
        # traced inside the device vmap, p being the device-local weights
        corr = lambda p, g, ds: alg.correction(cp, p, params, ds, srv_state, g)
    if cfg.participation < 1.0:
        mask = _participation_mask(cfg, key, t)
        b_eff, a_eff = ota.participation_fold(h_hat, b, a, mask)
    else:
        mask = None
        b_eff, a_eff = b, a
    if cfg.active_gather:
        # fixed-mode active set: gather the scheduled participants' batches
        # BEFORE the local computation so gradient compute scales with
        # m = round(p K), then scatter the m gradients back into a zero
        # [K, ...] stack and run the UNCHANGED dense aggregation.  A masked
        # device's superposition / side-info / energy weight is an exact
        # zero either way (b_eff = 0, and 0 * x == 0 * 0 in every K-way
        # reduction term), so the round is bitwise the dense masked round —
        # the participants are just the only devices that ever run grad_fn.
        idx = _active_indices(cfg, key, t)  # tracelint: disable=TL002 mask and active-set draws fold in distinct salts inside the helpers; streams are disjoint by construction
        dev_active = (None if dev_state is None else
                      jax.tree_util.tree_map(lambda l: l[idx], dev_state))
        active = _local_transmit(
            cfg, grad_fn, params,
            jax.tree_util.tree_map(lambda l: l[idx], batch),
            corr, dev_active)
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.zeros((cfg.num_devices,) + l.shape[1:],
                                l.dtype).at[idx].set(l), active)
        b_air = b_eff[idx]
    else:
        idx = None
        dev_active = dev_state
        active = stacked = _local_transmit(cfg, grad_fn, params, batch,
                                           corr, dev_active)
        b_air = b_eff
    if mask is not None:
        # fence the gradient stack so the aggregation below consumes a
        # materialized [K, ...] value: without it XLA fuses the aggregate's
        # K-way reductions into the (round-shape-dependent) gradient
        # producer, and the dense-masked and active-gather programs — whose
        # reduction TERMS are identical, masked devices contributing exact
        # zeros — would associate them differently, breaking the bitwise
        # gather contract.  Full-participation rounds (the golden-pinned
        # default) never take this branch.
        stacked = _fusion_fence(stacked)
    if mask is not None and sch.baseline:
        # baseline schemes bypass the channel (plain mean on every backend),
        # so the mask cannot reach them through b_eff — average over the
        # participants only, or the ideal reference would silently use all K
        # devices while the diagnostics report a partial cohort
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        y = jax.tree_util.tree_map(
            lambda l: jnp.tensordot(w, l.astype(jnp.float32), axes=(0, 0)),
            stacked)
    else:
        ocfg = ota.OTAConfig(scheme=cfg.scheme, a=a_eff,
                             noise_var=noise_var,
                             grad_bound=grad_bound, backend=cfg.backend)
        y = ota.aggregate(ocfg, stacked, h, b_eff,
                          jax.random.fold_in(key, t), h_hat=h_hat)
    # one stats pass feeds BOTH diagnostics (grad norms and the eq. 8
    # transmit-energy accounting); the aggregate above keeps its own internal
    # stats — folding the two would need aggregate() to return them.  Under
    # active_gather the stats cover the participants only (the scattered
    # zero rows are channel inputs, not computed gradients): the grad-norm
    # diagnostics shrink to the active set, while tx_energy is unchanged
    # (masked devices spent nothing — their dense energy terms were b_k = 0)
    stats = schemes.compute_stats(active, sch, batched=True)
    norms = jnp.sqrt(stats.sq_norm)
    tx = schemes.transmit_energy(sch, stats, b_air, grad_bound,
                                 None if idx is not None else mask)
    if idx is not None:
        # scatter the active set's energies back to the [K] layout (masked
        # devices spent exactly 0) and fence, so the eq.-8 total below runs
        # the same [K]-way sum as the dense masked round (per-device
        # energies can still carry ulp noise: [m]-row reductions vectorize
        # differently than [K]-row ones)
        tx = jnp.zeros((cfg.num_devices,), tx.dtype).at[idx].set(tx)
    if mask is not None:
        tx = _fusion_fence(tx)
    # total transmit energy sum_k b_k^2 ||x_k||^2 (eq. 8 budget) via the
    # scheme's analytic accounting; masked-out devices spend nothing.  A
    # second OTA slot adds its own eq.-8 term below.
    tx_energy = jnp.sum(tx)

    new_client_state = client_state
    if alg.stateful:
        tmap = jax.tree_util.tree_map
        hlr = cfg.local_steps * cfg.local_lr
        dev_new = dev_state
        dev_new_active = dev_active
        if alg.has_state:
            # the state transition sees the round's transmitted statistic
            # (``active``: grad for H = 1, the accumulated delta otherwise)
            dev_new_active = alg.update_state(cp, hlr, dev_active, srv_state,
                                              active)
            if idx is not None:
                dev_new = tmap(lambda full, act: full.at[idx].set(act),
                               dev_state, dev_new_active)
            elif mask is not None:
                # a masked device did not participate: its state must not
                # move (the raw transition still feeds slot 2 below, where
                # b_eff = 0 already silences the masked rows)
                keep = mask.astype(bool)
                dev_new = tmap(
                    lambda new, old: jnp.where(
                        keep.reshape((-1,) + (1,) * (new.ndim - 1)),
                        new, old), dev_new_active, dev_state)
            else:
                dev_new = dev_new_active
        srv_new = srv_state
        if alg.num_slots == 2:
            # ---- the second OTA transmission slot -------------------------
            # same channel realization h/b_eff/a_eff (the slots are
            # consecutive symbols of one coherence block), its own
            # normalization scheme, an independent noise draw, and its own
            # eq.-8 energy.  The server learns its state from the DE-GAINED
            # aggregate: y2 / (a sum h_hat b) is approximately the
            # participant-mean transmitted statistic.
            sch2 = schemes.get(cfg.client.variate_scheme)
            x2_active = alg.variate_stat(cp, dev_active, dev_new_active,
                                         srv_state, active)
            if idx is not None:
                x2 = tmap(lambda l: jnp.zeros(
                    (cfg.num_devices,) + l.shape[1:], l.dtype).at[idx].set(l),
                    x2_active)
            else:
                x2 = x2_active
            if mask is not None:
                x2 = _fusion_fence(x2)
            stats2 = schemes.compute_stats(x2_active, sch2, batched=True)
            tx2 = schemes.transmit_energy(sch2, stats2, b_air, grad_bound,
                                          None if idx is not None else mask)
            if idx is not None:
                tx2 = jnp.zeros((cfg.num_devices,), tx2.dtype).at[idx].set(tx2)
            if mask is not None:
                tx2 = _fusion_fence(tx2)
            tx_energy = tx_energy + jnp.sum(tx2)
            ocfg2 = ota.OTAConfig(scheme=cfg.client.variate_scheme, a=a_eff,
                                  noise_var=noise_var,
                                  grad_bound=grad_bound, backend=cfg.backend)
            key2 = jax.random.fold_in(jax.random.fold_in(key, t), _SLOT_SALT)
            y2 = ota.aggregate(ocfg2, x2, h, b_eff, key2, h_hat=h_hat)
            gain = a_eff * jnp.sum(h_hat * b_eff)
            y2_hat = tmap(lambda l: l / jnp.maximum(gain, schemes.EPS), y2)
            # |participants|/K scales the server variate step (SCAFFOLD's
            # m/K); an empty round has gain = 0 AND frac = 0 — srv holds
            frac = (jnp.sum(mask) / cfg.num_devices if mask is not None
                    else jnp.asarray(1.0, jnp.float32))
            srv_new = alg.apply_variate(cp, srv_state, y2_hat, frac)
        new_client_state = {"dev": dev_new, "srv": srv_new}

    diag_core = {
        "grad_norm_mean": jnp.mean(norms),
        "grad_norm_min": jnp.min(norms),
        "grad_norm_max": jnp.max(norms),
        "tx_energy": tx_energy,
    }
    new_params, new_opt_state, diag = _round_tail(
        cfg, sch, opt, params, opt_state, y, mask, eta0, t, diag_core, a_eff,
        h, h_hat, b_eff)
    return new_params, new_opt_state, new_client_state, diag


def _round_tail(cfg, sch, opt, params, opt_state, y, mask, eta0, t,
                diag_core, a_eff, h, h_hat, b_eff):
    """Post-aggregation tail shared by the dense and streaming rounds:
    empty-round gating, the server-optimizer step, and the ``DIAG_KEYS``
    assembly.  ``diag_core`` carries the grad-norm/energy numbers, which the
    two rounds compute differently (one dense stats pass vs a blocked
    running reduction); everything here sees only full-[K] channel vectors
    and the round's update direction, so it is layout-agnostic."""
    if mask is not None:
        # an empty round (possible under bernoulli draws) applies no update:
        # participation_fold zeroed the gain, but server_post schemes can
        # re-shift y, so the update direction is gated too
        any_part = (jnp.sum(mask) > 0).astype(jnp.float32)
        y = jax.tree_util.tree_map(
            lambda l: l * any_part.astype(l.dtype), y)
    eta = _eta_t(cfg, eta0, t)
    new_params, new_opt_state = opt.update(y, opt_state, params, lr=eta)
    if mask is not None:
        # ...and so is the state transition itself: a stateful server
        # optimizer (momentum / adam moments, even weight decay) must not
        # move the model or its moments on a round nobody transmitted in
        keep = jnp.sum(mask) > 0
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(keep, n, o), new_params, params)
        new_opt_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(keep, n, o), new_opt_state, opt_state)
    # sharded rounds pin the real-valued [K]/[N] reductions below so the
    # diagnostics stay bitwise across the shard_map / emulated programs
    # (see _round_math_streaming); mask sums are 0/1-exact and stay plain
    ksum = (ota.pinned_sum
            if cfg.device_mesh is not None and cfg.device_mesh > 1
            else jnp.sum)
    if sch.baseline:
        # the ideal reference bypasses the channel; no gain to misalign
        csi_gain_err = jnp.zeros((), jnp.float32)
    else:
        # relative effective-gain misalignment: the air realizes
        # a sum h_k b_k, the server designed a sum h_hat_k b_k.  Computed
        # through the DIFFERENCE (h - h_hat) so equal estimates give a hard
        # 0 (two independently-lowered sums would leave an ulp residual)
        designed = a_eff * ksum(h_hat * b_eff)
        gap = a_eff * ksum((h - h_hat) * b_eff)
        csi_gain_err = (gap / jnp.maximum(jnp.abs(designed),
                                          schemes.EPS)).astype(jnp.float32)
    diag = {
        **diag_core,
        "eta": eta,
        "update_norm": jnp.sqrt(sum(ksum(jnp.square(l))
                                    for l in jax.tree_util.tree_leaves(y))),
        "num_participants": (jnp.sum(mask) if mask is not None
                             else jnp.asarray(float(cfg.num_devices),
                                              jnp.float32)),
        "csi_gain_err": csi_gain_err,
    }
    return new_params, new_opt_state, diag


def _combine_shard_carries(stacked):
    """Close the sharded streaming round: fold D stacked per-shard scan
    carries ``(ota_carry, norm_sum, norm_min, norm_max, tx_sum[, ota_carry_2])``
    into one.  Every accumulator field reduces through the deterministic
    left fold of ``distribution.ota_collectives.fold_shards`` — the ONE
    combine both execution paths (shard_map and the emulated outer scan)
    share, which is what makes them bitwise-identical; the min/max
    diagnostics fold with their own (order-free) ops on the same path."""
    from repro.distribution import ota_collectives as coll
    out = (coll.fold_shards(stacked[0]),
           coll.fold_shards(stacked[1]),
           coll.fold_shards(stacked[2], jax.lax.min),
           coll.fold_shards(stacked[3], jax.lax.max),
           coll.fold_shards(stacked[4]))
    if len(stacked) > 5:
        out = out + (coll.fold_shards(stacked[5]),)
    return out


def _scan_stream_blocks(cfg: FLConfig, body, carry0, xs):
    """Drive the streaming round's block scan, sharded when
    ``cfg.device_mesh`` asks for it.

    Plain (``device_mesh`` None/1): one ``lax.scan`` over all nb blocks —
    the PR-6 flat left fold, bitwise-pinned.

    Sharded (``device_mesh = D``): the [nb, k_block, ...] xs leaves become
    [D, nb/D, k_block, ...]; each shard left-folds its contiguous run of
    blocks from the same zero carry, and ``_combine_shard_carries`` closes
    the round.  On a physical mesh the per-shard folds run SPMD under
    ``shard_map`` (params and the other closed-over round state replicate;
    the xs split; ONE ``all_gather`` of the partial carries is the round's
    only cross-shard collective); otherwise an outer ``lax.scan`` emulates
    the shards.  Both paths run the SAME blocking and the SAME combine, so
    the choice is invisible in the trajectory (bitwise).

    Returns ``(combined_carry, ys)`` with ``ys`` in the flat [nb, ...]
    block order either way."""
    if cfg.device_mesh is None or cfg.device_mesh <= 1:
        return jax.lax.scan(body, carry0, xs)
    from jax.sharding import PartitionSpec as P

    from repro.distribution import ota_collectives as coll
    from repro.distribution import sharding as shardlib

    d = cfg.device_mesh
    tmap = jax.tree_util.tree_map
    xs_sh = tmap(lambda l: l.reshape((d, l.shape[0] // d) + l.shape[1:]), xs)

    def shard_fold(xs_shard):
        return jax.lax.scan(body, carry0, xs_shard)

    mesh = shardlib.device_mesh(d)
    if mesh is None:
        _, (stacked, ys_sh) = jax.lax.scan(
            lambda _, xs_s: (None, shard_fold(xs_s)), None, xs_sh)
        ys = tmap(lambda l: l.reshape((l.shape[0] * l.shape[1],)
                                      + l.shape[2:]), ys_sh)
    else:
        axis = shardlib.FL_DEVICE_AXIS

        def per_shard(xs_s):
            local = tmap(lambda l: l[0], xs_s)
            carry, ys_local = shard_fold(local)
            return coll.gather_shards(carry, axis), ys_local

        # replicated constraints at BOTH shard_map boundaries: without them
        # GSPMD propagates the manual axis sharding backward into the xs
        # producers and forward through ys into the next round's carry, and
        # the surrounding round math (channel refresh, Problem-3 solve,
        # _round_tail) compiles 4-way-partitioned in the physical program
        # only — which drifts from the emulated program by ulps.  The
        # constraints change placement, never values.
        rep = jax.sharding.NamedSharding(mesh, P())
        xs_sh = tmap(lambda l: jax.lax.with_sharding_constraint(l, rep),
                     xs_sh)
        stacked, ys = jax.shard_map(
            per_shard, mesh=mesh,
            in_specs=(tmap(lambda _: P(axis), xs_sh),),
            out_specs=(P(), P(axis)),
            axis_names={axis}, check_vma=False)(xs_sh)
        stacked = tmap(lambda l: jax.lax.with_sharding_constraint(l, rep),
                       stacked)
        ys = tmap(lambda l: jax.lax.with_sharding_constraint(l, rep), ys)
    # fence the combined carry: the consumers (streaming_finish, the
    # server-state fold) must compile independently of whether the partials
    # arrived through shard_map or the emulated scan, or their producer
    # fusion drifts by ulps between the two paths
    return _fusion_fence(_combine_shard_carries(stacked)), ys


def _round_math_streaming(cfg: FLConfig, sch, opt, grad_fn: GradFn, params,
                          opt_state, batch, h, h_hat, b, a, eta0, t, key,
                          over: Optional[BatchAxes] = None,
                          block_batch_fn=None, client_state=None):
    """The flat-memory round (``cfg.k_block``): local gradients are computed
    and folded into the OTA accumulator ``k_block`` devices at a time through
    the streaming carry API (``ota.streaming_carry/_block/_finish``) inside a
    ``lax.scan`` over K-blocks — the [K, ...] transmit stack never exists, so
    the round's working set is O(k_block * N) plus O(K) channel vectors.

    ``batch`` is the dense per-device batch pytree over the streamed axis
    (the active set under ``active_gather``, else all K), or ``None`` — then
    ``block_batch_fn(t, dev_idx)`` materializes one block's [k_block, ...]
    batches from its [k_block] device indices, the 100k-device path where
    even a round's batch stack would not fit.

    Parity with the dense round: every per-device term (grad, scale, energy)
    is computed identically; the K-way sums re-associate into block partials
    (documented-ulp, tests/test_streaming.py), the channel-noise draw is
    bitwise-shared, and grad_norm_min/max are exact (min/max associate).

    ``client_state`` threads the client algorithm's state exactly like the
    dense round (returns a 4-tuple): the per-device ``[K, ...]`` stack rides
    the block scan's ``xs`` (its working set is O(k_block * N) per leaf),
    updated states come back as the scan's per-block outputs, and a second
    OTA slot folds into its OWN streaming accumulator alongside slot 1's.

    ``cfg.device_mesh`` partitions the block scan over mesh shards
    (``_scan_stream_blocks``): params/opt/server-state replicate, the
    blocked channel / participation / ``active_gather`` index vectors (and
    the slot-2 client-state stacks) shard with the blocks, and both OTA
    slots' accumulators close through one deterministic cross-shard fold
    before ``streaming_finish`` draws the (bitwise-shared) noise once."""
    if h_hat is None:
        h_hat = h
    noise_var = cfg.channel.noise_var
    grad_bound = cfg.grad_bound
    if over is not None:
        if over.noise_var is not None:
            noise_var = over.noise_var
        if over.grad_bound is not None:
            grad_bound = over.grad_bound
    alg = clientlib.get(cfg.client.algo)
    cp = clientlib.resolve_params(
        cfg.client,
        over.client_mu if over is not None else None,
        over.client_alpha if over is not None else None)
    dev_state = client_state["dev"] if client_state is not None else None
    srv_state = client_state["srv"] if client_state is not None else None
    corr = None
    if alg.correction is not None:
        corr = lambda p, g, ds: alg.correction(cp, p, params, ds, srv_state, g)
    # Under device_mesh the round's out-of-scan [K]-way REAL reductions
    # (effective-gain sums) are pinned (ota.pinned_sum): the shard_map and
    # emulated programs surround them with different computations, and an
    # unpinned jnp.sum lets XLA cluster each one differently — a 1-ulp gain
    # drift that compounds over rounds.  Sums of 0/1 masks are exact under
    # any association and stay plain.
    fence = cfg.device_mesh is not None and cfg.device_mesh > 1
    ksum = ota.pinned_sum if fence else jnp.sum
    if cfg.participation < 1.0:
        mask = _participation_mask(cfg, key, t)
        b_eff, a_eff = ota.participation_fold(h_hat, b, a, mask, sum_fn=ksum)
    else:
        mask = None
        b_eff, a_eff = b, a
    if cfg.active_gather:
        idx = _active_indices(cfg, key, t)  # tracelint: disable=TL002 same salt discipline as the dense round: helpers fold_in _MASK_SALT vs the gather salt
        if batch is not None:
            batch = jax.tree_util.tree_map(lambda l: l[idx], batch)
        h_air, h_srv, b_air = h[idx], h_hat[idx], b_eff[idx]
        dev = idx
    else:
        idx = None
        h_air, h_srv, b_air = h, h_hat, b_eff
        dev = jnp.arange(cfg.num_devices)
    s = cfg.stream_length()
    kb = min(cfg.k_block, s)
    nb = s // kb

    def blk(v):
        return v.reshape((nb, kb) + v.shape[1:])

    xs = {"ha": blk((h_air * b_air).astype(jnp.float32)),
          "hs": blk((h_srv * b_air).astype(jnp.float32)),
          "b": blk(b_air), "dev": blk(dev)}
    if dev_state is not None:
        # one K-block of per-device state per scan step: gathered to the
        # active set first (like the batches), then blocked like everything
        # on the streamed axis
        dev_str = (dev_state if idx is None else
                   jax.tree_util.tree_map(lambda l: l[idx], dev_state))
        xs["cst"] = jax.tree_util.tree_map(blk, dev_str)
    if mask is not None and idx is None:
        xs["mask"] = blk(mask)
    weighted = mask is not None and sch.baseline
    if weighted:
        # masked baseline: the participant mean, accumulated as the SAME
        # hb-free weighted sum the dense round takes (see _round_math)
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        xs["w"] = blk(w if idx is None else w[idx])
    if batch is not None:
        xs["batch"] = jax.tree_util.tree_map(blk, batch)
    elif block_batch_fn is None:
        raise ValueError("streaming round got batch=None and no "
                         "block_batch_fn — pass run(..., "
                         "block_batch_provider=...) for the lazy-batch path")
    ocfg = ota.OTAConfig(scheme=cfg.scheme, a=a_eff, noise_var=noise_var,
                         grad_bound=grad_bound, backend=cfg.backend,
                         k_block=kb)
    template = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zero = jnp.zeros((), jnp.float32)
    tmap = jax.tree_util.tree_map
    hlr = cfg.local_steps * cfg.local_lr
    two_slot = alg.num_slots == 2
    sch2 = ocfg2 = None
    if two_slot:
        # the second slot accumulates into its OWN streaming carry,
        # interleaved block-by-block with slot 1's
        sch2 = schemes.get(cfg.client.variate_scheme)
        ocfg2 = ota.OTAConfig(scheme=cfg.client.variate_scheme, a=a_eff,
                              noise_var=noise_var, grad_bound=grad_bound,
                              backend=cfg.backend, k_block=kb)
    carry0 = (ota.streaming_carry(ocfg, template), zero,
              jnp.asarray(jnp.inf, jnp.float32),
              jnp.asarray(-jnp.inf, jnp.float32), zero)
    if two_slot:
        carry0 = carry0 + (ota.streaming_carry(ocfg2, template),)

    # Under device_mesh the same block math lowers in two contexts
    # (shard_map's manual body and the emulated outer scan); fencing the
    # transmit quantities pins their values before the blocked reductions,
    # so XLA's producer fusion cannot differ between the contexts and the
    # bitwise phys==emulated contract holds for every scheme/algorithm.
    # The plain stream stays unfenced (its lowering is bitwise-pinned).
    def body(carry, x):
        oc, nsum, nmin, nmax, txsum = carry[:5]
        bat = x["batch"] if "batch" in x else block_batch_fn(t, x["dev"])
        g_blk = _local_transmit(cfg, grad_fn, params, bat, corr,
                                x.get("cst"))
        if fence:
            g_blk = _fusion_fence(g_blk)
        stats = schemes.compute_stats(g_blk, sch, batched=True)
        norms = jnp.sqrt(stats.sq_norm)
        tx = schemes.transmit_energy(sch, stats, x["b"], grad_bound,
                                     x.get("mask"))
        oc = ota.streaming_block(ocfg, oc, g_blk, x["ha"], x["hs"],
                                 stats=stats, grad_bound=grad_bound,
                                 baseline_weights=x.get("w"))
        txsum = txsum + jnp.sum(tx)
        ys = None
        cst = x.get("cst")
        raw_new = cst
        if alg.has_state:
            raw_new = alg.update_state(cp, hlr, cst, srv_state, g_blk)
            if "mask" in x:
                # masked devices hold their state (the raw transition still
                # feeds slot 2, where b_eff = 0 silences those rows)
                keep = x["mask"].astype(bool)
                ys = tmap(lambda new, old: jnp.where(
                    keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
                    raw_new, cst)
            else:
                ys = raw_new
        new_carry = (oc, nsum + jnp.sum(norms),
                     jnp.minimum(nmin, jnp.min(norms)),
                     jnp.maximum(nmax, jnp.max(norms)), txsum)
        if two_slot:
            x2_blk = alg.variate_stat(cp, cst, raw_new, srv_state, g_blk)
            if fence:
                x2_blk = _fusion_fence(x2_blk)
            stats2 = schemes.compute_stats(x2_blk, sch2, batched=True)
            tx2 = schemes.transmit_energy(sch2, stats2, x["b"], grad_bound,
                                          x.get("mask"))
            oc2 = ota.streaming_block(ocfg2, carry[5], x2_blk, x["ha"],
                                      x["hs"], stats=stats2,
                                      grad_bound=grad_bound)
            new_carry = new_carry[:4] + (txsum + jnp.sum(tx2), oc2)
        return new_carry, ys

    carry_out, ys_out = _scan_stream_blocks(cfg, body, carry0, xs)
    oc, nsum, nmin, nmax, txsum = carry_out[:5]
    y = ota.streaming_finish(ocfg, oc, template, a_eff,
                             jax.random.fold_in(key, t),
                             noise_var=noise_var,
                             num_devices=1.0 if weighted else float(s))
    new_client_state = client_state
    if alg.stateful:
        dev_new = dev_state
        if alg.has_state:
            flat_new = tmap(lambda l: l.reshape((s,) + l.shape[2:]), ys_out)
            if idx is not None:
                dev_new = tmap(lambda full, fl: full.at[idx].set(fl),
                               dev_state, flat_new)
            else:
                dev_new = flat_new
        srv_new = srv_state
        if two_slot:
            key2 = jax.random.fold_in(jax.random.fold_in(key, t), _SLOT_SALT)
            y2 = ota.streaming_finish(ocfg2, carry_out[5], template, a_eff,
                                      key2, noise_var=noise_var,
                                      num_devices=float(s))
            gain = a_eff * ksum(h_hat * b_eff)
            y2_hat = tmap(lambda l: l / jnp.maximum(gain, schemes.EPS), y2)
            frac = (jnp.sum(mask) / cfg.num_devices if mask is not None
                    else jnp.asarray(1.0, jnp.float32))
            srv_new = alg.apply_variate(cp, srv_state, y2_hat, frac)
        new_client_state = {"dev": dev_new, "srv": srv_new}
    diag_core = {
        "grad_norm_mean": nsum / s,
        "grad_norm_min": nmin,
        "grad_norm_max": nmax,
        "tx_energy": txsum,
    }
    new_params, new_opt_state, diag = _round_tail(
        cfg, sch, opt, params, opt_state, y, mask, eta0, t, diag_core, a_eff,
        h, h_hat, b_eff)
    return new_params, new_opt_state, new_client_state, diag


def _fading_refresh(cfg: FLConfig, model_dim: int, eff_gain, chan_key, t,
                    fad_state, over: Optional[BatchAxes] = None):
    """Time-varying channel (beyond the paper, which holds h_k fixed): step
    the configured fading model to the round-t channel, form the server's
    CSI estimate ``h_hat_t``, and RE-RUN the Problem-3 optimization on
    ``h_hat_t``, entirely in JAX — Algorithm 1 is cheap
    (O(log(1/eps)(K+1)^3)) relative to a round of local training, and
    ``solve_problem3_jax`` makes it scan-safe (and vmap-safe, which is how a
    batched run re-optimizes every experiment's b_t in one program).  The
    effective receiver-side gain a*sum(h_hat_k b_k) — what the server can
    design for, and what the bounds see — is held at its optimized value;
    under imperfect CSI the AIR still applies a*sum(h_k b_k), and the gap
    is the ``csi_gain_err`` diagnostic.

    ``fad_state`` is the persistent process state (AR(1) I/Q pair; None for
    stateless models); returns ``(h, h_hat, b, a, fad_state)``."""
    ccfg = cfg.channel
    model = chl.get(ccfg.model)
    noise_var = ccfg.noise_var
    b_max = ccfg.b_max
    rho = ccfg.rho
    csi_error = ccfg.csi_error
    scale = None
    if over is not None:
        if over.noise_var is not None:
            noise_var = over.noise_var
        if over.b_max is not None:
            b_max = over.b_max
        if over.rho is not None:
            rho = over.rho
        if over.csi_error is not None:
            csi_error = over.csi_error
        scale = over.rayleigh_scale
    if scale is None:
        scale = ccfg.amplitude_scale()
    h, fad_state = model.step(ccfg, scale,
                              jax.random.fold_in(chan_key, t), fad_state,
                              rho)
    h = h.astype(jnp.float32)
    h_hat = h
    if schemes.maybe_positive(csi_error):
        # maybe_positive: a traced csi_error (the batched sweep axis) must
        # resolve the branch at trace time; estimation with a concrete-zero
        # magnitude is exact (h_hat == h bitwise), so the gate is
        # value-preserving either way
        ck = jax.random.fold_in(jax.random.fold_in(chan_key, _CSI_SALT), t)
        h_hat = chl.estimate(h, ck, csi_error, scale,
                             ccfg.csi_error_model).astype(jnp.float32)
    if cfg.amplification == "optimal":
        sol = amp.solve_problem3_jax(h_hat, noise_var, model_dim, b_max)
        b = sol.b.astype(jnp.float32)
    else:
        b = jnp.broadcast_to(jnp.asarray(b_max, jnp.float32), h.shape)
    # pinned under device_mesh: a feeds every transmit scale, so a 1-ulp
    # clustering difference here would break the phys==emulated contract
    ksum = (ota.pinned_sum
            if cfg.device_mesh is not None and cfg.device_mesh > 1
            else jnp.sum)
    a = (eff_gain / ksum(h_hat * b)).astype(jnp.float32)
    return h, h_hat, b, a, fad_state


@_engine_cache
def _make_fading_refresh(cfg: FLConfig, model_dim: int):
    """Jitted per-round channel/Problem-3 refresh for the python driver
    (the scan driver inlines ``_fading_refresh`` in its scan body)."""
    def refresh(eff_gain, chan_key, t, fad_state, over):
        _count_trace("fading_refresh")
        return _fading_refresh(cfg, model_dim, eff_gain, chan_key, t,
                               fad_state, over)

    return jax.jit(refresh)


@_engine_cache
def make_round_step(cfg: FLConfig, grad_fn: GradFn, block_batch_fn=None):
    """Builds the jitted one-round function (the ``python`` driver's unit).

    round_step(params, opt_state, client_state, device_batches, h, h_hat, b,
               a, eta0, t, key)
        -> (new_params, new_opt_state, new_client_state, diagnostics)
    device_batches: pytree with leading [K, ...] axis (per-device
    minibatches) — or None under ``cfg.k_block`` with a ``block_batch_fn``
    (the lazy-batch streaming round; see ``_round_math_streaming``).
    client_state: the client algorithm's state dict (None for stateless
    algorithms — the pre-registry carry, bitwise).

    Cached on (cfg, grad_fn) — ``FLConfig`` is a frozen dataclass and
    functions/bound methods hash stably — so repeated ``run`` calls (resume,
    benchmark sweeps) reuse the compiled executable instead of re-tracing.
    """
    sch = schemes.get(cfg.scheme)
    opt = server_optimizer(cfg)

    @jax.jit
    def round_step(params, opt_state, client_state, device_batches, h, h_hat,
                   b, a, eta0, t, key):
        _count_trace("round_step")
        if cfg.k_block is not None:
            return _round_math_streaming(cfg, sch, opt, grad_fn, params,
                                         opt_state, device_batches, h, h_hat,
                                         b, a, eta0, t, key,
                                         block_batch_fn=block_batch_fn,
                                         client_state=client_state)
        return _round_math(cfg, sch, opt, grad_fn, params, opt_state,
                           device_batches, h, h_hat, b, a, eta0, t, key,
                           client_state=client_state)

    return round_step


def _make_chunk_scan(cfg: FLConfig, grad_fn: GradFn, model_dim: int,
                     trace_counter: str, block_batch_fn=None):
    """The one chunk-scan body BOTH engine builders share: ``lax.scan`` of
    ``_round_math`` (+ the block-fading refresh) over a chunk of rounds.
    ``over=None`` bakes the config numerics into the trace (the
    single-experiment engine); a ``BatchAxes`` of traced scalars is the
    vmapped sweep engine's per-experiment lane.  The carry threads the true
    channel ``h``, the server estimate ``h_hat``, and the fading-process
    state (None for stateless models — no carry leaf, so default traces are
    untouched)."""
    if trace_counter not in TRACE_KINDS:
        raise ValueError(f"trace_counter {trace_counter!r} not in TRACE_KINDS")
    sch = schemes.get(cfg.scheme)
    opt = server_optimizer(cfg)
    time_varying = cfg.channel.time_varying()

    def run_one(params, opt_state, client_state, h, h_hat, b, a, eta0, key,
                chan_key, eff_gain, fad_state, over, ts, batches):
        _count_trace(trace_counter)

        def body(carry, xs):
            params, opt_state, client_state, h, h_hat, b, a, fad_state = carry
            t, batch = xs
            if time_varying:
                h, h_hat_t, b, a, fad_state = _fading_refresh(
                    cfg, model_dim, eff_gain, chan_key, t, fad_state, over)
                # perfect-CSI runs arrive with h_hat=None and keep the carry
                # leafless: the refreshed estimate IS h there (the refresh's
                # csi gate was off), so nothing is lost by dropping it
                h_hat = None if h_hat is None else h_hat_t
            if cfg.k_block is not None:
                params, opt_state, client_state, diag = _round_math_streaming(
                    cfg, sch, opt, grad_fn, params, opt_state, batch,
                    h, h_hat, b, a, eta0, t, key, over,
                    block_batch_fn=block_batch_fn, client_state=client_state)
            else:
                params, opt_state, client_state, diag = _round_math(
                    cfg, sch, opt, grad_fn, params, opt_state, batch,
                    h, h_hat, b, a, eta0, t, key, over,
                    client_state=client_state)
            return (params, opt_state, client_state, h, h_hat, b, a,
                    fad_state), diag

        (params, opt_state, client_state, h, h_hat, b, a, fad_state), hist = \
            jax.lax.scan(
                body,
                (params, opt_state, client_state, h, h_hat, b, a, fad_state),
                (ts, batches))
        return params, opt_state, client_state, h, h_hat, b, a, fad_state, \
            hist

    return run_one


@_engine_cache
def _make_run_chunk(cfg: FLConfig, grad_fn: GradFn, model_dim: int,
                    block_batch_fn=None):
    """Builds the compiled multi-round engine: one ``lax.scan`` over a chunk
    of rounds.  Param and server-optimizer buffers are donated (in-place
    across chunks) and the per-round diagnostics come back as [chunk] device
    arrays — one host transfer per chunk, not one per round.  Cached like
    ``make_round_step``.
    """
    run_one = _make_chunk_scan(cfg, grad_fn, model_dim, "run_chunk",
                               block_batch_fn)

    def run_chunk(params, opt_state, client_state, h, h_hat, b, a, eta0,
                  key, chan_key, eff_gain, fad_state, over, ts, batches):
        return run_one(params, opt_state, client_state, h, h_hat, b, a,
                       eta0, key, chan_key, eff_gain, fad_state, over, ts,
                       batches)

    return jax.jit(run_chunk, donate_argnums=(0, 1, 2))


@_engine_cache
def _make_run_chunk_batched(cfg: FLConfig, grad_fn: GradFn, model_dim: int):
    """The vectorized sweep engine's unit: the SAME chunk scan as
    ``_make_run_chunk`` (one shared ``_make_chunk_scan`` body), wrapped in
    ``jax.vmap`` over a leading experiment axis E.  Per-experiment state
    (params, optimizer moments, channel h/b/a, eta0, PRNG keys, the
    ``BatchAxes`` traced numerics) is batched; the round schedule ``ts`` and
    the device batches are shared across experiments (in_axes=None), so a
    sub-batch that shares a task shares one host->device batch transfer per
    chunk.

    ``cfg`` must be the *structural* representative of the sub-batch
    (``structural_config``): every per-experiment numeric arrives through the
    batched inputs, never through the baked config, so all sub-batches with
    one structure share this cache entry AND its compiled executables.
    Block-fading chunks redraw every experiment's channel and re-run the
    Problem-3 bisection (``amp.solve_problem3_jax``) inside the vmapped scan
    — ``lax.while_loop``'s batching rule freezes converged lanes, so each
    lane's bisection is identical to its solo run."""
    run_one = _make_chunk_scan(cfg, grad_fn, model_dim, "run_chunk_batched")
    batched = jax.vmap(run_one, in_axes=(0,) * 13 + (None, None))
    return jax.jit(batched, donate_argnums=(0, 1, 2))


# name -> lru-cached builder, for cache_info()/clear_compile_caches()
_CACHED_BUILDERS = {
    "round_step": make_round_step,
    "run_chunk": _make_run_chunk,
    "run_chunk_batched": _make_run_chunk_batched,
    "fading_refresh": _make_fading_refresh,
}


def _plan_chunks(t0: int, num_rounds: int, eval_every: Optional[int],
                 chunk_size: int) -> List[List[int]]:
    """Group rounds ``t0+1 .. t0+num_rounds`` into scan chunks.  Every round
    the python driver would eval on (t == 1 or t % eval_every == 0) ends a
    chunk, so the scan driver observes params at identical rounds."""
    chunks: List[List[int]] = []
    cur: List[int] = []
    for t in range(t0 + 1, t0 + num_rounds + 1):
        cur.append(t)
        if (len(cur) >= chunk_size
                or (eval_every is not None
                    and (t == 1 or t % eval_every == 0))):
            chunks.append(cur)
            cur = []
    if cur:
        chunks.append(cur)
    return chunks


def _stack_batches(batch_provider, ts: Sequence[int]) -> PyTree:
    """One [chunk, K, ...] stacked batch pytree per chunk (a single host ->
    device transfer feeds the whole scan)."""
    per_round = [batch_provider(t) for t in ts]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_round)


def _locked_eval_keys(metrics: Dict[str, float],
                      eval_keys: Optional[Tuple[str, ...]], t,
                      where: str = "") -> Tuple[str, ...]:
    """The metric key set is LOCKED on the first eval: an eval_fn that
    returns a key only on some rounds (or, batched, some experiments) would
    silently misalign that metric's history with hist['eval_round'].  Both
    ``run`` and ``run_batched`` share this contract."""
    if eval_keys is None:
        return tuple(metrics)
    if set(metrics) != set(eval_keys):
        raise ValueError(
            f"eval_fn returned metric keys {sorted(metrics)} at round "
            f"{t}{where}, but the history locked {sorted(eval_keys)} on the "
            "first eval — per-round metric lists must stay aligned with "
            "hist['eval_round']")
    return eval_keys


def run(cfg: FLConfig, state: FLState, grad_fn: GradFn,
        batch_provider: Callable[[int], Any], num_rounds: int,
        eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
        eval_every: int = 10, *, driver: str = "scan",
        chunk_size: int = 16,
        chunk_batch_provider: Optional[Callable[[Sequence[int]], Any]] = None,
        block_batch_provider: Optional[Callable[[Any, Any], Any]] = None,
        recorder: Optional[Any] = None,
        ) -> Tuple[FLState, Dict[str, List]]:
    """Run ``num_rounds`` FL rounds on the selected driver.

    ``batch_provider(t)`` returns the per-device minibatch pytree (leading K
    axis) for round t.  ``driver='scan'`` (default) runs the compiled chunked
    engine; ``driver='python'`` the per-round host loop (see module
    docstring).  Both evaluate ``eval_fn`` at t == 1 and every
    ``eval_every``-th round, produce the same history keys, and persist the
    final channel state (h, h_hat, b, a under a time-varying channel, plus
    any fading-process state) and the round counter
    back into ``state`` so a second ``run`` resumes seamlessly.

    ``chunk_batch_provider(ts)``, when given, supplies a whole chunk's
    batches as one [T, K, ...] pytree (a single gather + transfer), replacing
    the scan driver's default of stacking T ``batch_provider`` calls.

    ``block_batch_provider(t, dev_idx)`` is the streaming round's lazy-batch
    hook (requires ``cfg.k_block``): a traced function returning one
    K-block's [k_block, ...] batch pytree from its [k_block] device indices,
    called inside the round's block scan — the 100k-device path where no
    [K, ...] (or even [k_block-free]) batch stack ever exists on the host.
    ``batch_provider`` may then be ``None``.

    ``recorder``, a :class:`repro.obs.Recorder`, streams the run live: one
    ``chunk`` event per engine dispatch (wall clock, re-trace attribution,
    RSS) fanned out into per-round ``round`` events, plus ``eval`` events.
    All emission happens host-side at chunk boundaries on the
    already-transferred diagnostics — the trajectory (params AND history) is
    bitwise-identical with the recorder on or off.

    This signature is the stable compatibility surface; new scenario axes
    (server optimizer, local steps, participation) are ``FLConfig`` fields,
    and ``repro.fl.Experiment`` is the declarative front door that builds
    cfg/state/providers from one spec and calls here.
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r}; one of {DRIVERS}")
    if block_batch_provider is not None and cfg.k_block is None:
        raise ValueError("block_batch_provider streams per-K-block batches "
                         "inside the round scan; set cfg.k_block")
    opt = server_optimizer(cfg)
    if state.opt_state is None:
        # states built before the server-optimizer axis (or restored from
        # old checkpoints): initialize, with step = rounds already taken so
        # Adam bias correction matches an unbroken run
        state.opt_state = opt.init(state.params)._replace(
            step=jnp.asarray(state.round, jnp.int32))
    opt_state = state.opt_state
    alg = clientlib.get(cfg.client.algo)
    if alg.stateful and state.client_state is None:
        # states built before the client-algorithm axis (or restored from
        # pre-registry checkpoints): zero state, like a fresh setup()
        state.client_state = clientlib.init_state(cfg.client, state.params,
                                                  cfg.num_devices)
    client_state = (None if state.client_state is None else
                    jax.tree_util.tree_map(jnp.asarray, state.client_state))
    key = jax.random.PRNGKey(cfg.seed + 1)
    h = jnp.asarray(state.h, jnp.float32)
    # perfect CSI is structural: h_hat = None makes the estimate alias h's
    # traced value exactly (leafless carry, hard-zero csi_gain_err)
    perfect_csi = cfg.channel.csi_error == 0.0
    h_hat_np = state.h_hat if state.h_hat is not None else state.h
    h_hat = None if perfect_csi else jnp.asarray(h_hat_np, jnp.float32)
    b = jnp.asarray(state.b, jnp.float32)
    a = jnp.asarray(state.a, jnp.float32)
    eta0 = jnp.asarray(state.eta0, jnp.float32)
    model = chl.get(cfg.channel.model)
    time_varying = cfg.channel.time_varying()
    chan_key = jax.random.PRNGKey(cfg.seed + 2)
    eff_gain = jnp.zeros((), jnp.float32)
    fad_state = None
    if model.has_state:
        if state.fad_state is None:
            raise ValueError(
                f"channel model {cfg.channel.model!r} threads a persistent "
                "fading state; FLState.fad_state is unset — build the state "
                "via setup()")
        fad_state = jnp.asarray(state.fad_state, jnp.float32)
    # geometry-heterogeneous per-device scales ride through the over lane
    # (None — the homogeneous default — keeps the baked-config trace)
    over = None
    if state.scale is not None:
        over = BatchAxes(
            rayleigh_scale=jnp.asarray(state.scale, jnp.float32))
    if time_varying:
        if state.model_dim <= 0:
            raise ValueError("a time-varying channel re-solves Problem 3 "
                             "with the real model dimension; "
                             "FLState.model_dim is unset — build the state "
                             "via setup()")
        # the DESIGNED effective gain: what the server set on its estimate
        eff_gain = jnp.asarray(
            state.a * float(np.sum(np.asarray(h_hat_np, np.float64)
                                   * np.asarray(state.b, np.float64))),
            jnp.float32)

    hist: Dict[str, List] = {"round": [], "eval_round": []}
    for k in DIAG_KEYS:
        hist[k] = []

    eval_keys: Optional[Tuple[str, ...]] = None

    def record_eval(params, t):
        nonlocal eval_keys
        metrics = eval_fn(params)
        eval_keys = _locked_eval_keys(metrics, eval_keys, t)
        for mk in eval_keys:
            hist.setdefault(mk, []).append(metrics[mk])
        hist["eval_round"].append(t)
        if recorder is not None:
            recorder.on_eval(t, {mk: float(metrics[mk]) for mk in eval_keys})

    t0 = state.round
    if driver == "python":
        round_step = make_round_step(cfg, grad_fn, block_batch_provider)
        fading_refresh = _make_fading_refresh(cfg, state.model_dim)
        params = state.params
        for chunk_i, t in enumerate(range(t0 + 1, t0 + num_rounds + 1)):
            if recorder is not None:
                tr0 = dict(TRACE_COUNTS)
                wt0 = time.perf_counter()
            with obsprof.annotate_chunk(chunk_i):
                if time_varying:
                    h, h_hat_t, b, a, fad_state = fading_refresh(
                        eff_gain, chan_key, jnp.asarray(t), fad_state, over)
                    h_hat = None if perfect_csi else h_hat_t
                batch = (None if block_batch_provider is not None
                         else batch_provider(t))
                params, opt_state, client_state, diag = round_step(
                    params, opt_state, client_state, batch, h, h_hat, b, a,
                    eta0, jnp.asarray(t), key)
            hist["round"].append(t)
            for k in DIAG_KEYS:
                hist[k].append(float(diag[k]))
            if recorder is not None:
                # the python driver's 'chunk' is one round: one (or, under a
                # time-varying channel, two) dispatches
                recorder.on_chunk(
                    chunk_i, [t], {k: np.asarray([hist[k][-1]])
                                   for k in DIAG_KEYS},
                    wall_time_s=time.perf_counter() - wt0,
                    dispatches=2 if time_varying else 1,
                    retraces=trace_deltas(tr0),
                    rss_mb=obsprof.rss_mb())
            if eval_fn is not None and (t % eval_every == 0 or t == 1):
                record_eval(params, t)
    else:
        run_chunk = _make_run_chunk(cfg, grad_fn, state.model_dim,
                                    block_batch_provider)
        # params, optimizer state, and client state are donated
        # chunk-to-chunk; copy once so the CALLER's pytrees (often reused
        # across runs, e.g. the benchmark experiments) survive
        params = jax.tree_util.tree_map(jnp.copy, state.params)
        opt_state = jax.tree_util.tree_map(jnp.copy, opt_state)
        client_state = (None if client_state is None else
                        jax.tree_util.tree_map(jnp.copy, client_state))
        for chunk_i, ts in enumerate(_plan_chunks(
                t0, num_rounds,
                eval_every if eval_fn is not None else None, chunk_size)):
            if recorder is not None:
                tr0 = dict(TRACE_COUNTS)
                wt0 = time.perf_counter()
            with obsprof.annotate_chunk(chunk_i):
                if block_batch_provider is not None:
                    batches = None     # drawn per (round, K-block) in-scan
                else:
                    batches = (chunk_batch_provider(ts) if chunk_batch_provider
                               else _stack_batches(batch_provider, ts))
                (params, opt_state, client_state, h, h_hat, b, a, fad_state,
                 chunk_hist) = run_chunk(
                     params, opt_state, client_state, h, h_hat, b, a, eta0,
                     key, chan_key, eff_gain, fad_state, over,
                     jnp.asarray(ts, jnp.int32), batches)
                chunk_hist = jax.device_get(chunk_hist)   # ONE sync per chunk
            hist["round"].extend(ts)
            for k in DIAG_KEYS:
                hist[k].extend(np.asarray(chunk_hist[k]).astype(float).tolist())
            if recorder is not None:
                recorder.on_chunk(
                    chunk_i, list(ts),
                    {k: np.asarray(chunk_hist[k]) for k in DIAG_KEYS},
                    wall_time_s=time.perf_counter() - wt0,
                    dispatches=1,
                    retraces=trace_deltas(tr0),
                    rss_mb=obsprof.rss_mb())
            t_end = ts[-1]
            if eval_fn is not None and (t_end % eval_every == 0 or t_end == 1):
                record_eval(params, t_end)

    state.params = params
    state.opt_state = opt_state
    if client_state is not None:
        state.client_state = client_state
    if time_varying:
        # persist the final channel/gain so a second run(cfg, state, ...)
        # resumes from round t0+num_rounds, not the stale round-0 draw
        state.h = np.asarray(jax.device_get(h), np.float64)
        state.h_hat = (state.h if h_hat is None
                       else np.asarray(jax.device_get(h_hat), np.float64))
        state.b = np.asarray(jax.device_get(b), np.float64)
        state.a = float(a)
    if fad_state is not None:
        # the correlated fading process continues where it left off
        state.fad_state = np.asarray(jax.device_get(fad_state), np.float64)
    state.round += num_rounds
    return state, hist


def _stack_trees(trees: Sequence[PyTree]) -> PyTree:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _slice_tree(tree: PyTree, e: int) -> PyTree:
    return jax.tree_util.tree_map(lambda l: l[e], tree)


def run_batched(cfgs: Sequence[FLConfig], states: Sequence[FLState],
                grad_fn: GradFn, batch_provider: Callable[[int], Any],
                num_rounds: int,
                eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
                eval_every: int = 10, *, chunk_size: int = 16,
                chunk_batch_provider: Optional[
                    Callable[[Sequence[int]], Any]] = None,
                shard: bool = True,
                recorder: Optional[Any] = None,
                ) -> Tuple[List[FLState], Dict[str, Any]]:
    """Run E experiments as ONE compiled program: the vectorized twin of
    ``run(driver='scan')``.

    The configs must be *structurally identical* (equal
    ``structural_config``): same scheme / case / backend / scenario axes /
    fading mode, differing only in the batchable numerics
    (``BATCHED_FL_FIELDS`` / ``BATCHED_CHANNEL_FIELDS``) — those travel as
    per-experiment traced inputs through ``BatchAxes`` and the stacked
    h/b/a/eta0 channel state, so E grid points cost one trace and one
    dispatch per chunk.  All experiments share ``grad_fn`` and the batch
    providers (one task), the round counter, and the eval schedule.

    When multiple local devices are available and E divides their count, the
    experiment axis is sharded across them
    (``distribution.sharding.experiment_mesh``) — grid points run on
    different devices with no further code change.

    Returns ``(states, hist)`` where each per-round diagnostic in ``hist``
    is an ``np.ndarray`` of shape [E, num_rounds] (same ``DIAG_KEYS`` as
    ``run`` plus the leading experiment axis), eval metrics are
    [E, num_evals], and ``hist['round']`` / ``hist['eval_round']`` stay flat
    lists shared by every experiment.  ``states`` is updated in place per
    experiment exactly like ``run`` updates its single state.

    The mesh backend is not batchable (its device axis IS the mesh); callers
    (``repro.fl.sweep``) fall back to sequential runs there.

    ``recorder`` streams the batched run exactly like ``run``'s: per-chunk
    ``chunk`` events, per-round ``round`` events whose diagnostic values are
    [E] lists, and ``eval`` events with [E] metric lists — host-side only,
    bitwise-invisible to the trajectory.
    """
    if len(cfgs) != len(states) or not cfgs:
        raise ValueError("need equal, nonzero numbers of configs and states")
    num_exp = len(cfgs)
    cfg0 = cfgs[0]
    if cfg0.backend == "mesh":
        raise ValueError("the mesh backend reserves the device axis for the "
                         "FL devices; run mesh experiments sequentially")
    if cfg0.device_mesh is not None:
        raise ValueError(
            "device_mesh (the sharded streaming engine) owns the local "
            "devices for the FL-device axis — a batched run cannot also "
            "shard its experiment axis over them; run device_mesh "
            "experiments sequentially (repro.fl.sweep falls back "
            "automatically)")
    sig = structural_config(cfg0)
    for c in cfgs[1:]:
        if structural_config(c) != sig:
            raise ValueError(
                "configs in a batched run must be structurally identical "
                "(they may differ only in "
                f"{BATCHED_FL_FIELDS + BATCHED_CHANNEL_FIELDS}); got "
                f"{structural_config(c)} vs {sig}")
    t0s = {s.round for s in states}
    if len(t0s) != 1:
        raise ValueError(f"states disagree on the round counter: {t0s}")
    t0 = t0s.pop()
    dims = {s.model_dim for s in states}
    if len(dims) != 1:
        raise ValueError(f"states disagree on model_dim: {dims} — a batched "
                         "run shares one task")
    model_dim = dims.pop()

    opt = server_optimizer(cfg0)
    alg0 = clientlib.get(cfg0.client.algo)
    for s in states:
        if s.opt_state is None:
            s.opt_state = opt.init(s.params)._replace(
                step=jnp.asarray(s.round, jnp.int32))
        if alg0.stateful and s.client_state is None:
            s.client_state = clientlib.init_state(cfg0.client, s.params,
                                                  cfg0.num_devices)

    # assemble the per-experiment numerics in NumPy — ONE host->device
    # transfer per stacked array, not one dispatch per experiment (the
    # stacking cost is per run_sweep call, so it must stay off the grid's
    # critical path)
    params = _stack_trees([s.params for s in states])
    opt_state = _stack_trees([s.opt_state for s in states])
    client_state = (_stack_trees([s.client_state for s in states])
                    if alg0.stateful else None)
    h = jnp.asarray(np.stack([np.asarray(s.h) for s in states]), jnp.float32)
    # perfect CSI across the whole sub-batch is structural (h_hat aliases h
    # in-trace); ANY imperfect lane threads the stacked estimates, and the
    # perfect lanes among them stay exact (their estimation noise term is a
    # traced-zero multiple)
    csi_off = all(c.channel.csi_error == 0.0 for c in cfgs)
    h_hat = None if csi_off else jnp.asarray(
        np.stack([np.asarray(s.h_hat if s.h_hat is not None else s.h)
                  for s in states]), jnp.float32)
    b = jnp.asarray(np.stack([np.asarray(s.b) for s in states]), jnp.float32)
    a = jnp.asarray(np.asarray([s.a for s in states]), jnp.float32)
    eta0 = jnp.asarray(np.asarray([s.eta0 for s in states]), jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(c.seed + 1) for c in cfgs])
    chan_keys = jnp.stack([jax.random.PRNGKey(c.seed + 2) for c in cfgs])
    model = chl.get(cfg0.channel.model)
    time_varying = cfg0.channel.time_varying()
    eff_gain = jnp.zeros((num_exp,), jnp.float32)
    fad_state = None
    if model.has_state:
        if any(s.fad_state is None for s in states):
            raise ValueError(
                f"channel model {cfg0.channel.model!r} threads a persistent "
                "fading state; build the states via setup()")
        fad_state = jnp.asarray(
            np.stack([np.asarray(s.fad_state) for s in states]), jnp.float32)
    if time_varying:
        if model_dim <= 0:
            raise ValueError("a time-varying channel re-solves Problem 3 "
                             "with the real model dimension; "
                             "FLState.model_dim is unset — build the states "
                             "via setup()")
        eff_gain = jnp.asarray(
            np.asarray([s.a * float(np.sum(np.asarray(
                s.h_hat if s.h_hat is not None else s.h, np.float64)
                * np.asarray(s.b, np.float64)))
                for s in states]), jnp.float32)

    def _scales():
        # in-scan redraw scale: [E, K] geometry-heterogeneous per-device
        # vectors (drawn at setup, living on the states) or [E] scalars
        if cfg0.channel.geometry is not None:
            return jnp.asarray(np.stack([np.asarray(s.scale)
                                         for s in states]), jnp.float32)
        return jnp.asarray(
            np.asarray([c.channel.amplitude_scale() for c in cfgs]),
            jnp.float32)

    over = BatchAxes(
        noise_var=jnp.asarray(
            np.asarray([c.channel.noise_var for c in cfgs]), jnp.float32),
        grad_bound=(None if cfg0.grad_bound is None else jnp.asarray(
            np.asarray([c.grad_bound for c in cfgs]), jnp.float32)),
        b_max=(jnp.asarray(np.asarray([c.channel.b_max for c in cfgs]),
                           jnp.float32) if time_varying else None),
        rayleigh_scale=(_scales() if time_varying else None),
        rho=(jnp.asarray(np.asarray([c.channel.rho for c in cfgs]),
                         jnp.float32) if time_varying else None),
        csi_error=(jnp.asarray(
            np.asarray([c.channel.csi_error for c in cfgs]), jnp.float32)
            if time_varying and not csi_off else None),
        # exactly the numerics the algorithm declares it reads become lanes
        # (an unused lane would change the default traces for nothing)
        client_mu=(jnp.asarray(
            np.asarray([c.client.mu for c in cfgs]), jnp.float32)
            if alg0.uses_mu else None),
        client_alpha=(jnp.asarray(
            np.asarray([c.client.alpha for c in cfgs]), jnp.float32)
            if alg0.uses_alpha else None),
    )

    if shard:
        from repro.distribution import sharding as shardlib
        mesh = shardlib.experiment_mesh(num_exp)
        if mesh is not None:
            (params, opt_state, client_state, h, h_hat, b, a, eta0, keys,
             chan_keys, eff_gain, fad_state, over) = \
                shardlib.shard_experiment_axis(
                    (params, opt_state, client_state, h, h_hat, b, a, eta0,
                     keys, chan_keys, eff_gain, fad_state, over), mesh)

    hist: Dict[str, Any] = {"round": [], "eval_round": []}
    diag_chunks: Dict[str, List[np.ndarray]] = {k: [] for k in DIAG_KEYS}
    eval_chunks: Dict[str, List[List[float]]] = {}
    eval_keys: Optional[Tuple[str, ...]] = None

    def record_eval(params, t):
        nonlocal eval_keys
        per_exp: Dict[str, List[float]] = {}
        for e in range(num_exp):
            metrics = eval_fn(_slice_tree(params, e))
            eval_keys = _locked_eval_keys(metrics, eval_keys, t,
                                          where=f" (experiment {e})")
            for mk in eval_keys:
                per_exp.setdefault(mk, []).append(metrics[mk])
        for mk in eval_keys:
            eval_chunks.setdefault(mk, []).append(per_exp[mk])
        hist["eval_round"].append(t)
        if recorder is not None:
            recorder.on_eval(t, {mk: [float(v) for v in per_exp[mk]]
                                 for mk in eval_keys})

    run_chunk = _make_run_chunk_batched(sig, grad_fn, model_dim)
    for chunk_i, ts in enumerate(_plan_chunks(
            t0, num_rounds, eval_every if eval_fn is not None else None,
            chunk_size)):
        if recorder is not None:
            tr0 = dict(TRACE_COUNTS)
            wt0 = time.perf_counter()
        with obsprof.annotate_chunk(chunk_i):
            batches = (chunk_batch_provider(ts) if chunk_batch_provider
                       else _stack_batches(batch_provider, ts))
            (params, opt_state, client_state, h, h_hat, b, a, fad_state,
             chunk_hist) = run_chunk(
                 params, opt_state, client_state, h, h_hat, b, a, eta0, keys,
                 chan_keys, eff_gain, fad_state, over,
                 jnp.asarray(ts, jnp.int32), batches)
            chunk_hist = jax.device_get(chunk_hist)   # ONE sync per chunk
        hist["round"].extend(ts)
        for k in DIAG_KEYS:
            diag_chunks[k].append(np.asarray(chunk_hist[k], np.float64))
        if recorder is not None:
            # [E, T] per-chunk diagnostics: on_chunk fans them out into one
            # round event per t with [E] value lists
            recorder.on_chunk(
                chunk_i, list(ts),
                {k: np.asarray(chunk_hist[k]) for k in DIAG_KEYS},
                wall_time_s=time.perf_counter() - wt0,
                dispatches=1,
                retraces=trace_deltas(tr0),
                rss_mb=obsprof.rss_mb())
        t_end = ts[-1]
        if eval_fn is not None and (t_end % eval_every == 0 or t_end == 1):
            record_eval(params, t_end)

    for k in DIAG_KEYS:
        hist[k] = np.concatenate(diag_chunks[k], axis=1)       # [E, T]
    for mk, cols in eval_chunks.items():
        hist[mk] = np.asarray(cols, np.float64).T              # [E, evals]

    for e, s in enumerate(states):
        s.params = _slice_tree(params, e)
        s.opt_state = _slice_tree(opt_state, e)
        if alg0.stateful:
            s.client_state = _slice_tree(client_state, e)
        if time_varying:
            s.h = np.asarray(jax.device_get(h[e]), np.float64)
            s.h_hat = (s.h if h_hat is None
                       else np.asarray(jax.device_get(h_hat[e]), np.float64))
            s.b = np.asarray(jax.device_get(b[e]), np.float64)
            s.a = float(a[e])
        if fad_state is not None:
            s.fad_state = np.asarray(jax.device_get(fad_state[e]),
                                     np.float64)
        s.round += num_rounds
    return list(states), hist
