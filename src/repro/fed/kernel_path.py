"""Kernel-accelerated aggregation: the *kernels backend* of
``repro.core.ota.aggregate`` (``cfg.backend == 'kernels'``).

On TPU the per-round hot loop of the paper's method is: per-device statistics
over every device's flat gradient (HBM-bound reduction) followed by the fused
scale-amplify-superpose (eq. 10).  Since the registry refactor this path is
scheme-generic and device-batched:

1. ONE batched Pallas ``pallas_call`` over a (K, blocks) grid
   (``ops.batched_moments``) yields every device's sum-of-squares and sum —
   no Python loop over devices, and the moments schemes (benchmark2) get
   mean/std from the same HBM pass.
2. ONE fused superpose kernel (``ops.ota_superpose``) takes a per-device
   composite scale vector ``h_k b_k * scheme.device_scale(stats)`` plus an
   optional in-register pre-transform (``sign`` for onebit), so every
   norm-scaling scheme in ``repro.core.schemes`` lowers to the same kernel.
   A per-device shift (benchmark2's ``-mean``) folds into one scalar
   correction after the kernel — zero extra memory traffic.
   ``normalized_per_tensor`` runs its per-(device, tensor) norms through the
   batched kernel leaf-by-leaf (a loop over *tensors*, never over devices).

Noise is drawn with the backend-shared per-leaf key schedule
(``schemes.add_channel_noise``) so a shared key reproduces the vmap/mesh
backends bitwise.  ``mean`` is the ideal non-OTA baseline and falls back to a
plain average.  On hosts without a TPU the default ``interpret=None`` routes
the kernel wrappers to their XLA oracles (full speed — the compiled FL engine
runs this path); passing ``interpret=True`` forces the Pallas interpreter, the
correctness path the kernel/backend test suites pin explicitly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import schemes
from repro.kernels import ops

PyTree = Any


def _template_unravel(stacked: PyTree):
    """Single-device f32 template of a stacked pytree + its unravel fn."""
    template = jax.tree_util.tree_map(lambda l: l[0].astype(jnp.float32),
                                      stacked)
    _, unravel = ravel_pytree(template)
    return template, unravel


def aggregate_kernels(cfg, stacked_grads: PyTree, h: jax.Array, b: jax.Array,
                      key: Optional[jax.Array] = None, *,
                      h_hat: Optional[jax.Array] = None,
                      interpret: Optional[bool] = None,
                      k_block: Optional[int] = None) -> PyTree:
    """Pallas-kernel implementation of ``aggregate`` for any registered
    norm-scaling scheme.  stacked_grads: pytree with leading device axis K;
    returns the update direction y with the single-device pytree structure.

    ``h`` is the true channel (folded into the superpose kernel's composite
    scale — the air); ``h_hat`` the server's CSI estimate, used only by the
    server-side side-info folding (None = perfect CSI).

    ``k_block`` routes both the moments and the superpose launch through the
    streaming (K-block, N-block)-grid kernels: the per-device statistics and
    the K-way reduction accumulate block-by-block in fp32, so VMEM only ever
    holds (k_block, block)-sized tiles of the stacked gradients.
    """
    if h_hat is None:
        h_hat = h
    sch = schemes.validate_config(cfg.scheme, cfg.grad_bound)
    if sch.baseline:
        return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0),
                                      stacked_grads)

    leaves = jax.tree_util.tree_leaves(stacked_grads)
    k = leaves[0].shape[0]
    if k_block is not None:
        k_block = min(k_block, k)
    flat2d = [l.astype(jnp.float32).reshape(k, -1) for l in leaves]
    hb = (h * b).astype(jnp.float32)
    template, unravel = _template_unravel(stacked_grads)

    shift = None
    kernel_pre = sch.pre
    if sch.per_tensor:
        # per-(device, tensor) scales via the scheme's OWN tensor_scale:
        # batched-moments kernel per LEAF (#tensors launches, each covering
        # all K devices), pre-transform + scaling fused into the flatten
        # pass; the superpose kernel then sees scale = h_k b_k.  ``pre``
        # must apply BEFORE the tensor scales (matching schemes.transform),
        # so it cannot run in-kernel here.
        pre_fn = schemes.PRE_TRANSFORMS[sch.pre]
        kernel_pre = "identity"
        tensor_sq = tuple(
            ops.batched_moments(l2, interpret=interpret, k_block=k_block)[0]
            for l2 in flat2d)
        stats = schemes.DeviceStats(
            count=sum(l2.shape[1] for l2 in flat2d),
            sq_norm=sum(tensor_sq), tensor_sq_norms=tensor_sq)
        scales = sch.tensor_scale(stats, cfg.grad_bound)
        flat = jnp.concatenate(
            [pre_fn(l2) * s[:, None] for l2, s in zip(flat2d, scales)], axis=1)
        scale = hb
    else:
        flat = jnp.concatenate(flat2d, axis=1)
        sumsq, total = ops.batched_moments(flat, interpret=interpret,
                                           k_block=k_block)
        stats = schemes.DeviceStats(
            count=flat.shape[1], sq_norm=sumsq,
            total=total if sch.needs_moments else None)
        scale = sch.device_scale(stats, cfg.grad_bound)
        if sch.device_shift is not None:
            shift = sch.device_shift(stats, cfg.grad_bound)
        scale = scale * hb

    n = flat.shape[1]
    if (key is not None and not cfg.noiseless
            and schemes.maybe_positive(cfg.noise_var)):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, template)
        noise, _ = ravel_pytree(
            schemes.add_channel_noise(zeros, key, cfg.noise_var))
    else:
        noise = jnp.zeros((n,), jnp.float32)

    y_flat = ops.ota_superpose(flat, scale, noise, cfg.a, pre=kernel_pre,
                               interpret=interpret, k_block=k_block)
    if shift is not None:
        # sum_k scale_k (g_k + shift_k) = kernel result + a * sum_k scale_k shift_k
        y_flat = y_flat + jnp.asarray(cfg.a, jnp.float32) * jnp.sum(scale * shift)

    y = unravel(y_flat)
    if sch.server_post is not None:
        folded = {}
        if sch.collect_side is not None:
            folded = schemes.fold_side_stacked(sch.collect_side(stats),
                                               h_hat, b)
        y = sch.server_post(y, folded)
    return y


def aggregate_normalized_kernels(stacked_grads: PyTree, h: jax.Array,
                                 b: jax.Array, a: float,
                                 key: Optional[jax.Array], noise_var: float,
                                 interpret: Optional[bool] = None) -> PyTree:
    """Back-compat wrapper: the pre-registry entry point for the
    ``normalized`` scheme only."""
    from repro.core.ota import OTAConfig
    cfg = OTAConfig(scheme="normalized", a=a, noise_var=noise_var,
                    backend="kernels")
    return aggregate_kernels(cfg, stacked_grads, h, b, key,
                             interpret=interpret)
