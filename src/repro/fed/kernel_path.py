"""Kernel-accelerated aggregation path.

On TPU the per-round hot loop of the paper's method is: global L2 norm of
every device's gradient (HBM-bound reduction) followed by the fused
normalize-amplify-superpose (eq. 10 with eq. 12).  This module routes the
``normalized`` scheme through the Pallas kernels
(``repro.kernels.grad_norm`` / ``repro.kernels.ota_aggregate``); on CPU the
kernels execute under interpret=True, so this path is also the kernels'
system-level integration test (vs ``repro.core.ota.aggregate``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.kernels import ops

PyTree = Any


def aggregate_normalized_kernels(stacked_grads: PyTree, h: jax.Array,
                                 b: jax.Array, a: float,
                                 key: Optional[jax.Array], noise_var: float,
                                 interpret: Optional[bool] = None) -> PyTree:
    """Pallas-kernel implementation of the ``normalized`` scheme.

    stacked_grads: pytree with leading device axis K.  Returns the update
    direction y with the single-device pytree structure.
    """
    leaves = jax.tree_util.tree_leaves(stacked_grads)
    k = leaves[0].shape[0]
    # flatten each device's gradient to one vector (shared unravel)
    _, unravel = ravel_pytree(jax.tree_util.tree_map(lambda l: l[0], stacked_grads))
    flat = jnp.stack([ravel_pytree(
        jax.tree_util.tree_map(lambda l: l[i], stacked_grads))[0]
        for i in range(k)])                                     # [K, N]

    norms = jnp.stack([ops.grad_norm(flat[i], interpret=interpret)
                       for i in range(k)])                      # [K]
    n = flat.shape[1]
    if key is not None and noise_var > 0.0:
        noise = jnp.sqrt(jnp.asarray(noise_var, jnp.float32)) \
            * jax.random.normal(key, (n,), jnp.float32)
    else:
        noise = jnp.zeros((n,), jnp.float32)
    y_flat = ops.ota_aggregate(flat, (h * b).astype(jnp.float32), norms,
                               noise, a, interpret=interpret)
    return unravel(y_flat)
