"""Block-level assembly: pattern-driven superblocks, scanned over depth.

A model is ``num_superblocks`` repetitions of ``cfg.block_pattern`` (a tuple
of block-kind strings).  Parameters for each pattern *position* are stacked
over superblocks and the stack is traversed with ``jax.lax.scan`` so the HLO
stays O(pattern) instead of O(num_layers) — essential for 126-layer models
compiled for 512 devices on a single-core CPU host.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X

PyTree = Any


def init_block(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    mixer, _, mlp_kind = kind.partition("+")
    p: Dict[str, Any] = {}
    if mixer == "attn":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["attn"] = L.init_attention(ks[0], cfg)
    elif mixer == "xattn":  # decoder block of an encoder-decoder
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln_x"] = L.init_rmsnorm(cfg.d_model)
        p["cross"] = L.init_attention(ks[3], cfg, cross=True)
    elif mixer == "mamba":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["mamba"] = M.init_mamba(ks[0], cfg)
    elif mixer == "mlstm":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["cell"] = X.init_mlstm(ks[0], cfg)
        return p
    elif mixer == "slstm":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["cell"] = X.init_slstm(ks[0], cfg)
        return p
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if mlp_kind == "dense":
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif mlp_kind == "moe":
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = MOE.init_moe(ks[1], cfg)
    return p


def apply_block(params: Dict, cfg: ModelConfig, kind: str, x, *,
                causal: bool = True, enc_out=None,
                cache_len: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Full-sequence block application.  Returns (x, aux_loss, cache|None).

    ``cache_len > 0`` collects this block's decode cache (prefill handoff),
    structured exactly like ``init_block_cache``.
    """
    mixer, _, mlp_kind = kind.partition("+")
    aux = jnp.zeros((), jnp.float32)
    cache = None
    collect = cache_len > 0
    if mixer in ("attn", "xattn"):
        window = cfg.sliding_window
        h_in = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if collect:
            h, (k_kv, v_kv) = L.attention(params["attn"], cfg, h_in,
                                          causal=causal, window=window,
                                          return_kv=True)
            ck, cv = L.prefill_kv_cache(cfg, k_kv, v_kv, x.shape[1], cache_len)
            cache = {"k": ck, "v": cv}
        else:
            h = L.attention(params["attn"], cfg, h_in, causal=causal,
                            window=window)
        x = x + h
        if mixer == "xattn":
            h = L.attention(params["cross"], cfg,
                            L.rmsnorm(params["ln_x"], x, cfg.norm_eps),
                            causal=False, kv_x=enc_out)
            x = x + h
    elif mixer == "mamba":
        h_in = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if collect:
            h, (ssm, conv) = M.mamba_mix(params["mamba"], cfg, h_in,
                                         return_state=True)
            cache = {"ssm": ssm, "conv": conv.astype(L.dtype_of(cfg))}
        else:
            h = M.mamba_mix(params["mamba"], cfg, h_in)
        x = x + h
    elif mixer == "mlstm":
        h_in = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if collect:
            h, (c_mem, n_mem) = X.mlstm_mix(params["cell"], cfg, h_in,
                                            return_state=True)
            cache = {"C": c_mem, "n": n_mem}
        else:
            h = X.mlstm_mix(params["cell"], cfg, h_in)
        return x + h, aux, cache
    elif mixer == "slstm":
        h_in = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if collect:
            h, st = X.slstm_mix(params["cell"], cfg, h_in, return_state=True)
            cache = dict(zip(("c", "n", "h", "m"), st))
        else:
            h = X.slstm_mix(params["cell"], cfg, h_in)
        return x + h, aux, cache
    if mlp_kind == "dense":
        x = x + L.mlp(params["mlp"], cfg, L.rmsnorm(params["ln2"], x, cfg.norm_eps))
    elif mlp_kind == "moe":
        y, moe_aux = MOE.moe_mlp(params["moe"], cfg,
                                 L.rmsnorm(params["ln2"], x, cfg.norm_eps))
        x = x + y
        aux = aux + MOE.aux_loss(cfg, moe_aux)
    return x, aux, cache


# ---------------------------------------------------------------------------
# decode-step application (single token, carried caches)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     cp_shards: int = 1) -> Dict:
    """Cache pytree for ONE layer of the given kind (unstacked)."""
    mixer = kind.partition("+")[0]
    if mixer in ("attn", "xattn"):
        window = cfg.sliding_window
        s = min(max_len, window) if window else max_len
        if cp_shards > 1 and s % cp_shards != 0:
            raise ValueError("cache length must divide the context-parallel shards")
        c = {"k": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim), L.dtype_of(cfg)),
             "v": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim), L.dtype_of(cfg))}
        return c
    if mixer == "mamba":
        return {"ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                                  L.dtype_of(cfg))}
    if mixer == "mlstm":
        di = X.xlstm_inner_dim(cfg)
        dh = di // cfg.num_heads
        return {"C": jnp.zeros((batch, cfg.num_heads, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, cfg.num_heads, dh), jnp.float32)}
    if mixer == "slstm":
        di = X.xlstm_inner_dim(cfg)
        z = jnp.zeros((batch, di), jnp.float32)
        return {"c": z, "n": z, "h": z, "m": jnp.full((batch, di), -1e30, jnp.float32)}
    raise ValueError(kind)


def apply_block_decode(params: Dict, cfg: ModelConfig, kind: str, x, cache: Dict,
                       pos, *, enc_out=None, axis_name: Optional[str] = None,
                       shard_offset=None) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode through one block.  x: [B,1,D]."""
    mixer = kind.partition("+")[0]
    new_cache = dict(cache)
    if mixer in ("attn", "xattn"):
        h, nk, nv = L.decode_attention(
            params["attn"], cfg, L.rmsnorm(params["ln1"], x, cfg.norm_eps),
            cache["k"], cache["v"], pos, window=cfg.sliding_window,
            axis_name=axis_name, shard_offset=shard_offset)
        new_cache["k"], new_cache["v"] = nk, nv
        x = x + h
        if mixer == "xattn":
            h = L.attention(params["cross"], cfg,
                            L.rmsnorm(params["ln_x"], x, cfg.norm_eps),
                            causal=False, kv_x=enc_out,
                            positions=jnp.full((1,), pos))
            x = x + h
    elif mixer == "mamba":
        h, ssm, conv = M.mamba_decode_step(
            params["mamba"], cfg, L.rmsnorm(params["ln1"], x, cfg.norm_eps),
            cache["ssm"], cache["conv"])
        new_cache["ssm"], new_cache["conv"] = ssm, conv
        x = x + h
        return _decode_mlp(params, cfg, kind, x), new_cache
    elif mixer == "mlstm":
        h, c_new, n_new = X.mlstm_decode_step(
            params["cell"], cfg, L.rmsnorm(params["ln1"], x, cfg.norm_eps),
            cache["C"], cache["n"])
        new_cache["C"], new_cache["n"] = c_new, n_new
        return x + h, new_cache
    elif mixer == "slstm":
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        h, new_state = X.slstm_decode_step(
            params["cell"], cfg, L.rmsnorm(params["ln1"], x, cfg.norm_eps), state)
        new_cache = dict(zip(("c", "n", "h", "m"), new_state))
        return x + h, new_cache
    return _decode_mlp(params, cfg, kind, x), new_cache


def _decode_mlp(params, cfg, kind, x):
    mlp_kind = kind.partition("+")[2]
    if mlp_kind == "dense":
        x = x + L.mlp(params["mlp"], cfg, L.rmsnorm(params["ln2"], x, cfg.norm_eps))
    elif mlp_kind == "moe":
        y, _ = MOE.moe_mlp(params["moe"], cfg, L.rmsnorm(params["ln2"], x, cfg.norm_eps))
        x = x + y
    return x
