"""Top-level model API: init / loss forward / prefill / decode for every
assigned architecture family (decoder LM, hybrid, xLSTM, MoE, enc-dec, VLM
and audio backbones with stub frontends).

All depth traversal is ``jax.lax.scan`` over superblock-stacked parameters
(see ``blocks.py``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L

PyTree = Any


# ---------------------------------------------------------------------------
# init


def _stack_superblocks(keys, cfg: ModelConfig):
    """Returns a tuple (per pattern position) of superblock-stacked param dicts."""
    pattern = cfg.block_pattern
    per_pos = []
    for pos, kind in enumerate(pattern):
        blocks = [B.init_block(jax.random.fold_in(keys[i], pos), cfg, kind)
                  for i in range(cfg.num_superblocks)]
        per_pos.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks))
    return tuple(per_pos)


def init_params(cfg: ModelConfig, key) -> Dict:
    k_emb, k_blocks, k_enc = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.num_superblocks)
    p = {
        "emb": L.init_embeddings(k_emb, cfg),
        "blocks": _stack_superblocks(keys, cfg),
        "final_ln": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
        enc_blocks = [B.init_block(enc_keys[i], cfg, "attn+dense")
                      for i in range(cfg.num_encoder_layers)]
        p["encoder"] = {
            "blocks": (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc_blocks),),
            "final_ln": L.init_rmsnorm(cfg.d_model),
        }
    return p


# ---------------------------------------------------------------------------
# shared depth scan


def _seq_shard(cfg: ModelConfig, x):
    """Sequence-parallel activation constraint (cfg.seq_shard_activations)."""
    if cfg.seq_shard_activations is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(None, cfg.seq_shard_activations, None))


def _run_stack(blocks, cfg: ModelConfig, pattern, x, *, causal=True,
               enc_out=None, cache_len: int = 0):
    """Depth scan.  With ``cache_len > 0`` the scan additionally emits every
    block's decode cache (prefill handoff), stacked over superblocks —
    matching ``init_cache`` layout."""
    def superblock(carry, blkparams):
        x, aux = carry
        x = _seq_shard(cfg, x)
        caches = []
        for pos, kind in enumerate(pattern):
            x, a, c = B.apply_block(blkparams[pos], cfg, kind, x,
                                    causal=causal, enc_out=enc_out,
                                    cache_len=cache_len)
            aux = aux + a
            caches.append(c)
        return (x, aux), (tuple(caches) if cache_len else None)

    if cfg.remat:
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(superblock, policy=policy)
    else:
        body = superblock
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    blocks, unroll=True if cfg.unroll else 1)
    if cache_len:
        return x, aux, caches
    return x, aux


def _encode(params, cfg: ModelConfig, src_embeds):
    """Encoder over stub modality embeddings.  src_embeds: [B, S_enc, E_modal]."""
    x = src_embeds @ params["emb"]["modal_proj"]
    x, _ = _run_stack(params["encoder"]["blocks"], cfg, ("attn+dense",), x,
                      causal=False)
    return L.rmsnorm(params["encoder"]["final_ln"], x, cfg.norm_eps)


def _decoder_inputs(params, cfg: ModelConfig, batch: Dict):
    """Embed tokens, prepend projected modality tokens for VLM-style models."""
    x = L.embed(params["emb"], cfg, batch["tokens"])
    if cfg.modality == "vision":
        img = batch["modal_embeds"] @ params["emb"]["modal_proj"]
        x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
    return x


# ---------------------------------------------------------------------------
# training forward (full sequence -> mean NLL + aux)


def forward_loss(params, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    """batch: tokens [B,St], labels [B,St] (+ modal_embeds / src_embeds).

    Returns (scalar loss, metrics dict).
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["src_embeds"])
    x = _decoder_inputs(params, cfg, batch)
    x, aux = _run_stack(params["blocks"], cfg, cfg.block_pattern, x,
                        causal=True, enc_out=enc_out)
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    if cfg.modality == "vision":  # loss only over the text positions
        x = x[:, -batch["tokens"].shape[1]:, :]
    w_un = L.unembed_matrix(params["emb"], cfg)
    nll = L.chunked_softmax_xent(x, w_un, batch["labels"], cfg.loss_seq_chunk,
                                 batch.get("loss_mask"), unroll=cfg.unroll)
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}


def forward_hidden(params, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    """Prefill: final hidden states [B,S,D] (no loss) — serving prefill path."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["src_embeds"])
    x = _decoder_inputs(params, cfg, batch)
    x, _ = _run_stack(params["blocks"], cfg, cfg.block_pattern, x,
                      causal=True, enc_out=enc_out)
    return L.rmsnorm(params["final_ln"], x, cfg.norm_eps)


def prefill_with_cache(params, cfg: ModelConfig, batch: Dict,
                       cache_len: int) -> Tuple[jnp.ndarray, PyTree]:
    """Serving prefill that also writes the decode cache: returns
    (hidden [B,S,D], cache) where cache matches ``init_cache(cfg, B,
    cache_len)`` and decode can continue at pos = S."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["src_embeds"])
    x = _decoder_inputs(params, cfg, batch)
    x, _, cache = _run_stack(params["blocks"], cfg, cfg.block_pattern, x,
                             causal=True, enc_out=enc_out, cache_len=cache_len)
    return L.rmsnorm(params["final_ln"], x, cfg.norm_eps), cache


# ---------------------------------------------------------------------------
# decode


def init_cache(cfg: ModelConfig, batch: int, max_len: int, cp_shards: int = 1):
    """Stacked decode cache: tuple (per pattern position) of dicts whose leaves
    have leading axis num_superblocks."""
    caches = []
    for kind in cfg.block_pattern:
        one = B.init_block_cache(cfg, kind, batch, max_len, cp_shards)
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.num_superblocks,) + l.shape), one)
        caches.append(stacked)
    return tuple(caches)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                enc_out=None, axis_name: Optional[str] = None,
                shard_offset=None) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step.  tokens: [B,1] int32; pos: scalar int32 (current
    position).  Returns (logits [B, vocab], new_cache)."""
    x = L.embed(params["emb"], cfg, tokens)
    pattern = cfg.block_pattern

    def superblock(x, scanned):
        blkparams, cache_in = scanned
        new_caches = []
        for p_idx, kind in enumerate(pattern):
            x, nc = B.apply_block_decode(blkparams[p_idx], cfg, kind, x,
                                         cache_in[p_idx], pos, enc_out=enc_out,
                                         axis_name=axis_name,
                                         shard_offset=shard_offset)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(superblock, x, (params["blocks"], cache),
                                unroll=True if cfg.unroll else 1)
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = (x[:, 0, :] @ L.unembed_matrix(params["emb"], cfg)).astype(jnp.float32)
    return logits, new_cache


def encode_for_decode(params, cfg: ModelConfig, batch: Dict):
    """Encoder pass used once before decoding (enc-dec archs)."""
    if not cfg.is_encoder_decoder:
        return None
    return _encode(params, cfg, batch["src_embeds"])


def param_count(params) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
