"""The paper's own experiment models (Sec. V).

* Case I: a 3-fully-connected-layer classifier with one ReLU activation and a
  SoftMax output (as in [7]) for 10-digit recognition — smooth, non-convex.
* Case II: ridge regression — smooth and strongly convex (strong-convexity
  modulus M = lam + lambda_min(X^T X / D), smoothness L = lam +
  lambda_max(X^T X / D), both computable exactly for tests).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Case I model: 784 -> hidden -> hidden -> 10 MLP with a ReLU (paper's classifier)


def init_mlp_classifier(key, in_dim: int = 784, hidden: int = 64,
                        num_classes: int = 10) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2, s3 = (1 / math.sqrt(in_dim), 1 / math.sqrt(hidden), 1 / math.sqrt(hidden))
    return {
        "w1": jax.random.normal(k1, (in_dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * s2,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, num_classes)) * s3,
        "b3": jnp.zeros((num_classes,)),
    }


def mlp_classifier_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ params["w1"] + params["b1"]
    h = jax.nn.relu(h)
    h = h @ params["w2"] + params["b2"]
    return h @ params["w3"] + params["b3"]


def mlp_classifier_loss(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Softmax cross-entropy; y: [B] int labels."""
    logits = mlp_classifier_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def mlp_classifier_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_classifier_logits(params, x), -1) == y)
                    .astype(jnp.float32))


# ---------------------------------------------------------------------------
# Case II model: ridge regression


def init_ridge(key, dim: int) -> Dict:
    return {"w": jax.random.normal(key, (dim,)) * 0.1}


def ridge_loss(params: Dict, x: jnp.ndarray, y: jnp.ndarray, lam: float) -> jnp.ndarray:
    """(1/2B) ||X w - y||^2 + (lam/2) ||w||^2."""
    r = x @ params["w"] - y
    return 0.5 * jnp.mean(r * r) + 0.5 * lam * jnp.sum(params["w"] ** 2)


def ridge_constants(x_all: jnp.ndarray, lam: float) -> Tuple[float, float, float]:
    """Exact (L, M) smoothness/strong-convexity constants of the *global* ridge
    loss, plus the optimal loss value's hessian condition helper.

    Hessian = X^T X / D + lam I  ->  L = lmax + lam, M = lmin + lam.
    """
    h = (x_all.T @ x_all) / x_all.shape[0]
    eig = jnp.linalg.eigvalsh(h)
    return float(eig[-1] + lam), float(eig[0] + lam), float(eig[-1] / jnp.maximum(eig[0], 1e-12))


def ridge_optimum(x_all: jnp.ndarray, y_all: jnp.ndarray, lam: float) -> jnp.ndarray:
    """Closed-form global minimizer of the global ridge loss."""
    d = x_all.shape[1]
    a = x_all.T @ x_all / x_all.shape[0] + lam * jnp.eye(d)
    b = x_all.T @ y_all / x_all.shape[0]
    return jnp.linalg.solve(a, b)
