"""Top-k mixture-of-experts MLP with capacity-bounded scatter dispatch.

Dispatch is Megablocks-style-in-spirit but JAX-native: flatten the (token,
slot) pairs, compute each pair's position within its expert via a one-hot
cumsum, scatter tokens into an ``[E, C, D]`` buffer (C = capacity), run all
experts as one batched einsum (expert axis shards over the ``model`` mesh
axis — expert parallelism), and gather back with the router's combine
weights.  Tokens beyond capacity are dropped (standard capacity-factor
semantics); the aux load-balance loss keeps the router near-uniform so drops
stay rare.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "router": (jax.random.normal(k0, (d, e)) * 0.02).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (e, d, f)) / math.sqrt(d)).astype(dt),
        "w_up": (jax.random.normal(k2, (e, d, f)) / math.sqrt(d)).astype(dt),
        "w_down": (jax.random.normal(k3, (e, f, d)) / math.sqrt(f)
                   / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(num_tokens * cfg.experts_per_token / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8 (TPU lane-friendly)


def router_probs(params, x_flat):
    """x_flat: [T, D] -> probs [T, E] (router math in fp32, per common practice)."""
    logits = x_flat.astype(jnp.float32) @ params["router"]
    return jax.nn.softmax(logits, axis=-1), logits


def moe_mlp(params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, dict]:
    """x: [B,S,D] -> (y [B,S,D], aux {load_balance_loss, router_z_loss})."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    x_flat = x.reshape(t, d)

    probs, logits = router_probs(params, x_flat)                 # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                       # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize

    # --- aux losses (fp32) ---
    # load balance (Switch-style): E * sum_e (frac tokens to e) * (mean prob e)
    assign = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], top_e].set(1.0)                  # [T, E] multi-hot
    frac_tokens = jnp.mean(assign, axis=0) / k
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss}

    # --- dispatch ---
    cap = capacity(t, cfg)
    flat_e = top_e.reshape(t * k)                                # expert of each pair
    # position of each (token, slot) within its expert, in pair order
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)             # exclusive cumsum
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    # clip dropped pairs into slot cap-1 of a scratch row? scatter with drop mode:
    dest_e = jnp.where(keep, flat_e, 0)
    dest_c = jnp.where(keep, flat_pos, cap)                      # cap row index == drop
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[dest_e, dest_c].set(x_flat[tok_idx], mode="drop")
    buf = buf[:, :cap, :]                                        # [E, C, D]

    # --- expert compute (expert-parallel einsum over the leading E axis) ---
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y_buf = jnp.einsum("ecf,efd->ecd", act(gate) * up, params["w_down"])

    # --- combine ---
    y_pairs = y_buf[dest_e, jnp.minimum(dest_c, cap - 1)]        # [T*k, D]
    w_pairs = (top_p.reshape(t * k) * keep).astype(y_pairs.dtype)
    y_flat = jax.ops.segment_sum(y_pairs * w_pairs[:, None], tok_idx, num_segments=t)
    return y_flat.reshape(b, s, d), aux


def aux_loss(cfg: ModelConfig, aux: dict):
    return (cfg.router_aux_coef * aux["load_balance_loss"]
            + cfg.router_z_coef * aux["router_z_loss"])
