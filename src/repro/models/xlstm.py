"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) and recurrent sLSTM.

TPU adaptation notes (vs. arXiv:2405.04517):

* **mLSTM** is implemented in its chunkwise-parallel form — intra-chunk terms
  are an O(c^2) masked linear attention (MXU-friendly), inter-chunk terms flow
  through a carried matrix memory ``C`` [B,H,dh,dh] and normalizer ``n`` —
  so training is sub-quadratic in S and decode carries O(1) state.
* The paper's exponential input gate needs a running stabilizer ``m``; we use
  a *sigmoid* input gate (bounded, no stabilizer), which keeps every carried
  quantity in [0, 1]-geometric range.  This is a documented simplification
  (DESIGN.md §7); the structural properties the assignment exercises —
  matrix memory, per-head scalar gating, recurrent decode — are unchanged.
* **sLSTM** keeps the true non-parallel recurrence (jax.lax.scan over time)
  with the paper's exp input gate + max-stabilizer, and block-diagonal
  (per-head) recurrent weights.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, init_rmsnorm, rmsnorm


def _proj_factor() -> int:
    return 2  # up-projection factor of the xLSTM block (pf = 2)


def xlstm_inner_dim(cfg: ModelConfig) -> int:
    return _proj_factor() * cfg.d_model


# ---------------------------------------------------------------------------
# mLSTM


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    di = xlstm_inner_dim(cfg)
    h = cfg.num_heads
    dh = di // h
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    s = 1.0 / math.sqrt(di)
    return {
        "up": (jax.random.normal(ks[0], (d, 2 * di)) / math.sqrt(d)).astype(dt),
        "wq": (jax.random.normal(ks[1], (di, di)) * s).astype(dt),
        "wk": (jax.random.normal(ks[2], (di, di)) * s).astype(dt),
        "wv": (jax.random.normal(ks[3], (di, di)) * s).astype(dt),
        "wi": (jax.random.normal(ks[4], (di, h)) * s).astype(jnp.float32),
        "wf": (jax.random.normal(ks[5], (di, h)) * s).astype(jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # start with long memory
        "ogate": (jax.random.normal(ks[6], (di, di)) * s).astype(dt),
        "down": (jax.random.normal(ks[7], (di, d)) / math.sqrt(di)
                 / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def _mlstm_chunk(q, k, v, log_f, i_gate, carry):
    """One chunk of the mLSTM recurrence.

    q,k,v: [B,c,H,dh]; log_f,i_gate: [B,c,H]; carry = (C [B,H,dh,dh], n [B,H,dh]).
    Returns (h [B,c,H,dh], new_carry).  All fp32.
    """
    c_mem, n_mem = carry
    f_cum = jnp.cumsum(log_f, axis=1)                      # F_t (inclusive)
    decay_out = jnp.exp(f_cum)                             # [B,c,H]
    # intra-chunk pairwise decay: exp(F_t - F_j) * i_j  for j <= t
    df = f_cum[:, :, None, :] - f_cum[:, None, :, :]       # [B,t,j,H]
    tri = jnp.tril(jnp.ones((q.shape[1], q.shape[1]), bool))[None, :, :, None]
    w = jnp.where(tri, jnp.exp(df) * i_gate[:, None, :, :], 0.0)

    qk = jnp.einsum("bthd,bjhd->btjh", q, k)               # [B,t,j,H]
    h_intra = jnp.einsum("btjh,btjh,bjhd->bthd", qk, w, v)
    n_intra = jnp.einsum("btjh,bjhd->bthd", w, k)

    h_inter = decay_out[..., None] * jnp.einsum("bthd,bhde->bthe", q, c_mem)
    n_inter = decay_out[..., None] * n_mem[:, None]

    n_tot = n_intra + n_inter
    denom = jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_tot))
    h = (h_intra + h_inter) / jnp.maximum(denom, 1.0)[..., None]

    # carry update: everything decayed to the end of the chunk
    f_end = f_cum[:, -1][:, None]                          # [B,1,H]
    w_end = jnp.exp(f_end - f_cum) * i_gate                # [B,c,H]
    c_new = jnp.exp(f_end[:, 0])[..., None, None] * c_mem \
        + jnp.einsum("bch,bchd,bche->bhde", w_end, k, v)
    n_new = jnp.exp(f_end[:, 0])[..., None] * n_mem \
        + jnp.einsum("bch,bchd->bhd", w_end, k)
    return h, (c_new, n_new)


def mlstm_mix(params, cfg: ModelConfig, x, return_state: bool = False):
    """Full mLSTM block mixing: up-proj, chunkwise cell, output gate, down-proj."""
    b, s, d = x.shape
    di = xlstm_inner_dim(cfg)
    h_heads = cfg.num_heads
    dh = di // h_heads
    up = x @ params["up"]
    u, z = jnp.split(up, 2, axis=-1)                       # [B,S,di] each

    q = (u @ params["wq"]).reshape(b, s, h_heads, dh).astype(jnp.float32)
    k = (u @ params["wk"]).reshape(b, s, h_heads, dh).astype(jnp.float32) / math.sqrt(dh)
    v = (u @ params["wv"]).reshape(b, s, h_heads, dh).astype(jnp.float32)
    uf = u.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(uf @ params["wf"] + params["bf"])   # [B,S,H]
    i_gate = jax.nn.sigmoid(uf @ params["wi"] + params["bi"])      # sigmoid (see module doc)

    c = min(cfg.mlstm_chunk, s)
    if s % c != 0:
        c = s
    nc = s // c

    def split_chunks(a):
        return a.reshape((b, nc, c) + a.shape[2:]).transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))

    xs = tuple(map(split_chunks, (q, k, v, log_f, i_gate)))

    def step(carry, chunk):
        qc, kc, vc, lfc, igc = chunk
        h, new_carry = _mlstm_chunk(qc, kc, vc, lfc, igc, carry)
        return new_carry, h

    body = jax.checkpoint(step) if cfg.remat else step
    c0 = (jnp.zeros((b, h_heads, dh, dh), jnp.float32),
          jnp.zeros((b, h_heads, dh), jnp.float32))
    carry, hs = jax.lax.scan(body, c0, xs, unroll=True if cfg.unroll else 1)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, di).astype(x.dtype)
    h = h * jax.nn.sigmoid(u @ params["ogate"])
    out = (h * jax.nn.silu(z)) @ params["down"]
    if return_state:
        return out, carry      # (C, n)
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int, n_layers: int):
    di = xlstm_inner_dim(cfg)
    h, dh = cfg.num_heads, di // cfg.num_heads
    return {"C": jnp.zeros((n_layers, batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((n_layers, batch, h, dh), jnp.float32)}


def mlstm_decode_step(params, cfg: ModelConfig, x, c_mem, n_mem):
    """x: [B,1,D]; exact single-step recurrence.  Returns (y, C', n')."""
    b = x.shape[0]
    di = xlstm_inner_dim(cfg)
    hh, dh = cfg.num_heads, di // cfg.num_heads
    up = x @ params["up"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ params["wq"]).reshape(b, hh, dh).astype(jnp.float32)
    k = (u @ params["wk"]).reshape(b, hh, dh).astype(jnp.float32) / math.sqrt(dh)
    v = (u @ params["wv"]).reshape(b, hh, dh).astype(jnp.float32)
    uf = u[:, 0].astype(jnp.float32)
    f = jax.nn.sigmoid(uf @ params["wf"] + params["bf"])        # [B,H]
    i = jax.nn.sigmoid(uf @ params["wi"] + params["bi"])
    c_new = f[..., None, None] * c_mem + i[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = f[..., None] * n_mem + i[..., None] * k
    denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    h = jnp.einsum("bhd,bhde->bhe", q, c_new) / jnp.maximum(denom, 1.0)[..., None]
    h = h.reshape(b, 1, di).astype(x.dtype)
    h = h * jax.nn.sigmoid(u @ params["ogate"])
    y = (h * jax.nn.silu(z)) @ params["down"]
    return y, c_new, n_new


# ---------------------------------------------------------------------------
# sLSTM


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    di = xlstm_inner_dim(cfg)
    h = cfg.num_heads
    dh = di // h
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "up": (jax.random.normal(ks[0], (d, 2 * di)) / math.sqrt(d)).astype(dt),
        # input weights for the 4 gates (z, i, f, o) fused: [di, 4*di]
        "w_gates": (jax.random.normal(ks[1], (di, 4 * di)) / math.sqrt(di)).astype(dt),
        # block-diagonal recurrent weights, per head, per gate: [4, H, dh, dh]
        "r_gates": (jax.random.normal(ks[2], (4, h, dh, dh)) / math.sqrt(dh)).astype(jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((2 * di,)), jnp.full((di,), 3.0),
                                    jnp.zeros((di,))]).astype(jnp.float32),
        "down": (jax.random.normal(ks[3], (di, d)) / math.sqrt(di)
                 / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def _slstm_cell(params, cfg: ModelConfig, wx_t, state):
    """One timestep.  wx_t: [B, 4*di] precomputed input contribution.
    state: (c, n, h, m) each [B, di] fp32."""
    di = xlstm_inner_dim(cfg)
    hh = cfg.num_heads
    dh = di // hh
    c, n, h, m = state
    h_heads = h.reshape(-1, hh, dh)
    rec = jnp.einsum("bhd,ghde->bghe", h_heads, params["r_gates"])  # [B,4,H,dh]
    pre = wx_t.reshape(-1, 4, di) + rec.reshape(-1, 4, di) + params["b_gates"].reshape(4, di)
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)                  # stabilizer
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_mix(params, cfg: ModelConfig, x, return_state: bool = False):
    """x: [B,S,D] -> [B,S,D] via the true sequential recurrence."""
    b, s, d = x.shape
    di = xlstm_inner_dim(cfg)
    up = x @ params["up"]
    u, z_gate = jnp.split(up, 2, axis=-1)
    wx = (u @ params["w_gates"]).astype(jnp.float32)       # [B,S,4di]

    def step(state, wx_t):
        return _slstm_cell(params, cfg, wx_t, state)

    zeros = jnp.zeros((b, di), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((b, di), -1e30, jnp.float32))
    final_state, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = (h * jax.nn.silu(z_gate)) @ params["down"]
    if return_state:
        return out, final_state
    return out


def init_slstm_cache(cfg: ModelConfig, batch: int, n_layers: int):
    di = xlstm_inner_dim(cfg)
    zeros = jnp.zeros((n_layers, batch, di), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((n_layers, batch, di), -1e30, jnp.float32)}


def slstm_decode_step(params, cfg: ModelConfig, x, state):
    """x: [B,1,D]; state tuple of [B,di].  Returns (y, new_state)."""
    up = x @ params["up"]
    u, z_gate = jnp.split(up, 2, axis=-1)
    wx = (u[:, 0] @ params["w_gates"]).astype(jnp.float32)
    new_state, h = _slstm_cell(params, cfg, wx, state)
    h = h[:, None, :].astype(x.dtype)
    y = (h * jax.nn.silu(z_gate)) @ params["down"]
    return y, new_state
