"""Mamba (S6) selective-state-space block, TPU-adapted.

The CUDA reference fuses the selective scan into one kernel with shared-memory
chunking; the TPU-native adaptation here is a *chunked associative scan*:
within a chunk the recurrence ``h_t = A_t h_{t-1} + B_t x_t`` (A_t diagonal)
runs as ``jax.lax.associative_scan`` (log-depth, maps onto the VPU), and a
``jax.lax.scan`` carries the [B, D_inner, N] state across chunks so the
[B, S, D_inner, N] intermediate never exists at full sequence length — the
same working-set discipline the GPU kernel achieves with SRAM tiling.

Decode is the exact O(1)-state single-step recurrence.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    r = cfg.mamba_dt_rank
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    # S4D-real initialization for A (negative real spectrum).
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di)) /
                   math.sqrt(cfg.mamba_d_conv)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * n)) / math.sqrt(di)).astype(dt),
        "dt_proj_w": (jax.random.normal(ks[3], (r, di)) * (r ** -0.5)).astype(dt),
        "dt_proj_b": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) / math.sqrt(di)
                     / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def _ssm_inputs(params, cfg: ModelConfig, u):
    """u: [B, C, Di] conv+silu activations -> (dA [B,C,Di,N], dBu, C_mat [B,C,N])."""
    n, r = cfg.mamba_d_state, cfg.mamba_dt_rank
    proj = u @ params["x_proj"]                                  # [B,C,r+2N]
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus((dt_r @ params["dt_proj_w"]).astype(jnp.float32)
                         + params["dt_proj_b"])                  # [B,C,Di]
    a = -jnp.exp(params["A_log"])                                # [Di,N]
    da = jnp.exp(dt[..., None] * a[None, None])                  # [B,C,Di,N]
    dbu = (dt * u.astype(jnp.float32))[..., :, None] * b_mat.astype(jnp.float32)[..., None, :]
    return da, dbu, c_mat.astype(jnp.float32)


def _chunk_scan(carry_h, da, dbu):
    """Associative scan of h_t = da_t * h_{t-1} + dbu_t within one chunk.

    carry_h: [B, Di, N]; da/dbu: [B, C, Di, N].  Returns (h_all [B,C,Di,N], h_last).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbu), axis=1)
    h_all = a_cum * carry_h[:, None] + b_cum
    return h_all, h_all[:, -1]


def mamba_mix(params, cfg: ModelConfig, x, chunk: int = None,
              return_state: bool = False):
    """Full-sequence Mamba mixing.  x: [B,S,D] -> [B,S,D].

    ``return_state=True`` additionally returns (ssm_state [B,Di,N],
    conv_tail [B,K-1,Di]) for prefill->decode handoff."""
    chunk = chunk or cfg.mamba_chunk
    b, s, d = x.shape
    di, n = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                             # [B,S,Di] each

    # depthwise causal conv, kernel K: pad left K-1
    kk = cfg.mamba_d_conv
    u_pad = jnp.pad(u, ((0, 0), (kk - 1, 0), (0, 0)))
    u_conv = sum(u_pad[:, i:i + s, :] * params["conv_w"][i] for i in range(kk))
    u_conv = jax.nn.silu(u_conv + params["conv_b"])

    if cfg.mamba_shard_channels is not None:
        from jax.sharding import PartitionSpec as P
        u_conv = jax.lax.with_sharding_constraint(
            u_conv, P(None, None, cfg.mamba_shard_channels))

    c = min(chunk, s)
    if s % c != 0:
        c = s
    nc = s // c
    u_chunks = u_conv.reshape(b, nc, c, di).transpose(1, 0, 2, 3)  # [nc,B,C,Di]

    scan_dt = jnp.dtype(cfg.mamba_scan_dtype)

    def step(h, u_c):
        da, dbu, c_mat = _ssm_inputs(params, cfg, u_c)
        h_all, h_last = _chunk_scan(h.astype(scan_dt), da.astype(scan_dt),
                                    dbu.astype(scan_dt))
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_mat.astype(scan_dt))
        return h_last.astype(jnp.float32), y.astype(jnp.float32)

    body = jax.checkpoint(step) if cfg.remat else step
    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, u_chunks, unroll=True if cfg.unroll else 1)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + u_conv.astype(jnp.float32) * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        # decode's conv_state holds the last K-1 PRE-conv inputs u
        conv_tail = u[:, -(kk - 1):, :]
        return out, (h_last, conv_tail)
    return out


# --- decode ---------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch: int, n_layers: int):
    di, n, kk = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "ssm": jnp.zeros((n_layers, batch, di, n), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, kk - 1, di), dtype_of(cfg)),
    }


def mamba_decode_step(params, cfg: ModelConfig, x, ssm_state, conv_state
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B,1,D]; ssm_state: [B,Di,N]; conv_state: [B,K-1,Di]."""
    b = x.shape[0]
    di, n, kk = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                             # [B,1,Di]

    window = jnp.concatenate([conv_state, u], axis=1)            # [B,K,Di]
    u_conv = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    u_conv = jax.nn.silu(u_conv)[:, None, :]                     # [B,1,Di]
    new_conv = window[:, 1:, :]

    da, dbu, c_mat = _ssm_inputs(params, cfg, u_conv)            # [B,1,Di,N]
    h = da[:, 0] * ssm_state + dbu[:, 0]                         # [B,Di,N]
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None, :]
    y = y + u_conv.astype(jnp.float32) * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], h, new_conv
