"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window / decode), SwiGLU MLP, embeddings.

Functional style: ``init_*`` builds parameter dicts, ``apply``-style functions
are pure.  Attention is *query-chunked* (blockwise over the query axis with a
rematerialized scan) so that 32k-sequence prefill never materializes an
S x S score matrix — the pure-JAX analogue of the Pallas flash kernel in
``repro.kernels.flash_attention`` (which is the TPU hot-path implementation;
this path is what the dry-run lowers).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def loop_map(f, xs, unroll: bool = False):
    """jax.lax.map with an unroll switch (analysis mode: true op counts)."""
    _, ys = jax.lax.scan(lambda c, x: (c, f(x)), None, xs,
                         unroll=True if unroll else 1)
    return ys


# ---------------------------------------------------------------------------
# norms


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)           # [Dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., :, None, :]                 # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    scale = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * scale).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * scale).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * scale).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * scale / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    return p


def _project_qkv(params, cfg: ModelConfig, x, kv_x=None):
    """Returns q [B,S,H,Dh], k/v [B,Skv,Hkv,Dh]."""
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _expand_kv(cfg: ModelConfig, k):
    """[B,S,Hkv,Dh] -> [B,S,H,Dh] by repeating each kv head q_per_kv times."""
    if cfg.q_per_kv == 1:
        return k
    return jnp.repeat(k, cfg.q_per_kv, axis=2)


def _attend_chunk(q, k, v, bias, softcap: Optional[float]):
    """q: [B,C,H,Dh], k/v: [B,Skv,H,Dh], bias: [C,Skv] additive mask."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + bias[None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive mask bias [len(q_pos), len(k_pos)] in fp32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(params, cfg: ModelConfig, x, *, causal: bool = True,
              positions=None, kv_x=None, kv_positions=None,
              window: Optional[int] = None, return_kv: bool = False):
    """Full-sequence attention, query-chunked.  x: [B,S,D] -> [B,S,D].

    ``return_kv=True`` additionally returns the (rope'd, unexpanded) k/v for
    prefill->decode cache handoff.
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    skv = k.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    if kv_positions is None:
        kv_positions = positions if kv_x is None else jnp.arange(skv)
    if kv_x is None:  # self-attention: rope on q and k
        q = apply_rope(q, jnp.broadcast_to(positions, (s,)), cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(kv_positions, (skv,)), cfg.rope_theta)
    kv_for_cache = (k, v) if return_kv else None
    k = _expand_kv(cfg, k)
    v = _expand_kv(cfg, v)

    cq = min(cfg.attn_q_chunk, s)
    if s % cq != 0:
        cq = s  # fall back to single chunk for ragged smoke shapes
    n_chunks = s // cq
    q = q.reshape(b, n_chunks, cq, cfg.num_heads, cfg.head_dim)
    qpos = jnp.asarray(positions).reshape(n_chunks, cq)

    def one_chunk(args):
        qc, qp = args
        bias = _mask_bias(qp, kv_positions, causal, window)
        return _attend_chunk(qc, k, v, bias, cfg.attn_logit_softcap)

    body = jax.checkpoint(one_chunk) if cfg.remat else one_chunk
    out = loop_map(body, (q.transpose(1, 0, 2, 3, 4), qpos), unroll=cfg.unroll)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, cfg.num_heads * cfg.head_dim)
    out = out @ params["wo"]
    if return_kv:
        return out, kv_for_cache
    return out


def prefill_kv_cache(cfg: ModelConfig, k, v, seq_len: int, cache_len: int):
    """Arrange prefill k/v [B,S,Hkv,dh] into the decode cache layout.

    Full-attention: left-aligned, zero-padded to cache_len.  Sliding window:
    rotating buffer where slot i holds the latest position p < S with
    p % W == i — exactly what decode_attention's slot arithmetic expects.
    """
    b, s, hkv, dh = k.shape
    if cfg.sliding_window:
        w = min(cache_len, cfg.sliding_window)
        slots = jnp.arange(w)
        # latest p < s with p % w == slot
        p = s - 1 - ((s - 1 - slots) % w)
        ck = jnp.take(k, p, axis=1)
        cv = jnp.take(v, p, axis=1)
        # positions p < 0 impossible when s >= w; for s < w zero out unused
        valid = (p >= 0) & (p < s)
        ck = jnp.where(valid[None, :, None, None], ck, 0)
        cv = jnp.where(valid[None, :, None, None], cv, 0)
        return ck, cv
    pad = cache_len - s
    if pad > 0:
        zeros = jnp.zeros((b, pad, hkv, dh), k.dtype)
        return (jnp.concatenate([k, zeros], axis=1),
                jnp.concatenate([v, zeros], axis=1))
    return k[:, :cache_len], v[:, :cache_len]


# --- decode path -----------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=None):
    """Stacked KV cache for the scanned layer stack: [L, B, S, Hkv, Dh]."""
    dt = dtype or dtype_of(cfg)
    window = cfg.sliding_window
    s = min(max_len, window) if window else max_len
    shape = (n_layers, batch, s, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attention(params, cfg: ModelConfig, x, cache_k, cache_v, pos,
                     *, window: Optional[int] = None, axis_name: Optional[str] = None,
                     shard_offset=None):
    """Single-token decode.  x: [B,1,D]; cache_k/v: [B,Scache,Hkv,Dh]; pos: scalar
    current position.  Returns (out [B,1,D], new_k, new_v).

    With ``window`` set, the cache is a rotating buffer of length window and the
    slot is ``pos % window``.  With ``axis_name`` set, the cache *sequence* axis
    is sharded across that mesh axis (context-parallel decode): each shard
    attends over its local slice and partial results merge with a shifted-
    softmax (flash-decoding) ``psum``; ``shard_offset`` gives the global
    position of this shard's first cache slot.
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, cfg, x)
    posv = jnp.full((1,), pos)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    s_cache = cache_k.shape[1]
    if window:
        slot = pos % s_cache
    else:
        slot = pos

    if axis_name is None:
        if cfg.decode_cache_update == "select":
            # masked full-cache write: shardable across a seq-sharded cache
            # (no cross-shard dynamic_update_slice -> no GSPMD gathers)
            sel = (jnp.arange(s_cache) == slot)[None, :, None, None]
            cache_k = jnp.where(sel, k_new.astype(cache_k.dtype), cache_k)
            cache_v = jnp.where(sel, v_new.astype(cache_v.dtype), cache_v)
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
        kpos = jnp.arange(s_cache)
        if window:
            # rotating buffer: slot i holds the latest position p with p % W == i
            kpos = jnp.where(kpos <= slot, pos - slot + kpos, pos - slot - s_cache + kpos)
        valid = (kpos >= 0) & (kpos <= pos)
        if window:
            valid = valid & (pos - kpos < window)
        bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
        k = _expand_kv(cfg, cache_k)
        v = _expand_kv(cfg, cache_v)
        if cfg.decode_cache_seq_axis is not None:
            # Flash-decoding sharding, pinned at the decisive tensor: the
            # SCORES must be sharded over the cache's seq dim (the softmax
            # reductions are then small psums and the o-contraction one small
            # all-reduce).  Pinning q or the cache is NOT enough — GSPMD still
            # picks head-sharded scores and all-gathers the multi-GB cache
            # (both tried and refuted — EXPERIMENTS.md §Perf).
            from jax.sharding import PartitionSpec as SP
            ax = cfg.decode_cache_seq_axis
            scale = 1.0 / math.sqrt(q.shape[-1])
            scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                                k.astype(jnp.float32)) * scale
            if cfg.attn_logit_softcap is not None:
                scores = cfg.attn_logit_softcap * jnp.tanh(
                    scores / cfg.attn_logit_softcap)
            scores = scores + bias[None, None, :, :]
            scores = jax.lax.with_sharding_constraint(
                scores, SP(None, None, None, ax))
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        else:
            out = _attend_chunk(q, k, v, bias, cfg.attn_logit_softcap)
    else:
        # context-parallel: each shard owns cache slots [offset, offset + s_cache)
        in_shard = (slot >= shard_offset) & (slot < shard_offset + s_cache)
        local_slot = jnp.clip(slot - shard_offset, 0, s_cache - 1)
        upd_k = jnp.where(in_shard, k_new, jax.lax.dynamic_slice_in_dim(cache_k, local_slot, 1, 1))
        upd_v = jnp.where(in_shard, v_new, jax.lax.dynamic_slice_in_dim(cache_v, local_slot, 1, 1))
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, upd_k, local_slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, upd_v, local_slot, axis=1)
        kpos = shard_offset + jnp.arange(s_cache)
        valid = kpos <= pos
        bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
        k = _expand_kv(cfg, cache_k)
        v = _expand_kv(cfg, cache_v)
        # local flash partials
        scale = 1.0 / math.sqrt(q.shape[-1])
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale + bias[None, None]
        m_loc = jnp.max(scores, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, axis_name)
        p = jnp.exp(scores - m_glob)
        num = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
        den = jnp.sum(p, axis=-1)[..., None].transpose(0, 2, 1, 3)  # [B,1,H,1]
        num = jax.lax.psum(num.astype(jnp.float32), axis_name)
        den = jax.lax.psum(den.astype(jnp.float32), axis_name)
        out = (num / jnp.maximum(den, 1e-30)).astype(x.dtype)

    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) / math.sqrt(d)).astype(dt),
        "w_up": (jax.random.normal(k2, (d, f)) / math.sqrt(d)).astype(dt),
        "w_down": (jax.random.normal(k3, (f, d)) / math.sqrt(f)
                   / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def mlp(params, cfg: ModelConfig, x):
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    return (act(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# embeddings / unembedding


def init_embeddings(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unemb"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                      / math.sqrt(cfg.d_model)).astype(dt)
    if cfg.modality:
        p["modal_proj"] = (jax.random.normal(k3, (cfg.modal_embed_dim, cfg.d_model))
                           / math.sqrt(cfg.modal_embed_dim)).astype(dt)
    return p


def embed(params, cfg: ModelConfig, tokens):
    return params["tok"][tokens]


def unembed_matrix(params, cfg: ModelConfig):
    return params["tok"].T if cfg.tie_embeddings else params["unemb"]


def chunked_softmax_xent(x, w_unemb, labels, chunk: int, mask=None,
                         unroll: bool = False):
    """Next-token cross-entropy without materializing [B,S,V] logits.

    x: [B,S,D] final hidden states; labels: [B,S] int32; returns mean nll.
    Scans over sequence chunks; each chunk's [B,c,V] logits live transiently
    (rematerialized in backward).
    """
    b, s, d = x.shape
    c = min(chunk, s)
    if s % c != 0:
        c = s
    n = s // c
    xc = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, c).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mc = mask.reshape(b, n, c).transpose(1, 0, 2)

    def one(args):
        xx, ll, mm = args
        logits = (xx @ w_unemb).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mm), jnp.sum(mm)

    body = jax.checkpoint(one)
    nll, cnt = loop_map(body, (xc, lc, mc), unroll=unroll)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(cnt), 1.0)
