"""Roofline-term extraction from compiled dry-run artifacts (deliverable (g)).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed of the SPMD-
partitioned (per-chip) module, so dividing by per-chip peaks is equivalent to
the global form above.  collective bytes are NOT in cost_analysis: we parse
the post-optimization HLO and sum the *output* sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute (per-chip
shapes; output-bytes is the standard per-op traffic proxy — an all-reduce of
``n`` bytes moves ~2n across the ring, an all-gather's output *is* the moved
buffer; we report raw output bytes and keep the convention fixed across every
experiment so deltas are meaningful).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

# v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one `dtype[d0,d1,...]` shape token
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# an HLO def line whose op is a collective:  %name = <output-type> <op>(
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per chip) summed over the module.
    ``-done`` ops are skipped so async start/done pairs count once."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: int
    coll_breakdown: Dict[str, int]
    model_flops: float                 # 6*N*D (train) or 2*N_active*tokens (inference)
    memory_analysis: str = ""
    # derived
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.flops_per_chip / PEAK_FLOPS
        self.memory_s = self.bytes_per_chip / HBM_BW
        self.collective_s = self.coll_bytes_per_chip / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo_flops = self.flops_per_chip * self.chips
        self.useful_flops_ratio = (self.model_flops / total_hlo_flops
                                   if total_hlo_flops else 0.0)
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(name: str, compiled, chips: int, model_flops: float,
            hlo_text: Optional[str] = None) -> RooflineReport:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    try:
        mem = str(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover - backend-dependent
        mem = f"<unavailable: {e}>"
    rep = RooflineReport(
        name=name, chips=chips, flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=sum(coll.values()), coll_breakdown=coll,
        model_flops=model_flops, memory_analysis=mem)
    return rep.finalize()


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference; D = tokens."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_params_active * shape.global_batch


def save_report(path: str, reports) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() if isinstance(r, RooflineReport) else r
                   for r in reports], f, indent=2)
