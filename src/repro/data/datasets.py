"""Data substrate: synthetic task generators + federated (non-IID) partitioner.

No network access in this container, so the paper's MNIST task is reproduced
with a *synthetic MNIST-like* generator (class-conditional Gaussian digit
blobs, 28x28) — see DESIGN.md §7.  Trend/ordering claims, not absolute
accuracy numbers, are the reproduction target; the exact theory is validated
on ridge regression where the constants are computable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# synthetic MNIST-like classification task (Case I)


def synthetic_mnist(key, num_examples: int, num_classes: int = 10,
                    side: int = 28, noise: float = 0.35):
    """Class-conditional images: each class is a fixed random smooth template
    plus per-example Gaussian noise.  Linearly non-separable at this noise
    level, so the MLP's non-convexity matters."""
    k_tmpl, k_lab, k_noise = jax.random.split(key, 3)
    base = jax.random.normal(k_tmpl, (num_classes, side * side))
    # smooth the templates a little so nearby pixels correlate (image-like)
    tmpl = base.reshape(num_classes, side, side)
    kernel = jnp.ones((3, 3)) / 9.0
    tmpl = jax.scipy.signal.convolve2d if False else tmpl  # keep jnp-only
    for _ in range(2):
        pad = jnp.pad(tmpl, ((0, 0), (1, 1), (1, 1)), mode="edge")
        tmpl = sum(pad[:, i:i + side, j:j + side] for i in range(3) for j in range(3)) / 9.0
    tmpl = tmpl.reshape(num_classes, side * side)
    labels = jax.random.randint(k_lab, (num_examples,), 0, num_classes)
    x = tmpl[labels] + noise * jax.random.normal(k_noise, (num_examples, side * side))
    return x, labels


# ---------------------------------------------------------------------------
# ridge regression task (Case II)


def ridge_data(key, num_examples: int, dim: int, noise: float = 0.05):
    k_w, k_x, k_n = jax.random.split(key, 3)
    w_true = jax.random.normal(k_w, (dim,))
    x = jax.random.normal(k_x, (num_examples, dim))
    y = x @ w_true + noise * jax.random.normal(k_n, (num_examples,))
    return x, y, w_true


# ---------------------------------------------------------------------------
# synthetic token streams (for transformer FL / throughput examples)


def token_stream(key, num_sequences: int, seq_len: int, vocab: int):
    """Markov-ish synthetic tokens so loss can actually decrease."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (num_sequences, 1), 0, vocab)
    steps = jax.random.randint(k2, (num_sequences, seq_len - 1), 0, 17)
    toks = jnp.cumsum(jnp.concatenate([start, steps], axis=1), axis=1) % vocab
    return toks.astype(jnp.int32)


# ---------------------------------------------------------------------------
# federated partitioner


@dataclasses.dataclass(frozen=True)
class FederatedSplit:
    """Per-device index sets (variable sizes => the paper's D_k / D_A weights)."""
    indices: Tuple[np.ndarray, ...]

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(i) for i in self.indices])

    def weights(self) -> np.ndarray:
        s = self.sizes
        return s / s.sum()


def split_iid(key, num_examples: int, num_devices: int) -> FederatedSplit:
    perm = np.asarray(jax.random.permutation(key, num_examples))
    return FederatedSplit(tuple(np.sort(p) for p in np.array_split(perm, num_devices)))


def split_dirichlet(key, labels: np.ndarray, num_devices: int,
                    alpha: float = 0.5) -> FederatedSplit:
    """Label-skewed non-IID split (Dirichlet over class proportions) — the
    statistical heterogeneity the paper's Assumption 5 bounds."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    dev_idx: List[List[int]] = [[] for _ in range(num_devices)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_devices)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for d, part in enumerate(np.split(idx, cuts)):
            dev_idx[d].extend(part.tolist())
    # guarantee every device has at least one example
    for d in range(num_devices):
        if not dev_idx[d]:
            donor = int(np.argmax([len(x) for x in dev_idx]))
            dev_idx[d].append(dev_idx[donor].pop())
    return FederatedSplit(tuple(np.sort(np.array(d, dtype=np.int64)) for d in dev_idx))


@functools.partial(jax.jit, static_argnames=("batch_size", "num_devices"))
def _round_choices(key, round_idx, sizes, batch_size: int, num_devices: int):
    """[K, batch_size] per-device draws in [0, size_k) — the K-device sampling
    of one round as ONE dispatch (the per-device fold_in/randint loop used to
    cost ~4K host round-trips per round, which dominated the FL round loop)."""
    base = jax.random.fold_in(key, round_idx)
    keys = jax.vmap(lambda d: jax.random.fold_in(base, d))(
        jnp.arange(num_devices))
    return jax.vmap(
        lambda kk, n: jax.random.randint(kk, (batch_size,), 0, n))(keys, sizes)


def device_batches(key, split: FederatedSplit, batch_size: int, round_idx: int
                   ) -> np.ndarray:
    """[K, batch_size] example indices for one round (per-device sampling
    with replacement when a shard is smaller than the batch).

    Bit-identical to the historical per-device loop
    ``randint(fold_in(fold_in(key, round), k), (B,), 0, len(idx_k))`` but
    batched over devices into a single jitted call."""
    k = len(split.indices)
    choices = np.asarray(_round_choices(
        key, round_idx, jnp.asarray(split.sizes), batch_size, k))
    return np.stack([idx[choices[d]] for d, idx in enumerate(split.indices)])


def device_batches_many(key, split: FederatedSplit, batch_size: int,
                        rounds) -> np.ndarray:
    """[T, K, batch_size] example indices for a whole chunk of rounds in one
    jitted dispatch — the scan engine's data path (``device_batches`` for
    each round of ``rounds``, bit-identical, without T separate host
    round-trips)."""
    rounds = jnp.asarray(rounds, jnp.int32)
    k = len(split.indices)
    choices = np.asarray(jax.vmap(
        lambda t: _round_choices(key, t, jnp.asarray(split.sizes),
                                 batch_size, k))(rounds))
    return np.stack([idx[choices[:, d]] for d, idx in
                     enumerate(split.indices)], axis=1)
