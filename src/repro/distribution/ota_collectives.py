"""The paper's aggregation as a first-class mesh collective: ``ota_psum`` —
the *mesh backend* of ``repro.core.ota.aggregate``.

Inside a ``jax.shard_map`` whose *manual* axes are the FL-client axes
(('data',) on one pod; ('pod',) or ('pod','data') across pods), each shard
plays one mobile device of the paper's system:

    g_k  --scheme transform-->  x_k * h_k b_k  --[psum over client axes]-->  *a, +a z

The single ``psum`` *is* the over-the-air superposition (DESIGN.md §2): the
paper's method costs exactly the same collective bytes as a standard
data-parallel all-reduce, plus two scalar psums for the norm bookkeeping —
which the roofline table in EXPERIMENTS.md confirms.

Since the registry refactor this module contains NO scheme math: the
device-side transform, side-info spec, and server post-transform all come
from ``repro.core.schemes``, with ``h_k b_k`` folded into the per-device
scale so the psum needs no second pass.  Adding a scheme to the registry
makes it available here unchanged.

The channel noise ``a*z`` is added *after* the psum from a key that is
replicated across shards, so every client computes the identical server-side
result (model replicas stay bitwise in sync, as Step 3 "Broadcast" requires);
the per-leaf key schedule is shared with the other backends
(``schemes.add_channel_noise``), which is what makes noisy three-way parity
exact.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schemes

PyTree = Any
_EPS = schemes.EPS


# ---------------------------------------------------------------------------
# sharded-streaming combine (FLConfig.device_mesh / OTAConfig.device_mesh)
#
# The sharded streaming engine closes eq. (10) across mesh shards by folding
# D per-shard accumulator partials into one total.  fp32 addition is not
# associative, so the fold ORDER is part of the math spec: both execution
# paths — shard_map on a physical mesh and the emulated outer lax.scan —
# must reduce through the SAME deterministic left fold below, which is what
# makes them bitwise-identical (tests/test_sharded_streaming.py).  A plain
# ``psum``/``jnp.sum`` would let XLA pick its own reduction tree and the two
# paths drift by ulps that compound over rounds.


def fold_shards(stacked: PyTree, op=None) -> PyTree:
    """Deterministic left fold of a stacked pytree over its leading (shard)
    axis: ``((s_0 op s_1) op s_2) op ...`` per leaf.  ``op`` defaults to
    ``jax.lax.add``; pass ``jax.lax.min``/``max`` for order-free diagnostics
    (kept on the same code path so the combine stays single-sourced).  The
    leading axis must be a static (trace-time) size."""
    if op is None:
        op = jax.lax.add

    def one(leaf):
        return functools.reduce(op, [leaf[d] for d in range(leaf.shape[0])])

    return jax.tree_util.tree_map(one, stacked)


def gather_shards(tree: PyTree, axis_name: str) -> PyTree:
    """``all_gather`` every leaf of a shard-local pytree over ``axis_name``
    (new leading axis = shard index, mesh order).  Pairs with
    ``fold_shards``: gather-then-fold inside ``shard_map`` is the sharded
    engine's ONE cross-shard collective — it reduces the same bytes a psum
    would, but with the fold order pinned by ``fold_shards`` instead of
    XLA's reduction tree."""
    return jax.tree_util.tree_map(
        lambda l: jax.lax.all_gather(l, axis_name, axis=0), tree)


def client_index(axis_names: Sequence[str]) -> jax.Array:
    """Flat FL-client index of this shard over the manual aggregation axes."""
    idx = jnp.zeros((), jnp.int32)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def tree_sq_norm(tree: PyTree) -> jax.Array:
    """Squared global L2 norm of a (per-shard) gradient pytree, fp32
    accumulation.  Public: the mesh train step's grad-norm metric
    (``repro.launch.train``) and any shard-local diagnostics reduce through
    this one helper."""
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
               for l in jax.tree_util.tree_leaves(tree))


# back-compat alias for pre-promotion callers
_tree_sq_norm = tree_sq_norm


def _psum_tree(tree: PyTree, axes) -> PyTree:
    return jax.tree_util.tree_map(lambda l: jax.lax.psum(l, axes), tree)


def _scale_tree(tree: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda l: (l.astype(jnp.float32) * s), tree)


def _local_stats_kernels(grads: PyTree, sch) -> "schemes.DeviceStats":
    """Per-shard statistics via the blocked Pallas reduction instead of plain
    jnp — the HBM-bound part of each client's transform on the fused kernel
    (``repro.kernels``); used when the mesh train step opts into
    ``stats_impl='kernels'`` (the default in ``repro.launch.train``)."""
    from repro.kernels import ops as kops
    leaves = jax.tree_util.tree_leaves(grads)
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(1, -1) for l in leaves], axis=1)
    sumsq, total = kops.batched_moments(flat)
    tensor_sq = None
    if sch.per_tensor:
        tensor_sq = tuple(
            kops.batched_moments(l.astype(jnp.float32).reshape(1, -1))[0][0]
            for l in leaves)
    return schemes.DeviceStats(
        count=flat.shape[1], sq_norm=sumsq[0],
        total=total[0] if sch.needs_moments else None,
        tensor_sq_norms=tensor_sq)


def ota_psum(grads: PyTree, *, scheme: str, axes: Tuple[str, ...],
             h: jax.Array, b: jax.Array, a: float, noise_var: float,
             key: Optional[jax.Array] = None,
             grad_bound: Optional[float] = None,
             reduce_dtype=None, stats_impl: str = "jnp",
             h_hat: Optional[jax.Array] = None) -> PyTree:
    """Aggregate this shard's gradient with every other FL client's, over the
    air.  ``h``/``b`` are the full [K] per-client arrays (replicated); each
    shard selects its own coefficient by mesh position.  ``h_hat`` is the
    server's CSI estimate (None = perfect): the TRUE ``h`` rides the psum
    (the air), the estimate weighs the server-side side-info fold.

    Returns the server-side update direction y (identical on all clients).
    """
    # same validation as OTAConfig.__post_init__ — a silent grad_bound=None
    # here used to reach benchmark1's division and produce NaNs
    sch = schemes.validate_config(scheme, grad_bound)
    if stats_impl not in ("jnp", "kernels"):
        raise ValueError(f"unknown stats_impl {stats_impl!r}")

    if sch.baseline:
        k_total = 1
        for ax in axes:
            k_total *= jax.lax.axis_size(ax)
        return _psum_tree(_scale_tree(grads, 1.0 / k_total), axes)

    me = client_index(axes)
    hk = h[me].astype(jnp.float32)
    bk = b[me].astype(jnp.float32)
    hk_hat = hk if h_hat is None else h_hat[me].astype(jnp.float32)

    stats = (_local_stats_kernels(grads, sch) if stats_impl == "kernels"
             else schemes.compute_stats(grads, sch, batched=False))
    # h_k b_k folds into the per-device scale: the psum below IS eq. (10)
    x = schemes.transform(sch, grads, stats, grad_bound, batched=False,
                          extra_scale=hk * bk, out_dtype=jnp.float32)

    if reduce_dtype is not None:
        # beyond-paper §Perf lever: superpose in bf16 (halves the gradient
        # collective bytes; the analog channel would quantize far more
        # coarsely than bf16 anyway, so fidelity-wise this is still above
        # the paper's operating point).  Norms/side-info stay fp32.
        x = jax.tree_util.tree_map(lambda l: l.astype(reduce_dtype), x)
    y = _psum_tree(x, axes)                       # <-- the over-the-air superposition
    y = jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), y)
    if key is not None and noise_var > 0.0:
        y = schemes.add_channel_noise(y, key, noise_var)  # z added once, pre-gain
    y = _scale_tree(y, jnp.asarray(a, jnp.float32))

    if sch.server_post is not None:
        folded = {}
        if sch.collect_side is not None:
            side = sch.collect_side(stats)
            sum_hb = jax.lax.psum(hk_hat * bk, axes)
            folded = schemes.fold_side(
                side, lambda v: jax.lax.psum(hk_hat * bk * v, axes)
                / (sum_hb + _EPS))
        y = sch.server_post(y, folded)
    return y


def aggregate_mesh(cfg, stacked_grads: PyTree, h: jax.Array, b: jax.Array,
                   key: Optional[jax.Array] = None,
                   h_hat: Optional[jax.Array] = None) -> PyTree:
    """The mesh backend behind ``core.ota.aggregate``: scatter a *stacked*
    [K, ...] gradient pytree over a 1-D mesh of local devices (one shard per
    FL client) and run ``ota_psum``.

    Needs >= K addressable devices (force them on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K``)."""
    from jax.sharding import PartitionSpec as P

    leaves = jax.tree_util.tree_leaves(stacked_grads)
    k = leaves[0].shape[0]
    devs = jax.devices()
    if len(devs) < k:
        raise ValueError(
            f"mesh backend needs >= {k} local devices for {k} FL clients, "
            f"have {len(devs)}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count or use the "
            "'vmap'/'kernels' backend")
    mesh = jax.sharding.Mesh(np.asarray(devs[:k]), ("ota_clients",))
    use_noise = (key is not None and not cfg.noiseless and cfg.noise_var > 0.0)
    key_arr = key if use_noise else jax.random.PRNGKey(0)

    def per_client(stack_slice, nk):
        g = jax.tree_util.tree_map(lambda l: l[0], stack_slice)  # drop K axis
        return ota_psum(g, scheme=cfg.scheme, axes=("ota_clients",), h=h, b=b,
                        a=cfg.a, noise_var=cfg.noise_var,
                        key=(nk if use_noise else None),
                        grad_bound=cfg.grad_bound, h_hat=h_hat)

    f = jax.shard_map(per_client, mesh=mesh,
                      in_specs=(P("ota_clients"), P()), out_specs=P(),
                      axis_names={"ota_clients"}, check_vma=False)
    return f(stacked_grads, key_arr)
