"""The paper's aggregation as a first-class mesh collective: ``ota_psum``.

Inside a ``jax.shard_map`` whose *manual* axes are the FL-client axes
(('data',) on one pod; ('pod',) or ('pod','data') across pods), each shard
plays one mobile device of the paper's system:

    g_k  --normalize-->  x_k  --* h_k b_k-->  [psum over client axes]  --*a, +a z-->

The single ``psum`` *is* the over-the-air superposition (DESIGN.md §2): the
paper's method costs exactly the same collective bytes as a standard
data-parallel all-reduce, plus two scalar psums for the norm bookkeeping —
which the roofline table in EXPERIMENTS.md confirms.

The channel noise ``a*z`` is added *after* the psum from a key that is
replicated across shards, so every client computes the identical server-side
result (model replicas stay bitwise in sync, as Step 3 "Broadcast" requires).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
_EPS = 1e-12


def client_index(axis_names: Sequence[str]) -> jax.Array:
    """Flat FL-client index of this shard over the manual aggregation axes."""
    idx = jnp.zeros((), jnp.int32)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def _tree_sq_norm(tree: PyTree) -> jax.Array:
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
               for l in jax.tree_util.tree_leaves(tree))


def _tree_sum_count(tree: PyTree) -> Tuple[jax.Array, int]:
    s = sum(jnp.sum(l.astype(jnp.float32)) for l in jax.tree_util.tree_leaves(tree))
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(tree))
    return s, n


def _psum_tree(tree: PyTree, axes) -> PyTree:
    return jax.tree_util.tree_map(lambda l: jax.lax.psum(l, axes), tree)


def _scale_tree(tree: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda l: (l.astype(jnp.float32) * s), tree)


def _add_noise(tree: PyTree, key, a: float, noise_var: float) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(flat))
    std = jnp.sqrt(jnp.asarray(noise_var, jnp.float32)) * a
    flat = [l + std * jax.random.normal(k, l.shape, jnp.float32)
            for l, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, flat)


def ota_psum(grads: PyTree, *, scheme: str, axes: Tuple[str, ...],
             h: jax.Array, b: jax.Array, a: float, noise_var: float,
             key: Optional[jax.Array] = None,
             grad_bound: Optional[float] = None,
             reduce_dtype=None) -> PyTree:
    """Aggregate this shard's gradient with every other FL client's, over the
    air.  ``h``/``b`` are the full [K] per-client arrays (replicated); each
    shard selects its own coefficient by mesh position.

    Returns the server-side update direction y (identical on all clients).
    """
    if scheme == "mean":
        k_total = 1
        for ax in axes:
            k_total *= jax.lax.axis_size(ax)
        return _psum_tree(_scale_tree(grads, 1.0 / k_total), axes)

    me = client_index(axes)
    hk = h[me].astype(jnp.float32)
    bk = b[me].astype(jnp.float32)

    if scheme == "normalized":
        norm = jnp.sqrt(_tree_sq_norm(grads))
        x = _scale_tree(grads, hk * bk / (norm + _EPS))
        side = None
    elif scheme == "normalized_per_tensor":
        leaves = jax.tree_util.tree_leaves(grads)
        n_t = float(len(leaves))
        x = jax.tree_util.tree_map(
            lambda l: l.astype(jnp.float32) * (hk * bk / (
                (jnp.sqrt(jnp.sum(jnp.square(l.astype(jnp.float32)))) + _EPS)
                * jnp.sqrt(n_t))), grads)
        side = None
    elif scheme == "raw":
        x = _scale_tree(grads, hk * bk)
        side = None
    elif scheme == "benchmark1":
        x = _scale_tree(grads, hk * bk / jnp.asarray(grad_bound, jnp.float32))
        side = None
    elif scheme == "benchmark2":
        # energy-fair standardization (see repro.core.ota.device_transform)
        s, n = _tree_sum_count(grads)
        mean = s / n
        var = jnp.maximum(_tree_sq_norm(grads) / n - mean * mean, 0.0)
        std = jnp.sqrt(var)
        sqrt_n = float(n) ** 0.5
        x = jax.tree_util.tree_map(
            lambda l: (l.astype(jnp.float32) - mean)
            * (hk * bk / ((std + _EPS) * sqrt_n)), grads)
        side = (mean, std, sqrt_n)
    elif scheme == "onebit":
        _, n = _tree_sum_count(grads)
        x = jax.tree_util.tree_map(
            lambda l: jnp.sign(l.astype(jnp.float32)) * (hk * bk / jnp.sqrt(float(n))),
            grads)
        side = None
    else:
        raise ValueError(f"unknown scheme {scheme!r}")

    if reduce_dtype is not None:
        # beyond-paper §Perf lever: superpose in bf16 (halves the gradient
        # collective bytes; the analog channel would quantize far more
        # coarsely than bf16 anyway, so fidelity-wise this is still above
        # the paper's operating point).  Norms/side-info stay fp32.
        x = jax.tree_util.tree_map(lambda l: l.astype(reduce_dtype), x)
    y = _psum_tree(x, axes)                       # <-- the over-the-air superposition
    y = jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), y)
    if key is not None and noise_var > 0.0:
        y = _add_noise(y, key, 1.0, noise_var)    # z added once, pre-gain
    y = _scale_tree(y, jnp.asarray(a, jnp.float32))

    if scheme == "benchmark2":
        mean, std, sqrt_n = side
        sum_hb = jax.lax.psum(hk * bk, axes)
        std_bar = jax.lax.psum(hk * bk * std, axes) / (sum_hb + _EPS) * sqrt_n
        mean_bar = jax.lax.psum(hk * bk * mean, axes) / (sum_hb + _EPS)
        y = jax.tree_util.tree_map(lambda l: l * std_bar + mean_bar, y)
    elif scheme == "onebit":
        y = jax.tree_util.tree_map(jnp.sign, y)
    return y
