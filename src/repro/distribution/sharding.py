"""Parameter/activation sharding rules (logical-axis style), plus the
experiment-axis data parallelism of the vectorized sweep engine.

Rules are keyed on parameter leaf names (the model zoo uses stable names) and
produce ``PartitionSpec``s.  ``model_axis`` carries tensor parallelism
(attention heads / FFN hidden / experts / vocab); ``fsdp_axis`` optionally
shards the other large dim (required for llama3-405b).  Leaves with a leading
superblock-stack axis get a ``None`` prepended automatically.

``experiment_mesh`` / ``shard_experiment_axis`` serve
``repro.fed.runtime.run_batched``: a batched grid of experiments is embar-
rassingly parallel over its leading E axis, so when several local devices
are available the stacked per-experiment state is placed with E sharded over
a 1-D mesh and the jitted vmapped program runs SPMD — each device carries
E / n_devices whole experiments, no cross-device collectives.

``device_mesh`` / ``shard_device_axis`` serve the OTHER mesh of the repo —
the FL-device axis of the sharded streaming engine
(``FLConfig.device_mesh``): the K-blocked round partitions its blocks over
``device_mesh`` shards, each mesh device left-folds its own blocks, and one
deterministic cross-shard combine closes eq. (10).  The two meshes are
orthogonal by construction (a batched sweep owns the experiment axis, a
streaming round owns the FL-device axis) and are never active in the same
program — ``run_batched`` rejects ``device_mesh`` configs.
"""
from __future__ import annotations

import os
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

EXPERIMENT_AXIS = "exp"
FL_DEVICE_AXIS = "fldev"

# set REPRO_FL_MESH=emulate to force the sharded streaming engine onto its
# emulated (no-collective) execution path even when enough local devices
# exist — the parity tests' lever.  Read at TRACE time: flip it only before
# the first run of a config, or call runtime.clear_compile_caches() after.
_EMULATE_ENV = "REPRO_FL_MESH"


def experiment_mesh(num_experiments: int, *, axis_name: str = EXPERIMENT_AXIS,
                    devices=None, require: bool = False):
    """A 1-D mesh over the local devices for sharding a batched run's
    experiment axis, or ``None`` when sharding would not help: a single
    device, or a grid the device count does not divide (uneven shards would
    force padding).  ``None`` means the caller falls back to running the
    whole batch replicated on one device — the run is still correct, just
    not device-parallel.

    ``require=True`` turns the silent fallback into an actionable
    ``ValueError`` for callers that *expect* sharding to engage (tests, the
    benchmark harness): the message says which precondition failed and how
    to fix it."""
    if num_experiments < 1:
        raise ValueError(
            f"num_experiments must be >= 1, got {num_experiments}")
    devices = list(jax.local_devices() if devices is None else devices)
    if len(devices) <= 1:
        if require:
            raise ValueError(
                f"experiment-axis sharding needs > 1 local device, have "
                f"{len(devices)} — force host devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N or drop "
                "require=True to run replicated on one device")
        return None
    if num_experiments % len(devices) != 0:
        if require:
            raise ValueError(
                f"experiment count {num_experiments} does not divide over "
                f"{len(devices)} local devices (uneven shards would force "
                "padding) — pad the grid to a multiple of the device count, "
                "restrict jax to a dividing subset, or drop require=True to "
                "run replicated on one device")
        return None
    return jax.make_mesh((len(devices),), (axis_name,), devices=devices)


def device_mesh(num_shards: int, *, axis_name: str = FL_DEVICE_AXIS,
                devices=None):
    """A 1-D mesh of exactly ``num_shards`` local devices for the sharded
    streaming engine's FL-device axis, or ``None`` when the host cannot
    provide them (or ``REPRO_FL_MESH=emulate`` forces the emulated path) —
    the caller then runs the SAME shard blocking as an outer ``lax.scan``,
    bitwise-identical by the deterministic-combine contract
    (``fold_shards``)."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if os.environ.get(_EMULATE_ENV, "") == "emulate":
        return None
    devices = list(jax.local_devices() if devices is None else devices)
    if num_shards == 1 or len(devices) < num_shards:
        return None
    return jax.make_mesh((num_shards,), (axis_name,),
                         devices=devices[:num_shards])


def shard_device_axis(tree: Any, mesh, *,
                      axis_name: str = FL_DEVICE_AXIS) -> Any:
    """``device_put`` every array leaf of ``tree`` with its leading (shard)
    axis split over ``mesh``; rank-0 leaves replicate.  The leaves must all
    carry the shard count as their leading axis — the [D, nb/D, k_block,
    ...] blocked inputs of the sharded streaming round."""
    def one(leaf):
        nd = jnp.ndim(leaf)
        spec = P() if nd == 0 else P(axis_name, *([None] * (nd - 1)))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(one, tree)


def shard_experiment_axis(tree: Any, mesh, *,
                          axis_name: str = EXPERIMENT_AXIS) -> Any:
    """``device_put`` every array leaf of ``tree`` with its leading
    (experiment) axis sharded over ``mesh``; rank-0 leaves replicate.  The
    leaves must all carry E as their leading axis (the stacked state of
    ``run_batched``)."""
    def one(leaf):
        nd = jnp.ndim(leaf)
        spec = P() if nd == 0 else P(axis_name, *([None] * (nd - 1)))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(one, tree)

# name -> spec WITHOUT the stack axis; 'M' = model axis, 'F' = fsdp axis
_RULES = {
    # embeddings.  NOTE: the token table is deliberately NOT fsdp-sharded:
    # a gather from a table sharded over BOTH mesh axes inside a partial-manual
    # shard_map trips an XLA SPMD-partitioner check failure (b/433785288-like;
    # minimal repro in tests/test_distribution.py) — and at TP-only sharding
    # the table is small per chip anyway (llama3-405b: 263 MB).
    "tok": ("M", None),           # vocab-sharded embedding table
    "unemb": ("F", "M"),
    "modal_proj": (None, "M"),
    # attention
    "wq": ("F", "M"), "wk": ("F", "M"), "wv": ("F", "M"),
    "bq": ("M",), "bk": ("M",), "bv": ("M",),
    "wo": ("M", "F"),
    # dense mlp (rank-2) / moe experts (rank-3) share names; see _spec_for
    "w_gate": ("F", "M"), "w_up": ("F", "M"), "w_down": ("M", "F"),
    "router": (None, None),
    # mamba
    "in_proj": ("F", "M"), "out_proj": ("M", "F"),
    "conv_w": (None, "M"), "conv_b": ("M",),
    "x_proj": ("M", None), "dt_proj_w": (None, "M"), "dt_proj_b": ("M",),
    "A_log": ("M", None), "D": ("M",),
    # xlstm
    "up": ("F", "M"), "down": ("M", "F"),
    "ogate": (None, "M"),
    "wi": ("M", None), "wf": ("M", None), "bi": (None,), "bf": (None,),
    "w_gates": ("M", None),
    "r_gates": (None, None, None, None),
    "b_gates": (None,),
    # norms
    "scale": (None,),
}

_MOE_RULES = {  # rank-3 expert-stacked weights: experts over the model axis
    "w_gate": ("M", "F", None), "w_up": ("M", "F", None), "w_down": ("M", None, "F"),
}


def _resolve(symbolic, model_axis, fsdp_axis):
    out = []
    for s in symbolic:
        if s == "M":
            out.append(model_axis)
        elif s == "F":
            out.append(fsdp_axis)
        else:
            out.append(None)
    return tuple(out)


def _spec_for(name: str, parents: tuple, ndim: int, model_axis: str,
              fsdp_axis: Optional[str]) -> P:
    rule = _RULES.get(name)
    if name in _MOE_RULES and "moe" in parents:   # expert-stacked weights
        rule = _MOE_RULES[name]
    if rule is None:
        return P()
    spec = _resolve(rule, model_axis, fsdp_axis)
    if ndim == len(spec) + 1:      # leading superblock-stack axis
        spec = (None,) + spec
    elif ndim != len(spec):
        return P()                 # unknown layout: replicate (safe default)
    return P(*spec)


def param_specs(params, *, model_axis: str = "model",
                fsdp_axis: Optional[str] = None):
    """PartitionSpec pytree for a model parameter pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        specs.append(_spec_for(name, tuple(names[:-1]), jnp.ndim(leaf),
                               model_axis, fsdp_axis))
    return jax.tree_util.tree_unflatten(treedef, specs)


# (axes, dim size) pairs sanitize_spec has already warned about: each
# distinct drop is reported ONCE per process, not once per leaf per call —
# a sharded sweep calls sanitize_spec thousands of times on the same rules
_SANITIZE_WARNED: set = set()


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop mesh axes from dims they don't divide (e.g. vocab 256206 on a
    16-way model axis) — replicating such a dim is always legal.  Each
    distinct drop warns once per process (``UserWarning``): a silently
    replicated dim that was *meant* to shard is a memory/perf bug the user
    should see, while the known-benign cases (that vocab) stay readable."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if d < len(shape) and shape[d] % size == 0:
            out.append(entry)
        else:
            dim = shape[d] if d < len(shape) else None
            sig = (axes, size, dim)
            if sig not in _SANITIZE_WARNED:
                _SANITIZE_WARNED.add(sig)
                what = (f"dim {d} of size {dim}" if dim is not None
                        else f"dim {d} (beyond the leaf's rank {len(shape)})")
                warnings.warn(
                    f"sanitize_spec: mesh axes {axes} (size {size}) do not "
                    f"divide {what}; replicating that dim instead. "
                    "Expected for known-ragged dims (e.g. an odd vocab); if "
                    "this dim was meant to shard, fix the rule or pad the "
                    "dim. (warned once per distinct drop)",
                    UserWarning, stacklevel=2)
            out.append(None)
    return P(*out)


def named_shardings(mesh, spec_tree, like_tree=None):
    """NamedShardings for a spec pytree; with ``like_tree`` given, specs are
    sanitized against the actual leaf shapes first."""
    if like_tree is None:
        return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_map(
        lambda s, l: NamedSharding(mesh, sanitize_spec(mesh, s, l.shape)),
        spec_tree, like_tree, is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_like, axes) -> object:
    """Shard the leading (batch) dim of every batch leaf over ``axes``;
    scalars (e.g. ``pos``) replicate."""
    def one(leaf):
        if jnp.ndim(leaf) == 0:
            return P()
        return P(tuple(axes))
    return jax.tree_util.tree_map(one, batch_like)


def cache_specs(cache_like, *, batch_axes, model_axis: str, num_kv_heads: int,
                model_size: int, seq_axis: Optional[str] = None):
    """Decode-cache shardings, name-keyed like ``param_specs``.

    KV caches [n_sb,B,S,Hkv,dh] shard batch over ``batch_axes``, kv-heads over
    ``model_axis`` when divisible (else the seq axis over ``seq_axis`` for the
    context-parallel long-decode path); recurrent states shard batch + their
    big feature dim over the model axis.
    """
    kv_ok = num_kv_heads % model_size == 0
    b_ax = tuple(batch_axes) if batch_axes else None

    def spec_for(name: str, nd: int) -> P:
        if name in ("k", "v") and nd == 5:
            return P(None, b_ax, seq_axis, model_axis if kv_ok else None, None)
        if name == "ssm" and nd == 4:       # [n_sb, B, di, N]
            return P(None, b_ax, model_axis, None)
        if name == "conv" and nd == 4:      # [n_sb, B, K-1, di]
            return P(None, b_ax, None, model_axis)
        if name == "C" and nd == 5:         # [n_sb, B, H, dh, dh] mlstm memory
            return P(None, b_ax, None, model_axis, None)
        if name == "n" and nd == 4:         # [n_sb, B, H, dh] mlstm normalizer
            return P(None, b_ax, None, model_axis)
        if nd == 3:                         # [n_sb, B, di] slstm states
            return P(None, b_ax, model_axis)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    specs = []
    for path, leaf in flat:
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        specs.append(spec_for(name, jnp.ndim(leaf)))
    return jax.tree_util.tree_unflatten(treedef, specs)
