"""Reproduction of "Over-the-Air Computation Aided Federated Learning with
the Aggregation of Normalized Gradient" as a production-scale jax system.

Importing this package installs the jax forward-compatibility shims
(``repro.compat``) so the modern mesh API (``jax.shard_map`` / ``jax.set_mesh``)
works on older pinned jax versions too.
"""
from repro import compat as _compat  # noqa: F401  (side-effect: jax API shims)
