"""Checkpointing substrate: msgpack-serialized pytrees with metadata.

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
reconstructed from a path-keyed flat dict, so any nested dict/tuple/list of
jnp arrays round-trips.  Atomic write (tmp + rename), latest-k retention.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def _encode_array(a) -> Dict:
    a = np.asarray(a)
    # non-numpy-native dtypes (bfloat16 & friends) are stored as float32 with
    # the original dtype name recorded for restore
    orig = None
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        orig = str(jnp.asarray(a).dtype)
        a = np.asarray(jnp.asarray(a).astype(jnp.float32))
    return {"dtype": a.dtype.str, "shape": list(a.shape), "data": a.tobytes(),
            "orig_dtype": orig}


def _decode_array(d: Dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(d["shape"]).copy()


def save(path: str, tree: PyTree, metadata: Optional[Dict] = None) -> None:
    leaves, _ = _flatten_with_paths(tree)
    payload = {
        "meta": metadata or {},
        "leaves": {k: _encode_array(v) for k, v in leaves.items()},
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like: PyTree,
            missing_ok: Tuple[str, ...] = ()) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype checked).

    A ``like`` leaf that is a *numpy* array round-trips as numpy with its own
    dtype — float64 host-side state (e.g. the FL channel draw) must not be
    silently truncated to fp32 by passing through jnp, which is the fate of
    every jax-array leaf (device arrays follow jax's default precision).

    ``missing_ok`` is a tuple of key-path prefixes (``jax.tree_util.keystr``
    form, e.g. ``"['channel']"``) whose leaves MAY be absent from the
    checkpoint: they keep ``like``'s own value instead of raising
    ``KeyError`` — scoped forward compatibility for state that grows fields
    over time (checkpoints written before the wireless-environment
    subsystem lack the ``h_hat``/``fad_state`` channel leaves and restore
    with the freshly-``setup()`` defaults), without silently accepting a
    checkpoint whose params/optimizer structure does not match.
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = _flatten_with_paths(like)
    stored = payload["leaves"]
    out = {}
    for k, ref in leaves_like.items():
        if k not in stored:
            if any(k.startswith(p) for p in missing_ok):
                out[k] = ref
                continue
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = _decode_array(stored[k])
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch at {k}: {arr.shape} vs {np.shape(ref)}")
        if isinstance(ref, np.ndarray) and not isinstance(ref, jax.Array):
            out[k] = arr.astype(ref.dtype)
            continue
        ref_dtype = jnp.asarray(ref).dtype if hasattr(ref, "dtype") else None
        out[k] = jnp.asarray(arr).astype(ref_dtype)
    flat = [out[jax.tree_util.keystr(p)] for p, _ in
            jax.tree_util.tree_flatten_with_path(like)[0]]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), flat), \
        payload["meta"]


def save_round(ckpt_dir: str, round_idx: int, tree: PyTree,
               metadata: Optional[Dict] = None, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"round_{round_idx:08d}.msgpack")
    meta = dict(metadata or {})
    meta["round"] = round_idx
    save(path, tree, meta)
    existing = sorted(p for p in os.listdir(ckpt_dir) if p.startswith("round_"))
    for old in existing[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
    return path


def latest_round(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    existing = sorted(p for p in os.listdir(ckpt_dir) if p.startswith("round_"))
    return os.path.join(ckpt_dir, existing[-1]) if existing else None
