"""The registered small-scale fading processes.

All three models draw the amplitude as the envelope of a 2-component
Gaussian (the I/Q pair of a complex-Gaussian channel tap), through the SAME
primitive the historical i.i.d. path used (``core.channel``), so the default
``rayleigh`` model is bitwise-identical to the pre-registry draw and the
AR(1) model at ``rho = 0`` is bitwise-identical to block fading:

``rayleigh``   h = scale * |x|,            x ~ N(0, I_2)   (the paper, Sec. V)
``rician``     h = scale * |x + nu e_1|,   nu = sqrt(2 K)  (LOS + scatter;
               ``scale`` is calibrated by ``ChannelConfig.amplitude_scale``
               so E[h] still equals ``channel_mean`` at every K-factor)
``ar1``        x_t = rho x_{t-1} + sqrt(1 - rho^2) w_t,  h_t = scale * |x_t|
               (Gauss-Markov / Jakes-flavoured time correlation; the state
               x_t threads through the scan carry and ``FLState.fad_state``,
               and the stationary marginal of h_t is exactly the i.i.d.
               Rayleigh of the same scale)

``scale`` may be a scalar, a per-device ``[K]`` vector (geometry-derived
heterogeneous means), or a traced value (the batched sweep engine's
``channel_mean`` axis); ``rho`` likewise (the ``channel.rho`` sweep axis).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.channels.base import ChannelModel, register
from repro.core import channel as chan


def _rayleigh_init(cfg, scale, key):
    return chan.draw_channel(key, cfg, scale), None


def _rayleigh_step(cfg, scale, key_t, state, rho):
    return chan.draw_channel(key_t, cfg, scale), None


register(ChannelModel(
    name="rayleigh",
    doc="i.i.d. Rayleigh envelope (the paper's model; bitwise-compatible "
        "default)",
    init=_rayleigh_init,
    step=_rayleigh_step,
))


def _rician_offset(cfg) -> float:
    # K-factor K = nu^2 / (2 sigma^2) with unit per-component variance
    return math.sqrt(2.0 * cfg.rician_k)


def _rician_draw(cfg, scale, key):
    x = chan.draw_fading_state(key, cfg.num_devices)
    x = x + jnp.asarray([_rician_offset(cfg), 0.0], x.dtype)
    return chan.envelope(x, scale), None


register(ChannelModel(
    name="rician",
    doc="Rician envelope with K-factor cfg.rician_k (LOS component); "
        "K = 0 degenerates to Rayleigh",
    init=lambda cfg, scale, key: _rician_draw(cfg, scale, key),
    step=lambda cfg, scale, key_t, state, rho: _rician_draw(cfg, scale,
                                                            key_t),
))


def _ar1_init(cfg, scale, key):
    x = chan.draw_fading_state(key, cfg.num_devices)
    return chan.envelope(x, scale), x


def _ar1_step(cfg, scale, key_t, state, rho):
    w = chan.draw_fading_state(key_t, cfg.num_devices)
    rho = jnp.asarray(rho, w.dtype)
    x = rho * state + jnp.sqrt(1.0 - rho * rho) * w
    return chan.envelope(x, scale), x


register(ChannelModel(
    name="ar1",
    doc="time-correlated Rayleigh: Gauss-Markov AR(1) on the underlying "
        "complex tap, correlation cfg.rho per round; rho = 0 IS block "
        "fading (bitwise), and the stationary marginal is the i.i.d. "
        "Rayleigh of the same scale",
    time_varying=True,
    has_state=True,
    init=_ar1_init,
    step=_ar1_step,
))
