"""Composable wireless-environment subsystem.

One ``ChannelModel`` registry (``repro.channels.base``) behind three
orthogonal axes of the radio environment, all declarative via
``ChannelConfig`` / ``ExperimentSpec`` and sweepable (``channel.model``,
``channel.rho``, ``channel.csi_error``, ...):

* **small-scale process** (``models``): i.i.d. Rayleigh (the bitwise
  default), Rician with K-factor, time-correlated Gauss-Markov AR(1);
* **large-scale geometry** (``geometry``): per-device distances ->
  path loss + log-normal shadowing -> heterogeneous per-device means;
* **imperfect CSI** (``csi``): the true ``h`` (the air) vs the server's
  estimate ``h_hat`` (amplification + receiver gain).
"""
from repro.channels.base import ChannelModel, get, names, register
from repro.channels.csi import CSI_ERROR_MODELS, estimate
from repro.channels.geometry import (GeometryConfig, draw_distances,
                                     relative_gains)
from repro.channels import models as _models  # noqa: F401  (registers)
from repro.channels import csi, geometry  # noqa: F401

__all__ = ["CSI_ERROR_MODELS", "ChannelModel", "GeometryConfig", "csi",
           "draw_distances", "estimate", "geometry", "get", "names",
           "register", "relative_gains"]
