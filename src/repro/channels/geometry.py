"""Large-scale channel structure: device geometry -> per-device mean gains.

The paper collapses the uplink budget to one scalar ``channel_mean`` (free
space over 300 m at 3.5 GHz for every device).  Real cohorts are spread over
a cell: each device k sits at its own distance ``d_k``, so its mean
amplitude is

    mean_k = channel_mean * (d_k / ref_distance)^(-path_loss_exp / 2)
                          * 10^(X_k / 20),     X_k ~ N(0, shadowing_std_db^2)

— ``channel_mean`` stays the single batchable knob (the gain AT the
reference distance), the path-loss exponent acts on *power* (hence the /2 on
the amplitude), and the optional log-normal shadowing term models
building/terrain blockage.  Distances are drawn uniformly **by area** over
the annulus [min_distance, cell_radius] (closer-in rings hold fewer devices)
from the experiment's channel seed, host-side at ``setup()`` time; the
resulting per-device scale vector rides into the compiled engine as data
(``FLState.scale``), so in-scan fading redraws see the heterogeneous means
with no extra trace.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GeometryConfig:
    """Static cell geometry behind heterogeneous per-device channel means."""

    cell_radius: float = 500.0       # outer annulus radius [m]
    min_distance: float = 50.0       # closest a device can sit to the ES [m]
    ref_distance: float = 300.0      # distance at which mean == channel_mean
    path_loss_exp: float = 3.0       # power path-loss exponent gamma
    shadowing_std_db: float = 0.0    # log-normal shadowing sigma (dB); 0 = off

    def __post_init__(self):
        if not 0.0 < self.min_distance <= self.cell_radius:
            raise ValueError(
                "need 0 < min_distance <= cell_radius, got "
                f"min_distance={self.min_distance}, "
                f"cell_radius={self.cell_radius}")
        if self.ref_distance <= 0.0:
            raise ValueError(f"ref_distance must be positive, got "
                             f"{self.ref_distance}")
        if self.path_loss_exp < 0.0:
            raise ValueError(f"path_loss_exp must be >= 0, got "
                             f"{self.path_loss_exp}")
        if self.shadowing_std_db < 0.0:
            raise ValueError(f"shadowing_std_db must be >= 0, got "
                             f"{self.shadowing_std_db}")


def draw_distances(key: jax.Array, geo: GeometryConfig,
                   num_devices: int) -> np.ndarray:
    """[K] device-to-ES distances, uniform by area over the annulus."""
    u = np.asarray(jax.random.uniform(key, (num_devices,)), np.float64)
    r2 = geo.min_distance ** 2 + u * (geo.cell_radius ** 2
                                      - geo.min_distance ** 2)
    return np.sqrt(r2)


def relative_gains(key: jax.Array, geo: GeometryConfig,
                   num_devices: int) -> np.ndarray:
    """[K] per-device mean-amplitude gains RELATIVE to ``channel_mean``
    (i.e. mean_k = channel_mean * relative_gains(...)[k]): path loss at the
    drawn distance plus optional log-normal shadowing.  Deterministic in the
    key; float64 host-side (this feeds ``setup()``, not the scan)."""
    d = draw_distances(key, geo, num_devices)
    gains = (d / geo.ref_distance) ** (-geo.path_loss_exp / 2.0)
    if geo.shadowing_std_db > 0.0:
        x_db = geo.shadowing_std_db * np.asarray(
            jax.random.normal(jax.random.fold_in(key, 1), (num_devices,)),
            np.float64)
        gains = gains * 10.0 ** (x_db / 20.0)
    return gains


def relative_gains_block(key: jax.Array, geo: GeometryConfig,
                         dev_idx: jax.Array) -> jax.Array:
    """Lazy per-K-block twin of ``relative_gains``: device i's distance (and
    shadowing) draw folds from its own index, so ANY blocking of ``[0, K)``
    concatenates to the same gain vector — the 100k-device path samples one
    K-block of geometry at a time, jit-side, instead of materializing a [K]
    host array up front.  A device-indexed key schedule, deliberately NOT
    the same stream as ``relative_gains``'s single [K] draw (which has no
    per-device lazy form): pick one schedule per experiment."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(dev_idx)
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    r2 = geo.min_distance ** 2 + u * (geo.cell_radius ** 2
                                      - geo.min_distance ** 2)
    gains = (jnp.sqrt(r2) / geo.ref_distance) ** (-geo.path_loss_exp / 2.0)
    if geo.shadowing_std_db > 0.0:
        x_db = geo.shadowing_std_db * jax.vmap(
            lambda k: jax.random.normal(jax.random.fold_in(k, 1), ()))(keys)  # tracelint: disable=TL002 the vmapped lambda fold_ins each key to slot 1 first; the shadowing draw is a disjoint stream
        gains = gains * 10.0 ** (x_db / 20.0)
    return gains
