"""Imperfect channel-state information: the ``h`` vs ``h_hat`` split.

The air superposes with the TRUE amplitudes ``h_k`` (eq. 10); the server
only ever sees its ESTIMATE ``h_hat_k``, so Algorithm-1 amplification
(Problem 3 solved on ``h_hat``), the receiver gain ``a``, the participation
rescale, and the side-info folding all run on ``h_hat``.  The gap between
the designed effective gain ``a sum_k h_hat_k b_k`` and the realized one
``a sum_k h_k b_k`` is the per-round ``csi_gain_err`` diagnostic
(``repro.fed.runtime.DIAG_KEYS``).

Two estimation-error models (``ChannelConfig.csi_error_model``), both
scaled by the dimensionless ``ChannelConfig.csi_error`` (0 = perfect CSI):

``additive``         h_hat = |h + csi_error * scale * e|,  e ~ N(0, I)
                     — pilot-estimation noise whose std is ``csi_error``
                     channel-widths (``scale`` is the amplitude scale, so
                     geometry-heterogeneous devices get proportionally
                     scaled estimation noise)
``multiplicative``   h_hat = h * |1 + csi_error * e|
                     — relative (quantization/feedback-style) error

Both take the magnitude so ``h_hat`` stays a valid non-negative amplitude
for the Problem-3 solvers, and both are EXACT at ``csi_error = 0`` — even
as a traced zero (``0 * e`` vanishes bitwise), which is what lets a batched
sweep carry perfect- and imperfect-CSI lanes in one compiled program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CSI_ERROR_MODELS = ("additive", "multiplicative")


def estimate(h: jax.Array, key: jax.Array, csi_error, scale,
             model: str = "additive") -> jax.Array:
    """The server's channel estimate ``h_hat`` for a true draw ``h``.

    ``csi_error`` and ``scale`` may be traced (per-experiment sweep lanes)
    or python floats; ``scale`` may also be a per-device ``[K]`` vector.
    jit/vmap/scan-safe — the compiled engine re-estimates every round's
    ``h_hat_t`` inside its scan body under time-varying fading.
    """
    if model not in CSI_ERROR_MODELS:
        raise ValueError(f"unknown csi_error_model {model!r}; "
                         f"one of {CSI_ERROR_MODELS}")
    e = jax.random.normal(key, h.shape, h.dtype)
    err = jnp.asarray(csi_error, h.dtype)
    if model == "additive":
        return jnp.abs(h + err * jnp.asarray(scale, h.dtype) * e)
    return h * jnp.abs(1.0 + err * e)
