"""The channel-model registry: every radio environment the FL system can
run over is ONE ``ChannelModel`` record here, consumed by both runtime
drivers, the vectorized sweep engine, and ``setup()``.

A model describes the *small-scale* fading process of the uplink amplitudes
``h_k``.  It is deliberately tiny — two pure functions over a PRNG key and a
(possibly per-device ``[K]``, possibly traced) amplitude scale — because
everything around it is owned elsewhere:

* **large-scale** structure (path loss from device geometry, log-normal
  shadowing) enters through the ``scale`` argument
  (``repro.channels.geometry`` turns a ``GeometryConfig`` into per-device
  scales at ``setup()`` time);
* **imperfect CSI** is applied *after* the draw (``repro.channels.csi``
  splits the true ``h`` from the server's estimate ``h_hat``);
* the redraw *schedule* (fixed vs per-round) is the runtime's: a model with
  ``time_varying=True`` (AR(1)) forces per-round steps, otherwise
  ``ChannelConfig.block_fading`` decides.

Both functions must be jit/vmap/scan-safe: the compiled FL engine calls
``step`` inside its ``lax.scan`` body (and the sweep engine vmaps that body
over an experiment axis), so a model may not branch on traced values at the
Python level.

Registering is the only extension step::

    register(ChannelModel(name="mymodel", init=..., step=...))

after which ``ChannelConfig(model="mymodel")`` validates, sweeps accept a
``channel.model`` axis, and both drivers run it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

# init(cfg, scale, key)            -> (h0 [K], state0 or None)
# step(cfg, scale, key_t, state, rho) -> (h_t [K], state_t or None)
InitFn = Callable[..., Tuple[jax.Array, Optional[jax.Array]]]
StepFn = Callable[..., Tuple[jax.Array, Optional[jax.Array]]]


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """One small-scale fading process.

    ``init`` draws the round-0 channel (host-side, at ``setup()``);
    ``step`` draws the round-t channel from an already-``fold_in``-ed key
    (device-side, inside the scan when the channel is time-varying).
    ``has_state`` models thread a persistent array (the AR(1) Gauss-Markov
    innovation state, shape [K, 2]) through the scan carry, ``FLState``, and
    checkpoints; stateless models carry ``None``.
    """

    name: str
    init: InitFn
    step: StepFn
    doc: str = ""
    # True: the channel evolves every round regardless of block_fading
    # (block fading is this process with correlation rho = 0)
    time_varying: bool = False
    # True: step() consumes/produces a [K, 2] persistent fading state
    has_state: bool = False


_REGISTRY: Dict[str, ChannelModel] = {}


def register(model: ChannelModel) -> ChannelModel:
    if not isinstance(model, ChannelModel):
        raise TypeError(f"expected a ChannelModel, got {type(model)}")
    _REGISTRY[model.name] = model
    return model


def get(name: str) -> ChannelModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown channel model {name!r}; "
                         f"registered: {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
