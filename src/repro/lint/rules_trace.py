"""Trace-purity rules: TL001 host coercion, TL002 key reuse, TL003 branching.

All three work on the traced-function sets produced by ``context.find_traced``
and share a light linear taint pass; see that module for the model.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from .base import Finding, Rule, register
from .context import (Taint, _dotted, find_traced, walk_statements)

# np.<name> calls that have a drop-in jnp.<name> spelling; these get an
# automatic --fix rewrite.  Anything else is flagged without a fix.
NP_TO_JNP_SAFE = {
    "sum", "mean", "sqrt", "abs", "maximum", "minimum", "exp", "log",
    "clip", "where", "concatenate", "stack", "zeros", "ones", "asarray",
    "arange", "dot", "square", "prod", "cumsum", "sort", "argmin", "argmax",
}

# jax.random draws: consuming a key twice through these without an
# intervening split/fold_in correlates streams.
_KEY_DERIVING = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "clone"}


def _stmt_exprs(stmt: ast.stmt) -> Iterable[ast.expr]:
    """Expressions belonging to ``stmt`` itself, not to nested statements.

    ``walk_statements`` already yields nested statements separately, so rules
    scanning expressions per-statement must not descend into child blocks or
    they would report each finding once per nesting level.
    """
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return
    for field in ("value", "test", "msg", "exc", "iter", "target", "targets"):
        val = getattr(stmt, field, None)
        if val is None:
            continue
        if isinstance(val, list):
            yield from (v for v in val if isinstance(v, ast.expr))
        elif isinstance(val, ast.expr):
            yield val
    for item in getattr(stmt, "items", ()) or ():
        yield item.context_expr


def _walk_expr(expr: ast.expr) -> Iterable[ast.AST]:
    """ast.walk that does not descend into lambdas (checked separately)."""
    stack = [expr]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Lambda):
                continue
            stack.append(child)


def _check_host_calls(expr: ast.expr, taint: Taint, path: str,
                      findings: List[Finding], lines: List[str]) -> None:
    for node in _walk_expr(expr):
        if not isinstance(node, ast.Call):
            continue
        fn = _dotted(node.func)
        args = list(node.args) + [kw.value for kw in node.keywords
                                  if kw.value is not None]
        any_tainted = any(taint.is_tainted(a) for a in args)
        if fn.startswith("np.") and any_tainted:
            fix = None
            tail = fn.split(".", 1)[1]
            if tail in NP_TO_JNP_SAFE and node.lineno - 1 < len(lines):
                orig = lines[node.lineno - 1]
                col = node.func.value.col_offset  # type: ignore[union-attr]
                if orig[col:col + 3] == "np.":
                    fix = (orig, orig[:col] + "jnp." + orig[col + 3:])
            findings.append(Finding(
                "TL001", path, node.lineno,
                f"host numpy call `{fn}` on a traced value inside a traced "
                f"context; use the jnp equivalent", fix=fix))
        elif fn in ("float", "int", "bool") and args \
                and any(taint.is_tainted(a) for a in node.args):
            findings.append(Finding(
                "TL001", path, node.lineno,
                f"`{fn}()` coerces a traced value to a host scalar inside a "
                f"traced context (concretization error or silent constant)"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("item", "tolist") \
                and taint.is_tainted(node.func.value):
            findings.append(Finding(
                "TL001", path, node.lineno,
                f"`.{node.func.attr}()` on a traced value inside a traced "
                f"context forces a host sync / concretization"))


def _run_taint(fn: ast.FunctionDef, path: str, lines: List[str],
               on_stmt) -> List[Finding]:
    findings: List[Finding] = []
    taint = Taint(fn)
    for stmt in walk_statements(fn):
        on_stmt(stmt, taint, findings)
        if isinstance(stmt, ast.Assign):
            taint.assign(stmt.targets, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            taint.assign([stmt.target], stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            taint.assign([stmt.target], stmt.value)
        elif isinstance(stmt, ast.For):
            taint.assign([stmt.target], stmt.iter)
    return findings


def _tl001(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        info = find_traced(mod.tree)

        def on_stmt(stmt, taint, out, _path=mod.relpath, _lines=mod.lines):
            for expr in _stmt_exprs(stmt):
                _check_host_calls(expr, taint, _path, out, _lines)

        for name in sorted(info.traced):
            fn = info.functions.get(name)
            if fn is not None:
                findings.extend(_run_taint(fn, mod.relpath, mod.lines, on_stmt))
        for lam in info.lambdas:
            taint = Taint(_lambda_as_fn(lam))
            _check_host_calls(lam.body, taint, mod.relpath, findings, mod.lines)
    return findings


def _lambda_as_fn(lam: ast.Lambda) -> ast.FunctionDef:
    fn = ast.FunctionDef(name="<lambda>", args=lam.args,
                         body=[ast.Return(value=lam.body)],
                         decorator_list=[], returns=None, type_params=[])
    return ast.fix_missing_locations(ast.copy_location(fn, lam))


def _tl003(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        info = find_traced(mod.tree)

        def on_stmt(stmt, taint, out, _path=mod.relpath):
            if isinstance(stmt, (ast.If, ast.While)) \
                    and taint.is_tainted(stmt.test):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                out.append(Finding(
                    "TL003", _path, stmt.lineno,
                    f"Python `{kind}` branches on a traced value; use "
                    f"jnp.where / lax.cond / lax.while_loop"))
            elif isinstance(stmt, ast.Assert) and taint.is_tainted(stmt.test):
                out.append(Finding(
                    "TL003", _path, stmt.lineno,
                    "`assert` on a traced value concretizes under trace; "
                    "use checkify or a host-side validation path"))

        for name in sorted(info.traced):
            fn = info.functions.get(name)
            if fn is not None:
                findings.extend(_run_taint(fn, mod.relpath, mod.lines, on_stmt))
    return findings


def _keyish(name: str) -> bool:
    low = name.lower()
    return "key" in low or low == "rng" or low.startswith("rng_")


# plain-Python builtins/containers: passing a name that LOOKS keyish to
# these is not a PRNG draw (e.g. `set(eval_keys)` on metric-name tuples)
_NOT_DRAWS = {"set", "sorted", "len", "list", "tuple", "dict", "enumerate",
              "zip", "str", "repr", "print", "min", "max", "isinstance",
              "type", "format", "join", "append", "extend", "get", "range"}


def _consumed_keys(expr: ast.expr) -> Iterable[ast.Name]:
    """Key names a statement's expression consumes (draws or forwards)."""
    for node in _walk_expr(expr):
        if not isinstance(node, ast.Call):
            continue
        fn = _dotted(node.func)
        tail = fn.split(".")[-1]
        if tail == "fold_in":
            continue  # fold_in derives; the parent key stays usable
        if tail in _KEY_DERIVING and tail != "split":
            continue  # constructors
        if tail in _NOT_DRAWS:
            continue
        for arg in node.args:
            if isinstance(arg, ast.Name) and _keyish(arg.id):
                yield arg
                break  # one key per call is the convention everywhere here
        for kw in node.keywords:
            if kw.arg and _keyish(kw.arg) and isinstance(kw.value, ast.Name) \
                    and _keyish(kw.value.id):
                yield kw.value


def _terminates(body: List[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _tl002_block(body: List[ast.stmt], consumed: Dict[str, int],
                 findings: List[Finding], path: str) -> None:
    """Walk one statement block tracking key consumption.

    Branch-aware: the arms of an ``if`` are exclusive paths, so a draw in the
    ``else`` does not conflict with a draw in the ``then`` — each arm starts
    from the pre-branch state and the post-state is the union (consumed on
    SOME path still blocks a later unconditional redraw)."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue  # nested defs get their own pass
        for expr in _stmt_exprs(stmt):
            for key_node in _consumed_keys(expr):
                name = key_node.id
                if name.isupper():
                    continue  # module-level fixture constants: deliberate
                prev = consumed.get(name)
                if prev is not None:
                    findings.append(Finding(
                        "TL002", path, key_node.lineno,
                        f"PRNG key `{name}` reused (first consumed at line "
                        f"{prev}) without an intervening split/fold_in"))
                else:
                    consumed[name] = key_node.lineno
        # rebinds clear consumption after the statement's reads
        targets: List[ast.expr] = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.For):
            targets = [stmt.target]
        for t in targets:
            for tname in _flat_names(t):
                consumed.pop(tname, None)
        if isinstance(stmt, ast.If):
            then_state = dict(consumed)
            else_state = dict(consumed)
            _tl002_block(stmt.body, then_state, findings, path)
            _tl002_block(stmt.orelse, else_state, findings, path)
            # a terminating arm (early return/raise) never rejoins the fall-
            # through path, so its consumption cannot conflict downstream
            consumed.clear()
            if not _terminates(stmt.orelse):
                consumed.update(else_state)
            if not _terminates(stmt.body):
                consumed.update(then_state)
        else:
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    _tl002_block(sub, consumed, findings, path)
            for handler in getattr(stmt, "handlers", ()) or ():
                _tl002_block(handler.body, consumed, findings, path)


def _tl002(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        info = find_traced(mod.tree)
        for name, fn in sorted(info.functions.items()):
            _tl002_block(fn.body, {}, findings, mod.relpath)
    return findings


def _flat_names(target: ast.expr) -> Iterable[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _flat_names(e)
    elif isinstance(target, ast.Starred):
        yield from _flat_names(target.value)


register(Rule(
    id="TL001", name="host-coercion-in-trace",
    summary="np./float()/.item()/bool() on traced values in traced contexts",
    contract="scan-vs-python and backend bitwise parity (PRs 1-3)",
    check=_tl001, fixable=True))

register(Rule(
    id="TL002", name="prng-key-reuse",
    summary="same PRNG key consumed twice without split/fold_in between",
    contract="per-device fold_in discipline; PR 6 blocking invariance",
    check=_tl002))

register(Rule(
    id="TL003", name="python-branch-on-tracer",
    summary="Python if/while/assert on tracer-derived values",
    contract="jit/scan tracing never concretizes control flow",
    check=_tl003))
