"""Rule registry and finding model for tracelint.

Mirrors the ``core.schemes`` register pattern: rules are frozen dataclasses
held in a module-level registry, looked up by id, and enumerated in sorted
order so ``--self-test`` and the CLI see a stable rule set.

A :class:`Finding` is one diagnostic anchored to a file/line.  Findings may
carry a mechanical fix as a whole-line replacement; ``--fix`` applies those
only when the on-disk line still matches what the rule saw (no stale edits).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .engine import Project


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: rule id, location, message, optional line fix."""

    rule_id: str
    path: str
    line: int
    message: str
    # (original_line_text, replacement_line_text) for --fix; None = not fixable
    fix: Optional[Tuple[str, str]] = None

    def to_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule_id,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "fixable": self.fix is not None,
        }


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered lint rule.

    ``check`` receives the whole :class:`~repro.lint.engine.Project` so rules
    can be cross-module (TL005/TL006 compare tables against dataclasses that
    live in different files).  ``contract`` names the parity contract the rule
    protects; it rides along into ``--json`` output and the README table.
    """

    id: str
    name: str
    summary: str
    contract: str
    check: Callable[["Project"], List[Finding]]
    fixable: bool = False


_REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.id in _REGISTRY:
        raise ValueError(f"duplicate tracelint rule id {rule.id!r}")
    _REGISTRY[rule.id] = rule
    return rule


def get(rule_id: str) -> Rule:
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        known = ", ".join(names())
        raise KeyError(f"unknown tracelint rule {rule_id!r}; known: {known}")


def names() -> List[str]:
    return sorted(_REGISTRY)


def all_rules() -> List[Rule]:
    return [_REGISTRY[k] for k in names()]
