"""tracelint: repo-native static analysis for the OTA-FL engine's contracts.

Run as ``PYTHONPATH=src python -m repro.lint src/ tests/ benchmarks/``.

The engine's correctness contracts (bitwise backend parity, scan-vs-python,
streamed-vs-dense, the sweep engine's batchable/structural split, PRNG
fold_in discipline) are enforced at runtime by the test tiers — but a stray
host ``np.`` call in a scan body or an unclassified config field produces
*plausible wrong numbers* long before a test names it.  tracelint turns those
implicit invariants into AST-checked rules that run in milliseconds.

Rules live in a registry mirroring ``core.schemes``; importing this package
registers the full set.  See each ``rules_*`` module for the hazards and the
parity contract each rule protects.
"""
from .base import Finding, Rule, all_rules, get, names, register  # noqa: F401

# importing the rule modules populates the registry (same idiom as
# repro.channels importing its model modules)
from . import rules_trace      # noqa: F401  TL001-TL003
from . import rules_pallas     # noqa: F401  TL004
from . import rules_contracts  # noqa: F401  TL005-TL006
from . import rules_buffers    # noqa: F401  TL007-TL008
from . import rules_obs        # noqa: F401  TL009

from .engine import (apply_fixes, build_project, lint, render_human,  # noqa: F401
                     render_json, self_test)
