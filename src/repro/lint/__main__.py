"""CLI entry point: ``python -m repro.lint [paths...] [--json] [--fix]
[--self-test]``.

Exit codes: 0 clean, 1 unsuppressed findings (or self-test failure),
2 usage errors.  ``--fix`` applies the mechanical fixes (TL001 np.->jnp.
where a drop-in spelling exists, TL000 reason normalization) and re-lints,
so the exit code reflects the post-fix state.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from . import engine


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="tracelint: static checks for the engine's trace-purity, "
                    "PRNG, and config-classification contracts")
    parser.add_argument("paths", nargs="*",
                        default=["src", "tests", "benchmarks"],
                        help="files or directories to lint "
                             "(default: src tests benchmarks)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable report on stdout")
    parser.add_argument("--fix", action="store_true",
                        help="apply mechanical fixes in place, then re-lint")
    parser.add_argument("--self-test", action="store_true",
                        help="run every rule against tests/lint_corpus/ and "
                             "exit nonzero if any rule misses its fixture")
    parser.add_argument("--root", default=".",
                        help="repo root for relative paths (default: cwd)")
    args = parser.parse_args(argv)

    root = pathlib.Path(args.root).resolve()

    if args.self_test:
        corpus = root / "tests" / "lint_corpus"
        if not corpus.is_dir():
            print(f"tracelint: corpus directory not found: {corpus}",
                  file=sys.stderr)
            return 2
        ok, report = engine.self_test(corpus, root)
        print(report)
        return 0 if ok else 1

    project, active, suppressed = engine.lint(args.paths, root=root)
    if args.fix:
        touched = engine.apply_fixes(project, active)
        if touched:
            print(f"tracelint: fixed {len(touched)} file(s): "
                  f"{', '.join(touched)}", file=sys.stderr)
        project, active, suppressed = engine.lint(args.paths, root=root)

    n_files = len(project.modules)
    if args.json:
        print(engine.render_json(active, suppressed, n_files))
    else:
        print(engine.render_human(active, suppressed, n_files))
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
