"""tracelint driver: file collection, suppressions, output, self-test, --fix.

Suppression syntax (checked by the TL000 meta-rule):

    x = np.sum(v)   # tracelint: disable=TL001 host-side setup path
    # tracelint: disable=TL002,TL003 fixture reuses one key on purpose
    y = draw(key)

An inline comment suppresses its own line; a comment-only line suppresses the
next line.  The free text after the rule list is the *reason* and is
mandatory: a reasonless suppression is itself a finding (TL000), fixable by
``--fix`` into a canonical ``TODO: justify`` placeholder so the gap stays
visible in review.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import base
from .base import Finding

# directories never linted as part of a normal run: the corpus is known-bad
# by design and only consulted by --self-test / the unit tests.
EXCLUDED_PARTS = {"lint_corpus", "__pycache__", ".git"}

SUPPRESS_RE = re.compile(
    r"#\s*tracelint:\s*disable=([A-Za-z0-9,\s]*?[A-Za-z0-9])(?:\s+(.+))?\s*$")
CANONICAL_SUPPRESS = "# tracelint: disable={ids} {reason}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int              # the line the suppression APPLIES to
    comment_line: int      # the line the comment sits on
    rule_ids: Tuple[str, ...]
    reason: Optional[str]
    raw: str               # full original line text (for --fix)


@dataclasses.dataclass
class ModuleInfo:
    path: pathlib.Path
    relpath: str
    text: str
    lines: List[str]
    tree: ast.Module
    suppressions: List[Suppression]


@dataclasses.dataclass
class Project:
    root: pathlib.Path
    modules: List[ModuleInfo]

    def suppressions_for(self, relpath: str) -> Dict[int, List[Suppression]]:
        for mod in self.modules:
            if mod.relpath == relpath:
                out: Dict[int, List[Suppression]] = {}
                for sup in mod.suppressions:
                    out.setdefault(sup.line, []).append(sup)
                return out
        return {}


def parse_suppressions(lines: List[str]) -> List[Suppression]:
    out: List[Suppression] = []
    for i, line in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(line)
        if not m:
            continue
        ids = tuple(s.strip().upper() for s in m.group(1).split(",") if s.strip())
        reason = m.group(2).strip() if m.group(2) else None
        # comment-only line guards the NEXT line; inline guards its own
        code_before = line[:m.start()].strip()
        target = i + 1 if code_before == "" else i
        out.append(Suppression(line=target, comment_line=i, rule_ids=ids,
                               reason=reason, raw=line))
    return out


def load_module(path: pathlib.Path, root: pathlib.Path) -> Optional[ModuleInfo]:
    try:
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError) as exc:
        print(f"tracelint: skipping unparsable {path}: {exc}", file=sys.stderr)
        return None
    try:
        rel = str(path.relative_to(root))
    except ValueError:
        rel = str(path)
    lines = text.splitlines()
    return ModuleInfo(path=path, relpath=rel, text=text, lines=lines,
                      tree=tree, suppressions=parse_suppressions(lines))


def collect_files(paths: Sequence[str], root: pathlib.Path,
                  include_corpus: bool = False) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if not path.is_absolute():
            path = root / path
        if path.is_file() and path.suffix == ".py":
            files.append(path)
        elif path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
    excluded = EXCLUDED_PARTS - ({"lint_corpus"} if include_corpus else set())
    return [f for f in files if not (set(f.parts) & excluded)]


def build_project(paths: Sequence[str], root: Optional[pathlib.Path] = None,
                  include_corpus: bool = False) -> Project:
    root = root or pathlib.Path.cwd()
    modules = []
    for f in collect_files(paths, root, include_corpus=include_corpus):
        mod = load_module(f, root)
        if mod is not None:
            modules.append(mod)
    return Project(root=root, modules=modules)


def _tl000(project: Project) -> List[Finding]:
    """Meta-rule: every suppression must carry a reason string."""
    findings: List[Finding] = []
    for mod in project.modules:
        for sup in mod.suppressions:
            if sup.reason:
                continue
            canonical = CANONICAL_SUPPRESS.format(
                ids=",".join(sup.rule_ids), reason="TODO: justify")
            m = SUPPRESS_RE.search(sup.raw)
            fixed = sup.raw[:m.start()] + canonical if m else sup.raw
            findings.append(Finding(
                "TL000", mod.relpath, sup.comment_line,
                f"suppression of {','.join(sup.rule_ids)} has no reason; "
                f"`# tracelint: disable=TLxxx <why>` documents the waiver",
                fix=(sup.raw, fixed)))
    return findings


def run_rules(project: Project,
              only: Optional[Set[str]] = None) -> List[Finding]:
    findings = [] if (only and "TL000" not in only) else _tl000(project)
    for rule in base.all_rules():
        if only and rule.id not in only:
            continue
        findings.extend(rule.check(project))
    return findings


def split_suppressed(project: Project, findings: List[Finding]
                     ) -> Tuple[List[Finding], List[Finding]]:
    """(active, suppressed).  TL000 is never suppressible by itself — a
    reasonless suppression cannot waive its own hygiene finding."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    by_path: Dict[str, Dict[int, List[Suppression]]] = {}
    for f in findings:
        sups = by_path.setdefault(f.path, project.suppressions_for(f.path))
        hit = any(f.rule_id in s.rule_ids
                  for s in sups.get(f.line, ()))
        if hit and f.rule_id != "TL000":
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


def lint(paths: Sequence[str], root: Optional[pathlib.Path] = None,
         include_corpus: bool = False,
         only: Optional[Set[str]] = None
         ) -> Tuple[Project, List[Finding], List[Finding]]:
    project = build_project(paths, root, include_corpus=include_corpus)
    findings = run_rules(project, only=only)
    active, suppressed = split_suppressed(project, findings)
    active.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return project, active, suppressed


def apply_fixes(project: Project, findings: Iterable[Finding]) -> List[str]:
    """Apply whole-line fixes whose original text still matches on disk.
    Returns the relpaths that were rewritten."""
    by_path: Dict[str, List[Finding]] = {}
    for f in findings:
        if f.fix is not None:
            by_path.setdefault(f.path, []).append(f)
    touched: List[str] = []
    for relpath, fs in sorted(by_path.items()):
        mod = next((m for m in project.modules if m.relpath == relpath), None)
        if mod is None:
            continue
        lines = mod.path.read_text().splitlines(keepends=True)
        changed = False
        for f in fs:
            idx = f.line - 1
            orig, new = f.fix
            if 0 <= idx < len(lines) and lines[idx].rstrip("\n") == orig:
                eol = "\n" if lines[idx].endswith("\n") else ""
                lines[idx] = new + eol
                changed = True
        if changed:
            mod.path.write_text("".join(lines))
            touched.append(relpath)
    return touched


def render_human(active: List[Finding], suppressed: List[Finding],
                 n_files: int) -> str:
    out = []
    for f in active:
        tag = " [fixable]" if f.fix is not None else ""
        out.append(f"{f.path}:{f.line}: {f.rule_id} {f.message}{tag}")
    out.append(f"tracelint: {len(active)} finding(s) "
               f"({len(suppressed)} suppressed) across {n_files} file(s), "
               f"{len(base.names())} rules")
    return "\n".join(out)


def render_json(active: List[Finding], suppressed: List[Finding],
                n_files: int) -> str:
    return json.dumps({
        "rules": [{"id": r.id, "name": r.name, "summary": r.summary,
                   "contract": r.contract, "fixable": r.fixable}
                  for r in base.all_rules()],
        "files": n_files,
        "findings": [f.to_json() for f in active],
        "suppressed": [f.to_json() for f in suppressed],
    }, indent=2)


def self_test(corpus_dir: pathlib.Path, root: pathlib.Path) -> Tuple[bool, str]:
    """Prove every registered rule fires on its known-bad fixture and stays
    quiet on its known-good twin; prove suppressions suppress.  Returns
    (ok, report)."""
    report: List[str] = []
    ok = True
    rule_ids = ["TL000"] + base.names()
    for rule_id in rule_ids:
        low = rule_id.lower()
        bad = corpus_dir / f"{low}_bad.py"
        good = corpus_dir / f"{low}_ok.py"
        if not bad.exists():
            ok = False
            report.append(f"FAIL {rule_id}: missing corpus fixture {bad.name}")
            continue
        _, active, _ = lint([str(bad)], root=root, include_corpus=True)
        fired = [f for f in active if f.rule_id == rule_id]
        if fired:
            report.append(f"ok   {rule_id}: fires on {bad.name} "
                          f"({len(fired)} finding(s))")
        else:
            ok = False
            report.append(f"FAIL {rule_id}: no finding on {bad.name}")
        if good.exists():
            _, active_g, _ = lint([str(good)], root=root, include_corpus=True)
            noise = [f for f in active_g if f.rule_id == rule_id]
            if noise:
                ok = False
                report.append(f"FAIL {rule_id}: false positive on "
                              f"{good.name}:{noise[0].line}")
    sup = corpus_dir / "suppressed_ok.py"
    if sup.exists():
        _, active_s, suppressed_s = lint([str(sup)], root=root,
                                         include_corpus=True)
        if active_s:
            ok = False
            report.append(f"FAIL suppressions: {len(active_s)} finding(s) "
                          f"leaked through {sup.name} "
                          f"(first: {active_s[0].rule_id}:{active_s[0].line})")
        else:
            report.append(f"ok   suppressions: {len(suppressed_s)} "
                          f"finding(s) suppressed in {sup.name}")
    report.append("self-test: " + ("PASS" if ok else "FAIL"))
    return ok, "\n".join(report)
