"""Traced-context discovery and value-taint analysis.

The trace rules (TL001-TL003) only apply inside code that JAX traces: scan
bodies, jitted functions, Pallas kernel bodies, and the callables stored in
``register(Scheme(...))`` / ``register(ChannelModel(...))`` blocks.  This
module finds those functions statically by seeding a per-module call graph
and walking it to a fixed point, and provides a light taint analysis that
distinguishes tracer-derived values from static (Python-time) configuration
so that e.g. ``float(cfg.num_devices)`` inside a scan body is not a finding
while ``float(grad_norm)`` is.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

# jax.lax / jax primitives whose callable arguments are traced.  Maps the
# attribute name to the positional indices holding callables.
_TRACING_CALLS = {
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": (1, 2, 3, 4),
    "vmap": (0,),
    "pmap": (0,),
    "jit": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "custom_jvp": (0,),
    "custom_vjp": (0,),
    "pallas_call": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
}

# Attribute accesses that always concretize to static Python values even on
# tracers (shape metadata), so they never carry taint.
_META_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}

# Calls that concretize or inspect without tracing hazards.
_CONCRETIZING_CALLS = {"len", "isinstance", "type", "getattr", "hasattr",
                       "maybe_positive", "static_broadcasted_argnums"}

# Parameter names that conventionally carry static Python configuration into
# traced helpers in this codebase (dataclass configs, scheme records, sizes
# closed over via static_argnames).  Attributes read off them stay untainted.
STATIC_PARAM_NAMES = {
    "cfg", "config", "fl_cfg", "ota_cfg", "chan_cfg", "channel_cfg", "self",
    "scheme", "sch", "model", "loss_fn", "grad_fn", "opt", "optimizer",
    "axes", "batch_axes", "interpret", "backend", "mesh", "spec", "geo",
    "ocfg",
}


def _decorator_is_jit(dec: ast.expr) -> bool:
    """@jax.jit, @jit, @functools.partial(jax.jit, ...) forms."""
    if isinstance(dec, ast.Call):
        fn = dec.func
        if _dotted(fn) in ("functools.partial", "partial") and dec.args:
            return _dotted(dec.args[0]) in ("jax.jit", "jit")
        return _dotted(fn) in ("jax.jit", "jit")
    return _dotted(dec) in ("jax.jit", "jit")


def _dotted(node: Optional[ast.expr]) -> str:
    """Best-effort dotted-name rendering of an expression ('' if complex)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _callable_name(node: ast.expr) -> Optional[str]:
    """Resolve a callable argument to a local function name if possible."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        fn = _dotted(node.func)
        if fn in ("functools.partial", "partial") and node.args:
            return _callable_name(node.args[0])
    return None


@dataclasses.dataclass
class TracedInfo:
    """Traced functions of one module."""

    # function name -> FunctionDef (module and nested functions, by bare name;
    # later definitions shadow earlier ones which matches runtime semantics
    # closely enough for this codebase's flat helper style)
    functions: Dict[str, ast.FunctionDef]
    traced: Set[str]            # names of functions reached from trace seeds
    kernels: Set[str]           # subset: Pallas kernel bodies
    lambdas: List[ast.Lambda]   # traced lambdas (scheme/channel callables)


def collect_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    funcs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs[node.name] = node
    return funcs


def find_traced(tree: ast.Module) -> TracedInfo:
    funcs = collect_functions(tree)
    seeds: Set[str] = set()
    kernels: Set[str] = set()
    lambdas: List[ast.Lambda] = []

    for name, fn in funcs.items():
        if name.startswith("_round_math"):
            seeds.add(name)
        if any(_decorator_is_jit(d) for d in fn.decorator_list):
            seeds.add(name)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn_name = _dotted(node.func)
        tail = fn_name.rsplit(".", 1)[-1]
        if tail in _TRACING_CALLS:
            for idx in _TRACING_CALLS[tail]:
                if idx < len(node.args):
                    arg = node.args[idx]
                    if isinstance(arg, ast.Lambda):
                        lambdas.append(arg)
                    else:
                        target = _callable_name(arg)
                        if target and target in funcs:
                            seeds.add(target)
                            if tail == "pallas_call":
                                kernels.add(target)
        # register(Scheme(...)) / register(ChannelModel(...)): every callable
        # keyword on the record is executed under trace by the engine.  Other
        # registries (lint rules, benchmark suites) hold host-side callables.
        if tail == "register" and node.args:
            rec = node.args[0]
            if isinstance(rec, ast.Call) and _dotted(rec.func).rsplit(
                    ".", 1)[-1] in ("Scheme", "ChannelModel"):
                for kw in rec.keywords:
                    if kw.value is None:
                        continue
                    if isinstance(kw.value, ast.Lambda):
                        lambdas.append(kw.value)
                    else:
                        target = _callable_name(kw.value)
                        if target and target in funcs:
                            seeds.add(target)

    # Fixed-point walk: a local function called from a traced function is
    # itself traced.  (Cross-module edges are not followed; each module seeds
    # its own traced set via jit/pallas_call/register markers.)
    traced = set(seeds)
    changed = True
    while changed:
        changed = False
        for name in list(traced):
            fn = funcs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                    callee = node.func.id
                    if callee in funcs and callee not in traced:
                        traced.add(callee)
                        changed = True
    return TracedInfo(functions=funcs, traced=traced, kernels=kernels,
                      lambdas=lambdas)


class Taint:
    """Per-function forward taint pass over names.

    A name is *tainted* when it (may) hold a tracer.  Parameters are tainted
    unless their name marks them as static config (``STATIC_PARAM_NAMES``) or
    they carry a scalar/str annotation.  Assignments propagate expression
    taint; attribute reads off untainted bases stay untainted; shape metadata
    never taints.
    """

    def __init__(self, fn: ast.FunctionDef):
        self.tainted: Set[str] = set()
        args = list(fn.args.posonlyargs) + list(fn.args.args)
        static_names = set(STATIC_PARAM_NAMES)
        for dec in fn.decorator_list:
            static_names |= _static_argnames(dec)
        # keyword-only params are static in this codebase: pallas kernels
        # take refs positionally and bind compile-time knobs after `*`, and
        # jitted functions mark traced-vs-static via static_argnames anyway
        defaulted = {a.arg for a, d in zip(
            reversed(args), reversed(fn.args.defaults))
            if isinstance(d, ast.Constant)}
        for a in args:
            if a.arg in static_names or a.arg in defaulted:
                continue
            if a.annotation is not None and _dotted(a.annotation) in (
                    "int", "float", "bool", "str", "Optional[int]"):
                continue
            self.tainted.add(a.arg)

    def is_tainted(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _META_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `is` / `is not` always compare Python identity (None checks);
            # they concretize regardless of operand taint.
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.is_tainted(node.left)
                    or any(self.is_tainted(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return (self.is_tainted(node.body) or self.is_tainted(node.orelse)
                    or self.is_tainted(node.test))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            return self._call_tainted(node)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, (ast.Dict,)):
            return any(v is not None and self.is_tainted(v)
                       for v in list(node.keys) + list(node.values))
        # Unknown expression kinds: assume tainted only if any child name is.
        return any(isinstance(n, ast.Name) and n.id in self.tainted
                   for n in ast.walk(node))

    def _call_tainted(self, node: ast.Call) -> bool:
        fn = _dotted(node.func)
        tail = fn.rsplit(".", 1)[-1]
        if tail in _CONCRETIZING_CALLS:
            return False
        root = fn.split(".", 1)[0]
        if root in ("jnp", "jax", "lax", "pl", "plgpu", "optax"):
            return True
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            return False  # .item() concretizes (flagged separately by TL001)
        if fn in ("float", "int", "bool", "str", "tuple"):
            # Concretization call: result is a host scalar.  Whether the CALL
            # itself is legal is TL001's question, not a taint question.
            return False
        # Unknown callee: conservative — tainted if any argument is.
        return (any(self.is_tainted(a) for a in node.args)
                or any(kw.value is not None and self.is_tainted(kw.value)
                       for kw in node.keywords))

    def assign(self, targets: List[ast.expr], value: ast.expr) -> None:
        tainted = self.is_tainted(value)
        for t in targets:
            for name in _target_names(t):
                if tainted:
                    self.tainted.add(name)
                else:
                    self.tainted.discard(name)


def _target_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _static_argnames(dec: ast.expr) -> Set[str]:
    """Pull static_argnames out of @functools.partial(jax.jit, ...) forms."""
    if not isinstance(dec, ast.Call):
        return set()
    out: Set[str] = set()
    for kw in dec.keywords:
        if kw.arg == "static_argnames" and isinstance(kw.value, (ast.Tuple, ast.List)):
            for e in kw.value.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    out.add(e.value)
        elif kw.arg == "static_argnames" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            out.add(kw.value.value)
    return out


def walk_statements(fn: ast.FunctionDef):
    """Yield statements of ``fn`` in source order, skipping nested defs
    (they get their own traced/taint treatment)."""

    def _walk(body):
        for stmt in body:
            yield stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
                continue
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    yield from _walk(sub)
            for handler in getattr(stmt, "handlers", ()) or ():
                yield from _walk(handler.body)

    yield from _walk(fn.body)
