"""Buffer-lifetime and scan-carry rules: TL007, TL008.

TL007: ``jax.jit(..., donate_argnums=...)`` invalidates the donated operand
buffers.  The engine's pattern — ``params, opt_state, ... = run_chunk(params,
opt_state, ...)`` — rebinds the donated names in the same statement, which is
safe; reading a donated name afterward without rebinding dereferences a
deleted buffer.  The rule tracks donating callables (direct ``jax.jit``
assignments and factory functions that *return* a donating jit) and flags
reads of donated names after the call.

TL008: ``lax.scan`` requires the carry pytree to be stable.  When the init,
the body's carry unpacking, the body's returned carry, and the call-site
destructuring are all tuple literals, their arities must agree — a 6-leaf
init against a 7-leaf unpack fails only at trace time with an opaque pytree
error; here it is a one-line diagnostic.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .base import Finding, Rule, register
from .context import _callable_name, _dotted, walk_statements
from .rules_trace import _stmt_exprs, _walk_expr


def _donating_jit(call: ast.expr) -> Optional[Tuple[int, ...]]:
    """Donated positions if ``call`` is jax.jit(..., donate_argnums=...)."""
    if not (isinstance(call, ast.Call) and _dotted(call.func).endswith("jit")):
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            val = kw.value
            if isinstance(val, (ast.Tuple, ast.List)):
                nums = tuple(e.value for e in val.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, int))
                return nums
            if isinstance(val, ast.Constant) and isinstance(val.value, int):
                return (val.value,)
            return ()
    return None


def _donating_factories(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Functions whose return value is a donating jit (engine builders)."""
    out: Dict[str, Tuple[int, ...]] = {}
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                nums = _donating_jit(node.value)
                if nums:
                    out[fn.name] = nums
    return out


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    out: Set[str] = set()
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Name):
                out.add(node.id)
    return out


def _tl007(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        factories = _donating_factories(mod.tree)
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # donating callables visible in this function
            donating: Dict[str, Tuple[int, ...]] = {}
            dead: Dict[str, int] = {}   # name -> line its buffer was donated
            for stmt in walk_statements(fn):
                rebound = _assigned_names(stmt)
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and isinstance(stmt.value, ast.Call):
                    name = stmt.targets[0].id
                    nums = _donating_jit(stmt.value)
                    if nums is None:
                        callee = _dotted(stmt.value.func)
                        nums = factories.get(callee)
                    if nums:
                        donating[name] = nums

                # reads of dead names anywhere in this statement's exprs
                for expr in _stmt_exprs(stmt):
                    for node in _walk_expr(expr):
                        if isinstance(node, ast.Name) \
                                and isinstance(node.ctx, ast.Load) \
                                and node.id in dead:
                            # the donating call itself re-consumes its args;
                            # skip names donated by THIS statement (added
                            # below), only prior donations count
                            findings.append(Finding(
                                "TL007", mod.relpath, node.lineno,
                                f"`{node.id}` was donated at line "
                                f"{dead[node.id]} (donate_argnums) and its "
                                f"buffer is deleted; rebind it from the "
                                f"call's results before reuse"))
                            dead.pop(node.id)  # one report per donation

                # donation by calls in this statement
                newly_dead: Dict[str, int] = {}
                for expr in _stmt_exprs(stmt):
                    for node in _walk_expr(expr):
                        if isinstance(node, ast.Call) \
                                and isinstance(node.func, ast.Name) \
                                and node.func.id in donating:
                            for pos in donating[node.func.id]:
                                if pos < len(node.args) \
                                        and isinstance(node.args[pos], ast.Name):
                                    newly_dead[node.args[pos].id] = node.lineno
                for name in rebound:
                    dead.pop(name, None)
                    newly_dead.pop(name, None)
                dead.update(newly_dead)
    return findings


def _tuple_arity(node: Optional[ast.expr]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _resolve_name(fn: ast.AST, name: str) -> Optional[ast.expr]:
    value = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    value = node.value
    return value


def _tl008(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            local_funcs = {n.name: n for n in ast.walk(fn)
                           if isinstance(n, ast.FunctionDef)}
            for stmt in walk_statements(fn):
                for expr in _stmt_exprs(stmt):
                    for node in _walk_expr(expr):
                        if not (isinstance(node, ast.Call)
                                and _dotted(node.func).endswith("lax.scan")
                                and len(node.args) >= 2):
                            continue
                        arities: List[Tuple[int, int, str]] = []  # (arity, line, what)
                        init = node.args[1]
                        if isinstance(init, ast.Name):
                            init = _resolve_name(fn, init.id)
                        a = _tuple_arity(init)
                        if a is not None:
                            arities.append((a, node.lineno, "scan init carry"))
                        body_name = _callable_name(node.args[0])
                        body = local_funcs.get(body_name) if body_name else None
                        if body is not None and body.args.args:
                            carry_param = body.args.args[0].arg
                            for inner in ast.walk(body):
                                if isinstance(inner, ast.Assign) \
                                        and isinstance(inner.value, ast.Name) \
                                        and inner.value.id == carry_param:
                                    ua = _tuple_arity(inner.targets[0])
                                    if ua is not None:
                                        arities.append(
                                            (ua, inner.lineno,
                                             "body carry unpack"))
                                if isinstance(inner, ast.Return) \
                                        and isinstance(inner.value, ast.Tuple) \
                                        and len(inner.value.elts) == 2:
                                    ra = _tuple_arity(inner.value.elts[0])
                                    if ra is not None:
                                        arities.append(
                                            (ra, inner.lineno,
                                             "body returned carry"))
                        # call-site destructuring: (a, b, ...), ys = scan(...)
                        if isinstance(stmt, ast.Assign) \
                                and stmt.value is node \
                                and isinstance(stmt.targets[0], ast.Tuple) \
                                and len(stmt.targets[0].elts) == 2:
                            da = _tuple_arity(stmt.targets[0].elts[0])
                            if da is not None:
                                arities.append(
                                    (da, stmt.lineno, "call-site unpack"))
                        if len({a for a, _, _ in arities}) > 1:
                            detail = "; ".join(f"{what}={a} (line {ln})"
                                               for a, ln, what in arities)
                            findings.append(Finding(
                                "TL008", mod.relpath, node.lineno,
                                f"scan carry leaf-set mismatch: {detail}; "
                                f"the carry pytree must be identical in "
                                f"init, body unpack, and body return"))
    return findings


register(Rule(
    id="TL007", name="donated-buffer-reuse",
    summary="read of a buffer after a donate_argnums call invalidated it",
    contract="chunked multi-round engine's donation discipline (PR 2/6)",
    check=_tl007))

register(Rule(
    id="TL008", name="scan-carry-stability",
    summary="lax.scan carry arity must agree across init/unpack/return",
    contract="chunk-scan carry layout (_make_chunk_scan, streaming rounds)",
    check=_tl008))
