"""TL009: the flight recorder stays out of traced contexts.

``repro.obs`` is host-side by contract — recorders only ever see values the
engine already transferred at a chunk boundary, which is what makes
telemetry trajectory-invisible (recorder on vs off is bitwise-identical;
see the parity suite in tests/test_obs.py).  An ``obs`` call inside a scan
body / jitted function would at best concretize tracers (crash) and at
worst silently bake one trace-time sample into the compiled program while
adding host syncs to every round.  This rule enforces the static half of
the contract: no ``repro.obs`` API call and no recorder-method call may
appear inside a traced context.
"""
from __future__ import annotations

import ast
from typing import List

from .base import Finding, Rule, register
from .context import _dotted, find_traced

# a dotted call with any of these segments is an obs-API call: obs.make(...),
# repro.obs.params_sha256(...), obs.profiling.rss_mb(...)
_OBS_SEGMENTS = {"obs", "obsprof"}

# recorder-protocol methods; calling one on a conventionally-named recorder
# variable inside a traced body is a finding even without the obs module in
# scope (runtime threads recorders through as parameters)
_RECORDER_METHODS = {"emit", "flush", "close", "latest", "select",
                     "on_manifest", "on_round", "on_chunk", "on_eval"}
_RECORDER_NAMES = {"recorder", "rec", "sink"}


def _tl009(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        info = find_traced(mod.tree)
        seen = set()
        bodies: List[ast.AST] = [info.functions[n] for n in sorted(info.traced)
                                 if n in info.functions]
        bodies.extend(info.lambdas)
        for body in bodies:
            for node in ast.walk(body):
                if not isinstance(node, ast.Call):
                    continue
                fn = _dotted(node.func)
                msg = None
                if fn and _OBS_SEGMENTS.intersection(fn.split(".")):
                    msg = (f"`{fn}` (repro.obs) called inside a traced "
                           "context; telemetry is host-side only — emit at "
                           "the chunk boundary after the dispatch returns")
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in _RECORDER_METHODS
                      and _dotted(node.func.value).rsplit(".", 1)[-1]
                      in _RECORDER_NAMES):
                    msg = (f"recorder method `.{node.func.attr}()` called "
                           "inside a traced context; recorders only consume "
                           "host values at chunk boundaries")
                if msg is not None and (node.lineno, msg) not in seen:
                    seen.add((node.lineno, msg))
                    findings.append(Finding("TL009", mod.relpath,
                                            node.lineno, msg))
    return findings


register(Rule(
    id="TL009", name="obs-in-trace",
    summary="repro.obs / recorder call inside a traced context",
    contract="flight-recorder trajectory invisibility (PR 10, tests/"
             "test_obs.py parity suite)",
    check=_tl009))
