"""Config-classification and diagnostics-sync contracts: TL005, TL006.

TL005 guards the sweep engine's batchable/structural split (PR 4): every
dataclass field of ``FLConfig`` / ``ChannelConfig`` must be claimed by
exactly one of the ``BATCHED_*_FIELDS`` tables or the ``STRUCTURAL_*_FIELDS``
exemption tables, every batched field must actually be collapsed by
``structural_config`` (else two configs that differ in it would silently
share one compiled program), and the collapse set must not touch structural
fields.  ``OTAConfig`` has no batched lanes, so its whole field set must be
claimed by ``STRUCTURAL_OTA_FIELDS``.

TL006 keeps ``DIAG_KEYS`` and the history dicts assembled in
``fed/runtime.py`` in lockstep: each ``diag_core`` literal must be a subset,
and each final ``diag`` literal (with ``**diag_core`` expanded) must equal
``DIAG_KEYS`` exactly — a key present in one but not the other either drops a
diagnostic on the floor or KeyErrors deep inside the scan driver.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .base import Finding, Rule, register
from .context import _dotted


@dataclasses.dataclass(frozen=True)
class _ClassSpec:
    class_name: str
    batched_table: Optional[str]
    structural_table: str
    # how structural_config collapses this class: 'fl' = replace(cfg, ...),
    # any other name = replace(cfg.<name>, ...) (e.g. 'channel', 'client'),
    # None = no collapse machinery
    collapse: Optional[str]


_SPECS = (
    _ClassSpec("FLConfig", "BATCHED_FL_FIELDS", "STRUCTURAL_FL_FIELDS", "fl"),
    _ClassSpec("ChannelConfig", "BATCHED_CHANNEL_FIELDS",
               "STRUCTURAL_CHANNEL_FIELDS", "channel"),
    _ClassSpec("ClientConfig", "BATCHED_CLIENT_FIELDS",
               "STRUCTURAL_CLIENT_FIELDS", "client"),
    _ClassSpec("OTAConfig", None, "STRUCTURAL_OTA_FIELDS", None),
)

# nested config dataclasses structural_config collapses via
# replace(cfg.<attr>, ...) — the _collapse_kwargs keying, and the FLConfig
# kwargs exempt from the "collapses a structural field" check (the rebuilt
# sub-configs are passed back through the outer replace)
_NESTED_COLLAPSE = ("channel", "client")


def _is_dataclass_def(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        name = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
        if name.endswith("dataclass"):
            return True
    return False


def _dataclass_fields(node: ast.ClassDef) -> List[Tuple[str, int, str]]:
    """(field, lineno, annotation) for every annotated field."""
    out = []
    for stmt in node.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            ann = _dotted(stmt.annotation)
            if ann.startswith("ClassVar") or "ClassVar[" in ast.dump(stmt.annotation):
                continue
            out.append((stmt.target.id, stmt.lineno, ann))
    return out


def _string_tuple_assign(tree: ast.Module, name: str
                         ) -> Optional[Tuple[List[str], int]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name \
                        and isinstance(node.value, (ast.Tuple, ast.List)):
                    vals = [e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)]
                    return vals, node.lineno
    return None


def _collapse_kwargs(tree: ast.Module) -> Dict[str, Set[str]]:
    """Keyword names of the dataclasses.replace calls in structural_config,
    keyed by 'fl' (first arg a bare Name) or the nested attribute name
    (first arg an Attribute like cfg.channel / cfg.client)."""
    out: Dict[str, Set[str]] = {"fl": set()}
    out.update({k: set() for k in _NESTED_COLLAPSE})
    for fn in ast.walk(tree):
        if not (isinstance(fn, ast.FunctionDef)
                and fn.name == "structural_config"):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and _dotted(node.func).endswith("replace") and node.args:
                first = node.args[0]
                if isinstance(first, ast.Name):
                    kind = "fl"
                elif isinstance(first, ast.Attribute) \
                        and first.attr in out:
                    kind = first.attr
                else:
                    continue
                out[kind] |= {kw.arg for kw in node.keywords if kw.arg}
    return out


def _tl005(project) -> List[Finding]:
    findings: List[Finding] = []
    # project-wide discovery: classes, tables, and collapse sets may live in
    # different modules (runtime.py holds the FL/channel tables, ota.py the
    # OTA one, channel.py the ChannelConfig dataclass)
    classes: Dict[str, Tuple[str, ast.ClassDef]] = {}
    tables: Dict[str, Tuple[str, List[str], int]] = {}
    collapse: Dict[str, Set[str]] = {"fl": set()}
    collapse.update({k: set() for k in _NESTED_COLLAPSE})
    collapse_mod = None
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and _is_dataclass_def(node):
                if node.name in {s.class_name for s in _SPECS}:
                    classes[node.name] = (mod.relpath, node)
        for spec in _SPECS:
            for tname in (spec.batched_table, spec.structural_table):
                if tname and tname not in tables:
                    hit = _string_tuple_assign(mod.tree, tname)
                    if hit is not None:
                        tables[tname] = (mod.relpath, hit[0], hit[1])
        got = _collapse_kwargs(mod.tree)
        if any(got.values()):
            collapse = got
            collapse_mod = mod.relpath

    for spec in _SPECS:
        if spec.class_name not in classes:
            continue
        cls_path, cls_node = classes[spec.class_name]
        fields = _dataclass_fields(cls_node)
        field_names = {f for f, _, _ in fields}
        batched = tables.get(spec.batched_table or "", ("", [], 0))[1]
        structural = tables.get(spec.structural_table, ("", [], 0))[1]

        for fname, lineno, ann in fields:
            in_b, in_s = fname in batched, fname in structural
            if not in_b and not in_s:
                findings.append(Finding(
                    "TL005", cls_path, lineno,
                    f"{spec.class_name}.{fname} is unclassified: add it to "
                    f"{spec.batched_table or 'a batched table'} (sweep lane) "
                    f"or {spec.structural_table} (structural axis)"))
            elif in_b and in_s:
                findings.append(Finding(
                    "TL005", cls_path, lineno,
                    f"{spec.class_name}.{fname} is claimed by BOTH "
                    f"{spec.batched_table} and {spec.structural_table}; "
                    f"a field has exactly one classification"))

        for tname in (spec.batched_table, spec.structural_table):
            if tname and tname in tables:
                tpath, tvals, tline = tables[tname]
                for stale in [v for v in tvals if v not in field_names]:
                    findings.append(Finding(
                        "TL005", tpath, tline,
                        f"{tname} lists {stale!r} which is not a field of "
                        f"{spec.class_name} (stale classification entry)"))

        if spec.collapse is not None and collapse_mod is not None:
            ckw = collapse[spec.collapse]
            for fname in batched:
                if fname in field_names and fname not in ckw:
                    findings.append(Finding(
                        "TL005", collapse_mod, 1,
                        f"batched field {spec.class_name}.{fname} is not "
                        f"collapsed by structural_config; two configs "
                        f"differing only in it would batch into one compiled "
                        f"program with distinct structure"))
            for kname in sorted(ckw):
                if kname in field_names and kname not in batched \
                        and not (spec.collapse == "fl"
                                 and kname in _NESTED_COLLAPSE):
                    findings.append(Finding(
                        "TL005", collapse_mod, 1,
                        f"structural_config collapses {spec.class_name}."
                        f"{kname} which is not in {spec.batched_table}; "
                        f"structurally-distinct configs would alias"))
    return findings


def _dict_assigns(tree: ast.Module, names: Tuple[str, ...]
                  ) -> List[Tuple[str, ast.Dict, int]]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id in names:
                    out.append((t.id, node.value, node.lineno))
    return out


def _dict_keys(d: ast.Dict) -> Tuple[Set[str], List[str]]:
    """(string keys, names of **-unpacked dicts)."""
    keys: Set[str] = set()
    unpacked: List[str] = []
    for k, v in zip(d.keys, d.values):
        if k is None:
            if isinstance(v, ast.Name):
                unpacked.append(v.id)
        elif isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.add(k.value)
    return keys, unpacked


def _tl006(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        hit = _string_tuple_assign(mod.tree, "DIAG_KEYS")
        if hit is None:
            continue
        diag_keys, dk_line = set(hit[0]), hit[1]
        assigns = _dict_assigns(mod.tree, ("diag", "diag_core"))
        core_sets = [_dict_keys(d)[0] for n, d, _ in assigns if n == "diag_core"]
        produced: Set[str] = set()
        for name, d, lineno in assigns:
            keys, unpacked = _dict_keys(d)
            if name == "diag_core":
                extra = keys - diag_keys
                if extra:
                    findings.append(Finding(
                        "TL006", mod.relpath, lineno,
                        f"diag_core writes keys {sorted(extra)} that are not "
                        f"in DIAG_KEYS (line {dk_line})"))
                produced |= keys
                continue
            # final diag dict: expand **diag_core against every diag_core
            # variant (dense and streaming must BOTH complete the key set)
            variants = core_sets if ("diag_core" in unpacked and core_sets) \
                else [set()]
            for core in variants:
                full = keys | core
                missing, extra = diag_keys - full, full - diag_keys
                if missing:
                    findings.append(Finding(
                        "TL006", mod.relpath, lineno,
                        f"diag dict is missing DIAG_KEYS entries "
                        f"{sorted(missing)}; the history recorder indexes "
                        f"every key each round"))
                if extra:
                    findings.append(Finding(
                        "TL006", mod.relpath, lineno,
                        f"diag dict writes keys {sorted(extra)} that are not "
                        f"in DIAG_KEYS; they would be dropped silently"))
            produced |= keys
        if assigns:
            never = diag_keys - produced - set().union(*core_sets) \
                if core_sets else diag_keys - produced
            for key in sorted(never):
                findings.append(Finding(
                    "TL006", mod.relpath, dk_line,
                    f"DIAG_KEYS entry {key!r} is never written by any diag "
                    f"dict in this module"))
    return findings


register(Rule(
    id="TL005", name="config-classification-completeness",
    summary="every config field claimed by batched tables, structural tables,"
            " and the structural_config collapse consistently",
    contract="sweep-engine batchable/structural split (PR 4 run_batched)",
    check=_tl005))

register(Rule(
    id="TL006", name="diag-keys-sync",
    summary="history-dict keys in fed/runtime.py match DIAG_KEYS exactly",
    contract="per-round diagnostics recorder (both drivers, PR 2/6)",
    check=_tl006))
