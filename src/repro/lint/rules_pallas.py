"""Pallas kernel constraints: TL004.

Two checks on kernels that *accumulate* (AugAssign into an output ref, the
``pl.when(kb == 0)`` init / ``+=`` pattern used by the streaming kernels):

1. every ``jax.ShapeDtypeStruct`` in the call's ``out_shape`` must be fp32 —
   accumulating partial block sums in bf16/f16 loses the paper's normalized
   magnitudes;
2. no full-axis (axis-less) ``jnp`` reductions inside the body when the grid
   is multi-dimensional — a bare ``jnp.sum(x)`` inside a (K-block, N-block)
   grid collapses the block axes the grid is supposed to keep separate.

Non-accumulating kernels (one output tile per grid step) may legally reduce
their whole tile, so they are exempt from check 2.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .base import Finding, Rule, register
from .context import _callable_name, _dotted, collect_functions

_REDUCTIONS = {"sum", "max", "min", "mean", "prod", "amax", "amin"}


def _enclosing_function_map(tree: ast.Module) -> Dict[int, ast.FunctionDef]:
    """Map pallas_call lineno -> function whose body contains the call."""
    out: Dict[int, ast.FunctionDef] = {}
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and _dotted(node.func).endswith("pallas_call"):
                out[node.lineno] = fn
    return out


def _local_assignment(fn: Optional[ast.FunctionDef], name: str) -> Optional[ast.expr]:
    if fn is None:
        return None
    value = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    value = node.value
    return value


def _resolve(node: Optional[ast.expr], fn: Optional[ast.FunctionDef]) -> Optional[ast.expr]:
    if isinstance(node, ast.Name):
        return _local_assignment(fn, node.id)
    return node


def _kernel_accumulates(kernel: ast.FunctionDef) -> bool:
    for node in ast.walk(kernel):
        if isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Subscript):
            base = node.target.value
            if isinstance(base, ast.Name) and base.id.endswith("_ref"):
                return True
    return False


def _shape_structs(node: ast.expr) -> List[ast.Call]:
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call) \
                and _dotted(n.func).endswith("ShapeDtypeStruct"):
            out.append(n)
    return out


def _struct_dtype(call: ast.Call) -> Optional[str]:
    dtype: Optional[ast.expr] = None
    if len(call.args) >= 2:
        dtype = call.args[1]
    for kw in call.keywords:
        if kw.arg == "dtype":
            dtype = kw.value
    if dtype is None:
        return None
    name = _dotted(dtype)
    return name.rsplit(".", 1)[-1] if name else None


def _tl004(project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        funcs = collect_functions(mod.tree)
        encl = _enclosing_function_map(mod.tree)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and _dotted(node.func).endswith("pallas_call")
                    and node.args):
                continue
            kernel_name = _callable_name(node.args[0])
            kernel = funcs.get(kernel_name) if kernel_name else None
            if kernel is None or not _kernel_accumulates(kernel):
                continue
            caller = encl.get(node.lineno)

            # check 1: accumulator out_shape dtypes must be float32
            out_shape = None
            for kw in node.keywords:
                if kw.arg == "out_shape":
                    out_shape = _resolve(kw.value, caller)
            if out_shape is not None:
                for struct in _shape_structs(out_shape):
                    dt = _struct_dtype(struct)
                    if dt is not None and dt != "float32":
                        findings.append(Finding(
                            "TL004", mod.relpath, struct.lineno,
                            f"accumulating kernel `{kernel_name}` declares a "
                            f"{dt} out_shape; block accumulators must be "
                            f"float32"))

            # check 2: axis-less reductions inside multi-dim gridded bodies
            grid = None
            for kw in node.keywords:
                if kw.arg == "grid":
                    grid = _resolve(kw.value, caller)
            if isinstance(grid, ast.Tuple) and len(grid.elts) >= 2:
                for inner in ast.walk(kernel):
                    if not isinstance(inner, ast.Call):
                        continue
                    fn = _dotted(inner.func)
                    parts = fn.split(".")
                    if len(parts) == 2 and parts[0] in ("jnp", "np", "lax") \
                            and parts[1] in _REDUCTIONS:
                        has_axis = any(kw.arg == "axis"
                                       for kw in inner.keywords) \
                            or len(inner.args) >= 2
                        if not has_axis:
                            findings.append(Finding(
                                "TL004", mod.relpath, inner.lineno,
                                f"full-axis `{fn}` reduction inside "
                                f"accumulating kernel `{kernel_name}` with a "
                                f"{len(grid.elts)}-d grid; reduce with an "
                                f"explicit axis so block axes stay separate"))
    return findings


register(Rule(
    id="TL004", name="pallas-kernel-constraints",
    summary="fp32 accumulators and explicit-axis reductions in gridded kernels",
    contract="kernel-vs-reference numerics parity (PR 3/6 streaming kernels)",
    check=_tl004))
