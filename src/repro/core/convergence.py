"""Convergence theory of the paper (Sec. III), as executable bounds.

These are the exact right-hand sides of Lemma 1 (eq. (13)) and Lemma 2
(eq. (15)); tests check the *empirical* trajectories produced by the runtime
against them (the bounds must hold and must exhibit the claimed rates).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


def _sum_hb(h, b) -> float:
    return float(np.sum(np.asarray(h, np.float64) * np.asarray(b, np.float64)))


def variance_term(h, b, noise_var: float, n: int) -> float:
    """The recurring term: sum_k 4 h_k^2 b_k^2 + (sum_k h_k b_k)^2 + n sigma^2."""
    h = np.asarray(h, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sum(4.0 * h * h * b * b) + _sum_hb(h, b) ** 2 + n * noise_var)


def case1_bound(T: int, p: float, a: float, h, b, L: float, theta_th: float,
                noise_var: float, n: int, expected_loss_drop: float) -> float:
    """Lemma 1, eq. (13): bound on min_{t<=T} ||grad F(w_t)|| with eta_t = 1/t^p.

    Sub-linear: both terms scale as 1/T^{1-p}.
    """
    if not (0.5 < p < 1.0):
        raise ValueError("p must lie in (1/2, 1)")
    shb = _sum_hb(h, b)
    if a <= 0 or shb <= 0:
        raise ValueError("need a > 0 and sum h_k b_k > 0")
    cos_th = math.cos(theta_th)
    t1 = expected_loss_drop / (T ** (1.0 - p) * cos_th * a * shb)
    t2 = (2.0 * p / (T ** (1.0 - p) * (2.0 * p - 1.0))) \
        * (a * L / (2.0 * cos_th * shb)) * variance_term(h, b, noise_var, n)
    return t1 + t2


def q_max(eta: float, a: float, h, b, M: float, G: float, theta_th: float) -> float:
    """Eq. (14): contraction factor of the strongly-convex case."""
    val = 1.0 - 2.0 * M * math.cos(theta_th) * eta * a * _sum_hb(h, b) / G
    return max(val, 0.0)


def case2_bound(T: int, eta: float, a: float, h, b, L: float, M: float, G: float,
                theta_th: float, noise_var: float, n: int,
                w1_dist_sq: float) -> float:
    """Lemma 2, eq. (15): bound on F(w_T) - F(w*) under constant eta.

    Linear rate (q_max)^{T-1} toward a bias floor.
    """
    q = q_max(eta, a, h, b, M, G, theta_th)
    shb = _sum_hb(h, b)
    first = 0.5 * L * (q ** (T - 1)) * w1_dist_sq
    coeff = max(a * eta * G / (2.0 * M * math.cos(theta_th) * shb), (a * eta) ** 2)
    second = 0.5 * L * coeff * variance_term(h, b, noise_var, n)
    return first + second


def case2_bias_floor(Z: float, L: float, G: float, M: float, theta_th: float,
                     s: float) -> float:
    """Minimized second term of (15) for q_max = s in (0,1):
    C2(s) = (Z+1) L G^2 (1-s) / (8 M^2 cos^2 th)."""
    return (Z + 1.0) * L * G * G * (1.0 - s) / (8.0 * M * M * math.cos(theta_th) ** 2)


def s_for_epsilon(epsilon: float, Z: float, L: float, G: float, M: float,
                  theta_th: float) -> float:
    """Paper Sec. IV-B: s = 1 - 8 M^2 cos^2(th) eps / ((Z+1) L G^2)."""
    return 1.0 - 8.0 * M * M * math.cos(theta_th) ** 2 * epsilon / ((Z + 1.0) * L * G * G)


def rounds_to_reach(epsilon_extra: float, q: float, w1_dist_sq: float, L: float) -> int:
    """Rounds needed for the linear term (L/2) q^{T-1} ||w1-w*||^2 <= epsilon_extra."""
    if not (0.0 < q < 1.0):
        return 1
    lhs = 0.5 * L * w1_dist_sq
    if lhs <= epsilon_extra:
        return 1
    return 1 + math.ceil(math.log(epsilon_extra / lhs) / math.log(q))


@dataclasses.dataclass(frozen=True)
class RateFit:
    """Least-squares rate fit of a trajectory, for validating claimed rates."""
    exponent: float     # fit of log(err) ~ exponent * log(t)  (sub-linear check)
    ratio: float        # geometric mean of err_{t+1}/err_t     (linear check)


def fit_rate(errors: Sequence[float], burn_in: int = 2) -> RateFit:
    e = np.asarray(errors, np.float64)[burn_in:]
    e = np.maximum(e, 1e-30)
    t = np.arange(burn_in + 1, burn_in + 1 + e.shape[0], dtype=np.float64)
    slope = float(np.polyfit(np.log(t), np.log(e), 1)[0])
    ratios = e[1:] / e[:-1]
    return RateFit(exponent=slope, ratio=float(np.exp(np.mean(np.log(ratios)))))
