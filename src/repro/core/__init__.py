"""Paper core: over-the-air normalized-gradient aggregation + theory."""
from repro.core.channel import (ChannelConfig, draw_channel, channel_for_round,
                                draw_fading_state, draw_noise, envelope,
                                DEFAULT_B_MAX, DEFAULT_CHANNEL_MEAN,
                                DEFAULT_MODEL, DEFAULT_NOISE_VAR,
                                DEFAULT_THETA_TH)
from repro.core.ota import (OTAConfig, BACKENDS, aggregate,
                            apply_update, device_transform, superpose,
                            server_post, per_device_norm, per_device_sq_norm,
                            per_device_mean_std, tree_num_elements,
                            transmit_norms, transmit_energy,
                            participation_fold)
from repro.core.schemes import (Scheme, DeviceStats, register as register_scheme,
                                get as get_scheme)


def __getattr__(name):
    # live view of the registry (PEP 562) — see repro.core.ota.SCHEMES
    if name == "SCHEMES":
        from repro.core import schemes as _schemes
        return _schemes.names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from repro.core.amplification import (Problem3Solution, Problem3SolutionJax,
                                      solve_problem3, solve_problem3_jax,
                                      solve_problem6, problem3_objective,
                                      optimal_S, case1_receiver_gain,
                                      optimize_case1, optimize_case2,
                                      Case1Parameters, Case2Parameters)
from repro.core.convergence import (case1_bound, case2_bound, q_max,
                                    case2_bias_floor, s_for_epsilon,
                                    variance_term, rounds_to_reach, fit_rate,
                                    RateFit)
