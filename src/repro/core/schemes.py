"""The scheme registry: every OTA aggregation scheme is defined exactly ONCE
here and consumed unchanged by all three execution backends —

* ``vmap``    (``repro.core.ota``)                  — leading device axis K
* ``mesh``    (``repro.distribution.ota_collectives``) — one shard = one device
* ``kernels`` (``repro.fed.kernel_path``)           — fused Pallas [K, N] path

A scheme describes the paper's device-side transmit transform

    x_k = ( pre(g_k) + shift_k ) * scale_k                       (per device)

with ``pre`` an element-wise transform (identity, or sign for one-bit),
``shift_k``/``scale_k`` per-device scalars derived from cheap per-device
statistics (norm / moments), plus an optional server-side post-transform of
the superposed signal and the error-free side information it needs.  Every
scheme of this shape runs on the fused Pallas kernel path for free.

What a scheme author implements (and NOTHING else — backends are generic):

``device_scale(stats, grad_bound)``   per-device multiplicative scale.  Must
    be written with element-wise jnp ops only: the same callable receives
    ``[K]`` statistics arrays on the vmap/kernels backends and scalar
    statistics on the mesh backend (each shard computes its own).
``device_shift(stats, grad_bound)``   optional additive pre-scale shift
    (benchmark2's ``-mean``).  Folds into a scalar post-kernel correction on
    the kernels backend, so it costs nothing there.
``pre``                               'identity' or 'sign'; applied in-register
    inside the fused kernel.
``tensor_scale(stats, grad_bound)``   for ``per_tensor=True`` schemes: one
    scale per (device, tensor) instead of one per device.
``collect_side(stats)`` / ``side_info`` the error-free side information the
    server folds back in.  Backends reduce it with h_k b_k weights and hand
    ``server_post(y, folded)`` the already-reduced values, so the same
    post-transform works under both jnp-sum (vmap/kernels) and psum (mesh).
``transmit_sq_norm(stats, grad_bound)`` per-device transmit energy
    ``||x_k||^2`` — the quantity the paper's power constraint (eq. 8) bounds;
    surfaced as the ``tx_energy`` diagnostic by the FL runtime.

Registering here is the ONLY step: the registry drives ``SCHEMES``, config
validation, and all three backends (demonstrated by the ``clipped`` scheme
below, which exists in no other module yet runs on every backend — see
tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
EPS = 1e-12

# element-wise pre-transforms the fused kernel knows how to apply in-register
PRE_TRANSFORMS = {
    "identity": lambda x: x,
    "sign": jnp.sign,
}


@dataclasses.dataclass(frozen=True)
class DeviceStats:
    """Per-device gradient statistics, backend-layout agnostic.

    Arrays are ``[K]`` in the stacked (vmap / kernels) layout and scalars in
    the mesh (per-shard) layout; scheme callables must therefore use
    element-wise jnp ops only.  ``count`` (= N, coordinates per device) is a
    python int in both layouts.
    """

    count: int
    sq_norm: jax.Array                                   # ||g_k||^2, global
    total: Optional[jax.Array] = None                    # sum_j g_k[j]
    tensor_sq_norms: Optional[Tuple[jax.Array, ...]] = None

    @property
    def norm(self) -> jax.Array:
        return jnp.sqrt(self.sq_norm)

    @property
    def mean(self) -> jax.Array:
        return self.total / self.count

    @property
    def var(self) -> jax.Array:
        return jnp.maximum(self.sq_norm / self.count - jnp.square(self.mean), 0.0)

    @property
    def std(self) -> jax.Array:
        return jnp.sqrt(self.var)


ScaleFn = Callable[[DeviceStats, Optional[float]], jax.Array]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """One OTA aggregation scheme (see module docstring for the contract)."""

    name: str
    doc: str = ""
    pre: str = "identity"
    per_tensor: bool = False
    needs_moments: bool = False
    requires_grad_bound: bool = False
    # ideal (non-OTA) reference that bypasses the channel entirely — every
    # backend aggregates it as a plain mean
    baseline: bool = False
    side_info: Tuple[str, ...] = ()
    device_scale: Optional[ScaleFn] = None
    device_shift: Optional[ScaleFn] = None
    tensor_scale: Optional[Callable[[DeviceStats, Optional[float]],
                                    Tuple[jax.Array, ...]]] = None
    collect_side: Optional[Callable[[DeviceStats], Dict[str, Any]]] = None
    server_post: Optional[Callable[[PyTree, Dict[str, Any]], PyTree]] = None
    transmit_sq_norm: Optional[ScaleFn] = None

    def __post_init__(self):
        # the registration IS the whole extension step, so an incomplete
        # scheme must fail HERE — not diverge silently between backends later
        if self.pre not in PRE_TRANSFORMS:
            raise ValueError(f"unknown pre-transform {self.pre!r}")
        if self.transmit_sq_norm is None:
            raise ValueError(f"scheme {self.name!r} needs transmit_sq_norm "
                             "(eq. 8 energy accounting)")
        if self.baseline:
            return
        if self.per_tensor:
            if self.tensor_scale is None:
                raise ValueError(
                    f"per_tensor scheme {self.name!r} needs tensor_scale")
            if self.device_shift is not None:
                raise ValueError(
                    f"per_tensor scheme {self.name!r} cannot use device_shift "
                    "(unsupported by the backends)")
        elif self.device_scale is None:
            raise ValueError(f"scheme {self.name!r} needs device_scale "
                             "(or per_tensor + tensor_scale, or baseline=True)")


_REGISTRY: Dict[str, Scheme] = {}


def register(scheme: Scheme) -> Scheme:
    if scheme.name in _REGISTRY:
        raise ValueError(f"scheme {scheme.name!r} already registered")
    _REGISTRY[scheme.name] = scheme
    return scheme


def get(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; one of {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def validate_config(name: str, grad_bound: Optional[float]) -> Scheme:
    """Shared config validation: raised identically by ``OTAConfig`` and the
    mesh path's ``ota_psum`` (which previously let ``grad_bound=None`` slip
    through into NaNs)."""
    sch = get(name)
    if sch.requires_grad_bound and grad_bound is None:
        raise ValueError(f"{name} requires grad_bound (the max-norm G)")
    return sch


# ---------------------------------------------------------------------------
# backend-shared math


def compute_stats(tree: PyTree, scheme: Scheme, *, batched: bool) -> DeviceStats:
    """Per-device statistics; ``batched=True`` treats leaves' leading axis as
    the device axis K, ``batched=False`` reduces the whole (per-shard) tree."""
    leaves = jax.tree_util.tree_leaves(tree)
    if batched:
        k = leaves[0].shape[0]
        flat = [l.astype(jnp.float32).reshape(k, -1) for l in leaves]
        axis = 1
    else:
        flat = [l.astype(jnp.float32).reshape(-1) for l in leaves]
        axis = 0
    count = sum(l.shape[axis] for l in flat)
    tensor_sq = tuple(jnp.sum(jnp.square(l), axis=axis) for l in flat)
    sq_norm = sum(tensor_sq)
    total = (sum(jnp.sum(l, axis=axis) for l in flat)
             if scheme.needs_moments else None)
    return DeviceStats(count=count, sq_norm=sq_norm, total=total,
                       tensor_sq_norms=tensor_sq if scheme.per_tensor else None)


def _bcast(v, leaf, batched: bool):
    v = jnp.asarray(v)
    if batched:
        return v.reshape((leaf.shape[0],) + (1,) * (leaf.ndim - 1))
    return v


def transform(scheme: Scheme, tree: PyTree, stats: DeviceStats,
              grad_bound: Optional[float] = None, *, batched: bool,
              extra_scale=None, out_dtype=None) -> PyTree:
    """Apply ``x_k = (pre(g_k) + shift_k) * scale_k`` over a gradient pytree.

    ``extra_scale`` is an additional per-device factor folded into the scale —
    the mesh backend passes ``h_k b_k`` here so its single psum IS the
    over-the-air superposition.  ``out_dtype=None`` keeps each leaf's dtype
    (vmap path); the mesh path passes float32 (its ``reduce_dtype`` contract).
    """
    pre = PRE_TRANSFORMS[scheme.pre]
    if scheme.per_tensor:
        scales = scheme.tensor_scale(stats, grad_bound)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        for l, s in zip(leaves, scales):
            if extra_scale is not None:
                s = s * extra_scale
            lf = pre(l.astype(jnp.float32))
            out.append(lf * _bcast(s, l, batched))
        return jax.tree_util.tree_unflatten(treedef, out)

    scale = scheme.device_scale(stats, grad_bound)
    if extra_scale is not None:
        scale = scale * extra_scale
    shift = (scheme.device_shift(stats, grad_bound)
             if scheme.device_shift is not None else None)

    def one(l):
        if out_dtype is not None:
            l = l.astype(out_dtype)
        x = pre(l)
        if shift is not None:
            x = x + _bcast(shift, l, batched).astype(l.dtype)
        return x * _bcast(scale, l, batched).astype(l.dtype)

    return jax.tree_util.tree_map(one, tree)


def fold_side(side: Dict[str, Any], weighted_mean: Callable) -> Dict[str, Any]:
    """Reduce per-device side info to the server's view.  ``weighted_mean``
    is backend-supplied: an h_k b_k-weighted ``jnp.sum`` on the stacked
    backends, an h_k b_k-weighted ``psum`` on the mesh backend.  Python
    numbers (dimension constants like sqrt_n) pass through unreduced."""
    return {k: (weighted_mean(v) if isinstance(v, jax.Array) else v)
            for k, v in side.items()}


def fold_side_stacked(side: Dict[str, Any], h: jax.Array,
                      b: jax.Array) -> Dict[str, Any]:
    """The stacked-layout ([K] side info) fold both the vmap and kernels
    backends use — one definition, so their server post-transforms stay
    bitwise identical (the noisy parity contract)."""
    hb = (h * b).astype(jnp.float32)
    w = hb / (jnp.sum(hb) + EPS)
    return fold_side(side, lambda v: jnp.sum(w * v))


def transmit_energy(scheme: Scheme, stats: DeviceStats, b: jax.Array,
                    grad_bound: Optional[float] = None,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """Per-device transmit energies ``b_k^2 ||x_k||^2`` — the paper's eq. 8
    power budget — via the scheme's analytic ``transmit_sq_norm`` (no second
    pass over the gradients).  ``mask`` is an optional 0/1 per-device
    participation vector: a masked device transmits NOTHING that round, so
    its energy is exactly zero (not merely a zeroed superposition weight) —
    the accounting every backend and the FL runtime's ``tx_energy``
    diagnostic share."""
    e = (jnp.square(b.astype(jnp.float32))
         * scheme.transmit_sq_norm(stats, grad_bound))
    if mask is not None:
        e = e * mask.astype(jnp.float32)
    return e


def maybe_positive(noise_var) -> bool:
    """Python-level gate for "should the noise branch be traced?": True for a
    traced (or concrete-array) variance — the batched sweep engine threads
    sigma^2 as a per-experiment traced scalar, so the branch must be resolved
    at trace time — and for a positive python float.  Tracing the noise path
    with a concrete 0 adds ``sqrt(0) * z = 0`` exactly, so the gate is
    value-preserving either way."""
    return isinstance(noise_var, jax.Array) or noise_var > 0.0


def add_channel_noise(tree: PyTree, key: jax.Array, noise_var: float) -> PyTree:
    """Add the ES receiver noise z ~ N(0, sigma^2 I), one subkey per leaf.

    Every backend draws noise through this function with the SAME
    single-device tree structure, so a shared key gives bitwise-identical
    noise on vmap, mesh, and kernels — the property the three-way parity
    tests rely on."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(flat))
    std = jnp.sqrt(jnp.asarray(noise_var, jnp.float32))
    flat = [l + std * jax.random.normal(k, l.shape, jnp.float32)
            for l, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, flat)


# ---------------------------------------------------------------------------
# the registered schemes


def _ones(st: DeviceStats) -> jax.Array:
    return jnp.ones_like(st.sq_norm)


register(Scheme(
    name="normalized",
    doc="x_k = g_k / ||g_k||  (the paper, eq. 12)",
    device_scale=lambda st, gb: 1.0 / (st.norm + EPS),
    transmit_sq_norm=lambda st, gb: _ones(st),
))

register(Scheme(
    name="normalized_restored",
    doc="x_k = g_k / ||g_k|| with the hb-weighted mean norm folded back by "
        "the server from error-free side info (the benchmark2 pattern on "
        "the paper's eq.-12 transmit): unit transmit energy per device, but "
        "the aggregate keeps the cohort's magnitude — the statistic an "
        "algorithm-state update (e.g. SCAFFOLD's variate slot) needs at its "
        "original scale",
    side_info=("norm",),
    device_scale=lambda st, gb: 1.0 / (st.norm + EPS),
    collect_side=lambda st: {"norm": st.norm},
    server_post=lambda y, folded: jax.tree_util.tree_map(
        lambda l: l * folded["norm"], y),
    transmit_sq_norm=lambda st, gb: _ones(st),
))

register(Scheme(
    name="normalized_per_tensor",
    doc="beyond-paper LARS-flavoured variant: each tensor normalized by its "
        "own norm, scaled 1/sqrt(#tensors) so the total transmit norm is 1 — "
        "keeps a cold MoE expert's gradient from being drowned by the dense "
        "layers",
    per_tensor=True,
    tensor_scale=lambda st, gb: tuple(
        1.0 / ((jnp.sqrt(t) + EPS) * math.sqrt(len(st.tensor_sq_norms)))
        for t in st.tensor_sq_norms),
    transmit_sq_norm=lambda st, gb: _ones(st),
))

register(Scheme(
    name="raw",
    doc="x_k = g_k (no power discipline; diagnostic)",
    device_scale=lambda st, gb: _ones(st),
    transmit_sq_norm=lambda st, gb: st.sq_norm,
))

register(Scheme(
    name="benchmark1",
    doc="x_k = g_k / G — raw gradient under the conservative max-norm "
        "assumption of [7]; the worst-case bound G keeps the transmit "
        "amplitude <= b_k^max",
    requires_grad_bound=True,
    device_scale=lambda st, gb: _ones(st) / gb,
    transmit_sq_norm=lambda st, gb: st.sq_norm / (gb * gb),
))


def _benchmark2_post(y: PyTree, folded: Dict[str, Any]) -> PyTree:
    std_bar = folded["std"] * folded["sqrt_n"]
    mean_bar = folded["mean"]
    return jax.tree_util.tree_map(lambda l: l * std_bar + mean_bar, y)


register(Scheme(
    name="benchmark2",
    doc="x_k = (g_k - mean_k) / (std_k sqrt(N)) — standardization of [13], "
        "made energy-fair: the raw operation leaves ||x|| = sqrt(N) (the "
        "paper's unboundedness critique), so we rescale to unit norm and the "
        "server folds sqrt(N) back in (it knows the model dimension)",
    needs_moments=True,
    side_info=("mean", "std", "sqrt_n"),
    device_scale=lambda st, gb: 1.0 / ((st.std + EPS) * math.sqrt(st.count)),
    device_shift=lambda st, gb: -st.mean,
    collect_side=lambda st: {"mean": st.mean, "std": st.std,
                             "sqrt_n": math.sqrt(st.count)},
    server_post=_benchmark2_post,
    transmit_sq_norm=lambda st, gb: st.var / jnp.square(st.std + EPS),
))

register(Scheme(
    name="onebit",
    doc="x_k = sign(g_k)/sqrt(N) ([12]; over-the-air signSGD-MV — the server "
        "takes the sign of the aggregate; 1/sqrt(N) keeps ||x_k|| = 1 so the "
        "transmit power discipline matches)",
    pre="sign",
    device_scale=lambda st, gb: _ones(st) / math.sqrt(st.count),
    server_post=lambda y, folded: jax.tree_util.tree_map(jnp.sign, y),
    transmit_sq_norm=lambda st, gb: _ones(st),
))

register(Scheme(
    name="mean",
    doc="ideal noiseless FedSGD mean (upper-bound reference; bypasses the "
        "channel entirely — every backend special-cases it)",
    baseline=True,
    transmit_sq_norm=lambda st, gb: st.sq_norm,
))

register(Scheme(
    name="clipped",
    doc="x_k = g_k / max(||g_k||, G) — truncated-norm transmit: small "
        "gradients keep their magnitude information (like benchmark1) while "
        "large ones are clipped to the unit ball (no benchmark1 headroom "
        "waste).  Registered ONLY here, runs on all three backends — the "
        "registry's one-module extension contract.",
    requires_grad_bound=True,
    device_scale=lambda st, gb: 1.0 / jnp.maximum(st.norm, gb),
    transmit_sq_norm=lambda st, gb: jnp.minimum(st.sq_norm / (gb * gb), 1.0),
))
