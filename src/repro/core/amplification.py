"""System-parameter optimization (paper Sec. IV, Problems 1-8, Algorithm 1).

Everything reduces to **Problem 3**:

    Z = min_b  ( sum_k 4 h_k^2 b_k^2 + n sigma^2 ) / ( sum_k h_k b_k )^2
        s.t.   0 <= b_k <= b_k^max

which is non-convex, but (paper Lemma 3 + Problems 4-6) is solved *optimally*
by a bisection over ``r`` with an inner convex feasibility program:

    feasible(r)  <=>  min_{b in box} phi_r(b) <= 0,
    phi_r(b) = sqrt( sum_k 4 h_k^2 b_k^2 + n sigma^2 ) - r * sum_k h_k b_k

``phi_r`` is convex (norm composed with an affine map, minus a linear term —
paper Lemma 3/Appendix C), and the box is convex, so the inner problem is a
box-constrained convex program: L-BFGS-B finds its global optimum.  Total
complexity is ``O(log(1/eps_b))`` bisection steps times a polynomial convex
solve, matching the paper's ``O(log2(eps_b) (K+1)^3)`` claim.

After Problem 3, Case I picks ``S*`` by eq. (26) and ``a = 1/(S sum h_k b_k)``;
Case II picks ``a * eta`` from eq. (30) given a target contraction ``s=q_max``.

Imperfect CSI (``repro.channels.csi``): the ``h`` these solvers receive is
whatever channel knowledge the CALLER has.  The FL runtime hands them the
server's estimate ``h_hat`` — Algorithm 1, the receiver gain, and the
participation rescale are all server-side computations, so under
``ChannelConfig.csi_error > 0`` the optimized ``b, a`` are optimal for the
*estimated* channel while the air applies the true one; the induced
effective-gain misalignment is the runtime's ``csi_gain_err`` diagnostic.
The solvers themselves are CSI-agnostic — both accept any non-negative
amplitude vector (and ``solve_problem3_jax`` stays jit/vmap/scan-safe on a
traced one, which is how in-scan refreshes re-optimize on every round's
fresh estimate).

Two interchangeable Problem-3 solvers live here:

``solve_problem3``      float64 NumPy+SciPy (bisection + L-BFGS-B inner convex
                        program) — the host-side reference, used at ``setup()``
                        time and as the cross-check oracle in tests.
``solve_problem3_jax``  pure-JAX ``lax.while_loop`` bisection whose inner
                        feasibility program is solved in CLOSED FORM (see its
                        docstring) — jit/scan-safe, so block-fading FL rounds
                        re-run Algorithm 1 *inside* the compiled round loop
                        (``repro.fed.runtime``) with no host callback.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from scipy import optimize as sopt


@dataclasses.dataclass(frozen=True)
class Problem3Solution:
    b: np.ndarray          # optimal per-device amplification factors
    Z: float               # optimal objective of Problem 3
    r_star: float          # optimal r from the bisection (Z = r_star^2)
    iterations: int        # bisection iterations used


def problem3_objective(b: np.ndarray, h: np.ndarray, noise_var: float, n: int) -> float:
    """Objective of Problem 3: (sum 4 h^2 b^2 + n sigma^2) / (sum h b)^2."""
    b = np.asarray(b, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    num = float(np.sum(4.0 * h * h * b * b) + n * noise_var)
    den = float(np.sum(h * b)) ** 2
    return num / den


def _phi(b: np.ndarray, r: float, h: np.ndarray, c: float) -> Tuple[float, np.ndarray]:
    """phi_r(b) = sqrt(sum 4 h^2 b^2 + c) - r sum h b, with gradient."""
    q = np.sqrt(np.sum(4.0 * h * h * b * b) + c)
    val = q - r * float(np.sum(h * b))
    grad = (4.0 * h * h * b) / q - r * h
    return val, grad


def _min_phi_over_box(r: float, h: np.ndarray, c: float, b_max: np.ndarray) -> Tuple[float, np.ndarray]:
    """Inner convex feasibility program of the bisection (Problem 6 in value form).

    Returns (min phi, argmin b).  Convex objective over a box: L-BFGS-B from the
    upper corner is globally optimal.  phi is scale-benefitting (larger b helps
    the linear term), so the upper corner is an excellent warm start.
    """
    res = sopt.minimize(
        _phi, x0=b_max.copy(), args=(r, h, c), jac=True,
        method="L-BFGS-B", bounds=[(0.0, bm) for bm in b_max],
        options={"maxiter": 500, "ftol": 1e-16, "gtol": 1e-14},
    )
    return float(res.fun), np.asarray(res.x)


def solve_problem3(
    h: Sequence[float],
    noise_var: float,
    n: int,
    b_max: Sequence[float] | float,
    tol: float = 1e-10,
    max_iters: int = 200,
) -> Problem3Solution:
    """Algorithm 1 Part I: bisection on r + convex feasibility check.

    ``n`` is the model dimension N (the noise enters per coordinate).
    Memoized on the exact inputs: an experiment sweep re-solves the same
    (h, sigma^2, N, b_max) instance once per grid point per run — repeats
    (benchmark warm-up vs timed runs, seed-replicate setups over shared
    structural configs) hit the cache instead of the SciPy bisection.
    """
    h = np.asarray(h, dtype=np.float64)
    if np.isscalar(b_max):
        b_max = np.full_like(h, float(b_max))
    else:
        b_max = np.asarray(b_max, dtype=np.float64)
        if b_max.shape != h.shape:
            # the byte-keyed memo below cannot rely on numpy broadcasting to
            # reject ragged inputs — check explicitly
            raise ValueError(f"b_max shape {b_max.shape} must match h shape "
                             f"{h.shape}")
    sol = _solve_problem3_cached(h.tobytes(), h.shape[0], float(noise_var),
                                 int(n), b_max.tobytes(), float(tol),
                                 int(max_iters))
    # the cached record's array is shared; hand every caller its own copy
    return dataclasses.replace(sol, b=sol.b.copy())


@functools.lru_cache(maxsize=512)
def _solve_problem3_cached(h_bytes: bytes, k: int, noise_var: float, n: int,
                           b_max_bytes: bytes, tol: float,
                           max_iters: int) -> Problem3Solution:
    h = np.frombuffer(h_bytes, np.float64, count=k)
    b_max = np.frombuffer(b_max_bytes, np.float64, count=k)
    if np.any(h < 0):
        raise ValueError("channel coefficients must be non-negative magnitudes")
    if not np.any(h * b_max > 0):
        raise ValueError("sum h_k b_k^max must be positive for feasibility")
    c = float(n) * float(noise_var)
    # Noiseless edge (c = 0): Problem 3 becomes scale-invariant and b = 0
    # degenerates the objective to 0/0.  A vanishing floor keeps the bisection
    # well-posed without moving the optimum of any noisy instance.
    c = max(c, 1e-12 * float(np.sum(4.0 * h * h * b_max * b_max)))

    # r is feasible iff min_b phi_r(b) <= 0.  r at the upper corner is always
    # feasible, giving the initial hi; lo = 0 is infeasible (c > 0).
    r_hi = math.sqrt(problem3_objective(b_max, h, noise_var, n))
    r_lo = 0.0
    b_best = b_max.copy()
    iters = 0
    # Relative tolerance on r.
    while (r_hi - r_lo) > tol * max(1.0, r_hi) and iters < max_iters:
        r_mid = 0.5 * (r_lo + r_hi)
        val, b_arg = _min_phi_over_box(r_mid, h, c, b_max)
        if val <= 0.0:
            r_hi = r_mid
            b_best = b_arg
        else:
            r_lo = r_mid
        iters += 1

    # Polish: evaluate the true Problem-3 objective at the feasibility argmin.
    Z = problem3_objective(b_best, h, noise_var, n)
    return Problem3Solution(b=b_best, Z=Z, r_star=math.sqrt(Z), iterations=iters)


# ---------------------------------------------------------------------------
# jax-native Algorithm 1 (jit/scan-safe; runs inside the compiled FL engine)


class Problem3SolutionJax(NamedTuple):
    """Pytree-compatible twin of ``Problem3Solution`` (all fields jax arrays)."""

    b: jax.Array           # [K] optimal per-device amplification factors
    Z: jax.Array           # optimal objective of Problem 3
    r_star: jax.Array      # sqrt(Z)
    iterations: jax.Array  # bisection iterations used


EPS_DENOM = 1e-20


def _phi_min_waterfill(r, u_max: jax.Array, c):
    """Closed-form inner feasibility program: min over the box of
    ``phi_r(u) = sqrt(4||u||^2 + c) - r 1'u`` in received-signal coordinates
    ``u_k = h_k b_k`` (caps ``u_max_k = h_k b_k^max``).

    phi_r increases with ``||u||^2`` at fixed ``1'u``, and the minimum-norm
    box point with a given coordinate sum is the water-filling profile
    ``u_k(t) = min(t, u_max_k)`` — so the K-dimensional convex program
    collapses to a line search over t.  Between consecutive sorted caps u(t)
    is affine in t, phi_r is convex there (norm of an affine map minus a
    linear term) and its stationary point solves
    ``16 t^2 = r^2 (4 m t^2 + 4 D + c)`` in closed form (m = #uncapped
    coordinates, D = sum of capped caps squared).  Evaluating phi_r at every
    clamped per-segment stationary point is exact — no iterative inner solve.

    Returns ``(min phi_r, argmin t)``.
    """
    q = jnp.sort(u_max)                              # segment breakpoints
    k = q.shape[0]
    lo = jnp.concatenate([jnp.zeros((1,), q.dtype), q[:-1]])
    # capped mass below each segment: first j sorted caps
    csq = jnp.cumsum(q * q)
    d_cap = jnp.concatenate([jnp.zeros((1,), q.dtype), csq[:-1]])
    m = (k - jnp.arange(k)).astype(q.dtype)          # coords still growing
    denom = 16.0 - 4.0 * m * r * r
    t_star = r * jnp.sqrt((4.0 * d_cap + c) / jnp.maximum(denom, EPS_DENOM))
    # denom <= 0: phi_r decreases over the whole segment -> right endpoint
    t_star = jnp.where(denom > 0.0, t_star, q[-1])
    cand = jnp.concatenate([jnp.clip(t_star, lo, q), q[-1:]])
    u = jnp.minimum(cand[:, None], u_max[None, :])   # [K+1, K] path points
    vals = (jnp.sqrt(4.0 * jnp.sum(u * u, axis=1) + c)
            - r * jnp.sum(u, axis=1))
    i = jnp.argmin(vals)
    return vals[i], cand[i]


def solve_problem3_jax(h: jax.Array, noise_var, n: int, b_max,
                       tol: float = 1e-6,
                       max_iters: int = 100) -> Problem3SolutionJax:
    """Algorithm 1 Part I as a pure-JAX program: ``lax.while_loop`` bisection
    on r with the closed-form water-filling feasibility check.

    Matches ``solve_problem3`` (the float64 SciPy reference) to solver
    tolerance — see tests/test_engine.py — while being jit-, vmap- and
    scan-safe, so block-fading rounds re-optimize ``b_t`` on device.
    ``n`` is static (the model dimension); ``tol`` is relative on r.

    vmap note (the batched sweep engine relies on this): ``lax.while_loop``'s
    batching rule masks the carry update of lanes whose own condition is
    false, so a batched solve over stacked (h, sigma^2, b_max) instances is
    per-lane IDENTICAL (bitwise, CPU) to solo solves — each lane performs
    exactly its own bisection steps (tests/test_sweep.py pins this).
    """
    h = jnp.asarray(h)
    h = h.astype(jnp.promote_types(h.dtype, jnp.float32))
    b_max = jnp.broadcast_to(jnp.asarray(b_max, h.dtype), h.shape)
    u_max = h * b_max
    c = jnp.asarray(n, h.dtype) * jnp.asarray(noise_var, h.dtype)
    # same vanishing noise floor as the SciPy solver (noiseless edge)
    c = jnp.maximum(c, 1e-12 * jnp.sum(4.0 * u_max * u_max))

    sum_u = jnp.sum(u_max)
    r_hi0 = jnp.sqrt(4.0 * jnp.sum(u_max * u_max) + c) / sum_u
    t0 = jnp.max(u_max)                  # upper corner: feasible at r_hi0

    def cond(s):
        r_lo, r_hi, _, it = s
        return jnp.logical_and(
            (r_hi - r_lo) > tol * jnp.maximum(1.0, r_hi), it < max_iters)

    def body(s):
        r_lo, r_hi, t_best, it = s
        r_mid = 0.5 * (r_lo + r_hi)
        val, t_arg = _phi_min_waterfill(r_mid, u_max, c)
        feas = val <= 0.0
        return (jnp.where(feas, r_lo, r_mid),
                jnp.where(feas, r_mid, r_hi),
                jnp.where(feas, t_arg, t_best),
                it + 1)

    init = (jnp.zeros((), h.dtype), r_hi0, t0, jnp.zeros((), jnp.int32))
    _, _, t_best, it = jax.lax.while_loop(cond, body, init)
    u = jnp.minimum(t_best, u_max)
    b = jnp.where(h > 0, u / jnp.where(h > 0, h, 1.0), 0.0)
    # polish exactly like the SciPy solver: true objective at the argmin
    Z = (4.0 * jnp.sum(u * u) + c) / jnp.square(jnp.sum(u))
    return Problem3SolutionJax(b=b, Z=Z, r_star=jnp.sqrt(Z), iterations=it)


def solve_problem6(r: float, h: np.ndarray, noise_var: float, n: int,
                   b_max: np.ndarray) -> Tuple[float, np.ndarray]:
    """Literal Problem 6 (paper eq. (25)): min v s.t. cone constraint and
    0 <= b_k <= b_k^max + v.  Used as a faithfulness cross-check of the
    value-form feasibility test: V(r) <= 0  <=>  min_b phi_r(b) <= 0.

    Solved via SLSQP (convex, per Lemma 3).
    """
    K = h.shape[0]
    c = float(n) * float(noise_var)

    def obj(x):
        return x[-1]

    def obj_jac(x):
        g = np.zeros_like(x)
        g[-1] = 1.0
        return g

    def cone(x):
        b = x[:K]
        return r * float(np.sum(h * b)) - math.sqrt(float(np.sum(4 * h * h * b * b)) + c)

    cons = [{"type": "ineq", "fun": cone}]
    # 0 <= b_k <= b_max_k + v  ->  b_max_k + v - b_k >= 0
    for k in range(K):
        cons.append({"type": "ineq", "fun": (lambda x, k=k: b_max[k] + x[-1] - x[k])})
        cons.append({"type": "ineq", "fun": (lambda x, k=k: x[k])})

    def solve_from(x0):
        return sopt.minimize(obj, x0, jac=obj_jac, constraints=cons,
                             method="SLSQP",
                             options={"maxiter": 500, "ftol": 1e-12})

    def accepted(res):
        return (res.success and cone(res.x) >= -1e-8
                and float(np.min(res.x[:K])) >= -1e-10)

    res = solve_from(np.concatenate([b_max, [0.0]]))
    if not accepted(res):
        # SLSQP can fail from the (cone-infeasible) b_max start.  The cone is
        # satisfiable at *some* scale iff r > 2/sqrt(K) (best direction
        # b ~ 1/h_k, which equalizes h_k b_k); if it is, retry from a
        # strictly feasible interior point.  If it is not, the feasible set
        # of Problem 6 is empty at ANY v and the min over it is +inf —
        # report that instead of SLSQP's garbage iterate.
        gap = r * r * K * K - 4.0 * K
        if gap <= 1e-12 * max(1.0, c):
            if c <= 0.0:
                # noiseless edge: b = 0 meets the cone with equality, so the
                # minimum is finite: v* = -min(b_max) at b = 0
                return -float(np.min(b_max)), np.zeros(K)
            return math.inf, np.asarray(res.x[:K])
        t = 1.1 * math.sqrt(c / gap)
        b0 = t / h
        v0 = max(float(np.max(b0 - b_max)), 0.0) + 1e-6
        res = solve_from(np.concatenate([b0, [v0]]))
        if not accepted(res):
            # conservative upper bound from the feasible start itself
            return v0, b0
    return float(res.x[-1]), np.asarray(res.x[:K])


def optimal_S(Z: float, L: float, p: float, expected_loss_drop: float) -> float:
    """Case I, eq. (26): S* = sqrt( L (Z+1) p / ((2p-1) E{F(w1)-F(wT+1)}) )."""
    if not (0.5 < p < 1.0):
        raise ValueError("p must lie in (1/2, 1)")
    if expected_loss_drop <= 0:
        raise ValueError("expected loss drop must be positive")
    return math.sqrt(L * (Z + 1.0) * p / ((2.0 * p - 1.0) * expected_loss_drop))


def case1_receiver_gain(S: float, h: np.ndarray, b: np.ndarray) -> float:
    """Case I: a = 1 / (S * sum_k h_k b_k), from constraint (18a)."""
    denom = S * float(np.sum(h * b))
    if denom <= 0:
        raise ValueError("S * sum h_k b_k must be positive")
    return 1.0 / denom


@dataclasses.dataclass(frozen=True)
class Case1Parameters:
    b: np.ndarray
    a: float
    S: float
    Z: float
    p: float


def optimize_case1(h, noise_var, n, b_max, L, p, expected_loss_drop,
                   tol: float = 1e-10) -> Case1Parameters:
    """Full Algorithm 1: Problem 3 then eq. (26) then a = 1/(S sum h b)."""
    sol = solve_problem3(h, noise_var, n, b_max, tol=tol)
    S = optimal_S(sol.Z, L, p, expected_loss_drop)
    a = case1_receiver_gain(S, np.asarray(h, dtype=np.float64), sol.b)
    return Case1Parameters(b=sol.b, a=a, S=S, Z=sol.Z, p=p)


@dataclasses.dataclass(frozen=True)
class Case2Parameters:
    b: np.ndarray
    a_eta: float           # the product a*eta fixed by eq. (30)
    s: float               # chosen contraction factor q_max in (0, 1)
    Z: float
    bias_bound: float      # the minimized second term of (15): (Z+1) L G^2 (1-s) / (8 M^2 cos^2 th)


def optimize_case2(h, noise_var, n, b_max, L, M, G, theta_th,
                   s: Optional[float] = None, epsilon: Optional[float] = None,
                   tol: float = 1e-10) -> Case2Parameters:
    """Case II (Sec. IV-B, q_max in (0,1) branch).

    Exactly one of ``s`` (target contraction q_max) or ``epsilon`` (target bias)
    must be given.  From the paper: C2(s) = (Z+1) L G^2 (1-s) / (8 M^2 cos^2 th),
    and for a bias target eps: s = 1 - 8 M^2 cos^2(th) eps / ((Z+1) L G^2).
    a*eta then follows from eq. (30): 2 M cos(th) eta a sum h b = G (1-s).
    """
    if (s is None) == (epsilon is None):
        raise ValueError("specify exactly one of s / epsilon")
    sol = solve_problem3(h, noise_var, n, b_max, tol=tol)
    cos2 = math.cos(theta_th) ** 2
    if s is None:
        s = 1.0 - 8.0 * M * M * cos2 * epsilon / ((sol.Z + 1.0) * L * G * G)
        if s <= 0.0:
            # epsilon so loose that even q_max = 0 satisfies it; clamp into (0,1).
            s = 1e-6
    if not (0.0 < s < 1.0):
        raise ValueError(f"target contraction s must lie in (0,1), got {s}")
    h_arr = np.asarray(h, dtype=np.float64)
    sum_hb = float(np.sum(h_arr * sol.b))
    a_eta = G * (1.0 - s) / (2.0 * M * math.cos(theta_th) * sum_hb)
    bias = (sol.Z + 1.0) * L * G * G * (1.0 - s) / (8.0 * M * M * cos2)
    return Case2Parameters(b=sol.b, a_eta=a_eta, s=s, Z=sol.Z, bias_bound=bias)
