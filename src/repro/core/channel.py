"""Wireless channel substrate for the over-the-air computation FL system.

The paper (Sec. V) models the uplink between each of the K mobile devices and
the edge server as an i.i.d. Rayleigh-fading coefficient ``h_k`` with mean
``1e-5`` (free-space attenuation over 300 m at 3.5 GHz composed with a
unit-mean Rayleigh draw) and AWGN with variance ``sigma^2 = 1e-7``.

On a TPU mesh there is no radio: the channel is *simulated* deterministically
from a JAX PRNG key so an entire FL round — including the "air" — is a single
jittable, shardable program (see DESIGN.md Sec. 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

# Paper Sec. V defaults.
DEFAULT_CHANNEL_MEAN = 1e-5
DEFAULT_NOISE_VAR = 1e-7
DEFAULT_B_MAX = math.sqrt(5.0)
DEFAULT_THETA_TH = math.pi / 3.0


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Static description of the MAC channel between K devices and the ES."""

    num_devices: int
    channel_mean: float = DEFAULT_CHANNEL_MEAN
    noise_var: float = DEFAULT_NOISE_VAR
    # Per-device transmit-amplification cap b_k^max (paper uses sqrt(5) for all k).
    b_max: float = DEFAULT_B_MAX
    # Block-fading: if True the channel is redrawn every round; the paper's
    # analysis and experiments hold h_k fixed over iterations (no t superscript),
    # which is the default here.
    block_fading: bool = False

    def rayleigh_scale(self) -> float:
        # Rayleigh(sigma) has mean sigma * sqrt(pi/2).
        return self.channel_mean / math.sqrt(math.pi / 2.0)


def draw_channel(key: jax.Array, cfg: ChannelConfig,
                 scale: Optional[jax.Array] = None) -> jax.Array:
    """Draw ``h_k`` for k = 1..K, i.i.d. Rayleigh with the configured mean.

    A Rayleigh variate is the magnitude of a complex Gaussian:
    ``|CN(0, 2 sigma_r^2)| = sigma_r * sqrt(x1^2 + x2^2)``, x_i ~ N(0,1).

    ``scale`` overrides ``cfg.rayleigh_scale()`` with a (possibly traced)
    per-experiment value — the batched sweep engine's ``channel_mean`` axis
    redraws every experiment's channel from one vmapped program.
    """
    sigma_r = cfg.rayleigh_scale() if scale is None else scale
    x = jax.random.normal(key, (cfg.num_devices, 2))
    return sigma_r * jnp.sqrt(jnp.sum(x * x, axis=-1))


def channel_for_round(key: jax.Array, cfg: ChannelConfig, round_idx,
                      scale: Optional[jax.Array] = None) -> jax.Array:
    """Channel draw for a given round honouring the block-fading switch.

    ``round_idx`` may be a traced int32 scalar: the fold_in/draw pair is
    jit- and scan-safe, which is how the compiled FL engine
    (``repro.fed.runtime``) redraws ``h_t`` inside its ``lax.scan`` body
    with no host callback."""
    if cfg.block_fading:
        return draw_channel(jax.random.fold_in(key, round_idx), cfg, scale)
    return draw_channel(key, cfg, scale)


def draw_noise(key: jax.Array, shape, noise_var: float, dtype=jnp.float32) -> jax.Array:
    """AWGN vector z ~ N(0, sigma^2 I) received at the edge server."""
    return jnp.sqrt(jnp.asarray(noise_var, dtype)) * jax.random.normal(key, shape, dtype)


def mean_snr_db(cfg: ChannelConfig, b: Optional[jax.Array] = None) -> float:
    """Diagnostic: mean received SNR (dB) of a unit-norm signal per device."""
    b_val = float(jnp.mean(b)) if b is not None else cfg.b_max
    sig = (cfg.channel_mean * b_val) ** 2
    return 10.0 * math.log10(sig / cfg.noise_var)
