"""Wireless channel substrate for the over-the-air computation FL system.

The paper (Sec. V) models the uplink between each of the K mobile devices and
the edge server as an i.i.d. Rayleigh-fading coefficient ``h_k`` with mean
``1e-5`` (free-space attenuation over 300 m at 3.5 GHz composed with a
unit-mean Rayleigh draw) and AWGN with variance ``sigma^2 = 1e-7``.

On a TPU mesh there is no radio: the channel is *simulated* deterministically
from a JAX PRNG key so an entire FL round — including the "air" — is a single
jittable, shardable program (see DESIGN.md Sec. 2).

Beyond the paper's scalar-mean i.i.d. Rayleigh, ``ChannelConfig`` now
describes a full radio environment through the composable
``repro.channels`` subsystem:

* ``model`` selects the small-scale fading process from the channel-model
  registry (``'rayleigh'`` — the bitwise-compatible default — ``'rician'``
  with K-factor ``rician_k``, or time-correlated ``'ar1'`` Gauss-Markov
  fading with per-round correlation ``rho``);
* ``geometry`` (a ``repro.channels.geometry.GeometryConfig``) replaces the
  single ``channel_mean`` with per-device means from drawn distances ->
  path loss (+ optional log-normal shadowing);
* ``csi_error`` / ``csi_error_model`` split the TRUE ``h`` seen by the air
  from the server's ESTIMATE ``h_hat`` used for amplification and the
  receiver gain (``repro.channels.csi``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:   # pragma: no cover — avoids a core <-> channels cycle
    from repro.channels.geometry import GeometryConfig

# Paper Sec. V defaults.
DEFAULT_CHANNEL_MEAN = 1e-5
DEFAULT_NOISE_VAR = 1e-7
DEFAULT_B_MAX = math.sqrt(5.0)
DEFAULT_THETA_TH = math.pi / 3.0
DEFAULT_MODEL = "rayleigh"


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Static description of the MAC channel between K devices and the ES."""

    num_devices: int
    channel_mean: float = DEFAULT_CHANNEL_MEAN
    noise_var: float = DEFAULT_NOISE_VAR
    # Per-device transmit-amplification cap b_k^max (paper uses sqrt(5) for all k).
    b_max: float = DEFAULT_B_MAX
    # Block-fading: if True the channel is redrawn every round; the paper's
    # analysis and experiments hold h_k fixed over iterations (no t superscript),
    # which is the default here.
    block_fading: bool = False
    # --- wireless-environment axes (repro.channels) -----------------------
    # small-scale fading process, from the channel-model registry:
    # 'rayleigh' (paper default) | 'rician' | 'ar1'
    model: str = DEFAULT_MODEL
    # Rician K-factor (LOS power / scattered power); 0 == Rayleigh
    rician_k: float = 0.0
    # AR(1) per-round correlation of the 'ar1' model; rho = 0 IS block fading
    rho: float = 0.0
    # CSI estimation-error magnitude (0 = perfect CSI: h_hat is h bitwise)
    # and the error model applying it ('additive' | 'multiplicative')
    csi_error: float = 0.0
    csi_error_model: str = "additive"
    # large-scale structure: per-device distances -> path loss (+ shadowing)
    # -> heterogeneous per-device means (None keeps the scalar channel_mean)
    geometry: Optional["GeometryConfig"] = None

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got "
                             f"{self.num_devices}")
        if self.channel_mean <= 0.0:
            raise ValueError(f"channel_mean must be positive, got "
                             f"{self.channel_mean}")
        if self.noise_var < 0.0:
            raise ValueError(f"noise_var must be >= 0, got {self.noise_var}")
        if self.b_max <= 0.0:
            raise ValueError(f"b_max must be positive, got {self.b_max}")
        if self.rician_k < 0.0:
            raise ValueError(f"rician_k must be >= 0, got {self.rician_k}")
        if not 0.0 <= self.rho < 1.0:
            raise ValueError(f"rho must lie in [0, 1), got {self.rho}")
        if self.csi_error < 0.0:
            raise ValueError(f"csi_error must be >= 0, got {self.csi_error}")
        # registry-backed validation (lazy imports: repro.channels builds on
        # this module, so the registry cannot be imported at module scope)
        from repro import channels as _chl
        _chl.get(self.model)    # raises ValueError naming the registry
        if self.csi_error_model not in _chl.CSI_ERROR_MODELS:
            raise ValueError(
                f"unknown csi_error_model {self.csi_error_model!r}; "
                f"one of {_chl.CSI_ERROR_MODELS}")

    def rayleigh_scale(self) -> float:
        # Rayleigh(sigma) has mean sigma * sqrt(pi/2).
        return self.channel_mean / math.sqrt(math.pi / 2.0)

    def amplitude_scale(self) -> float:
        """The envelope scale handed to the configured fading model so that
        ``E[h_k] == channel_mean``.  For Rayleigh (and its AR(1) extension,
        whose stationary marginal is the same Rayleigh) this is the
        classical ``mean / sqrt(pi/2)``; for Rician the mean picks up the
        Laguerre factor ``L_{1/2}(-K) = (1+K) I0e(K/2) + K I1e(K/2)``."""
        base = self.rayleigh_scale()
        if self.model == "rician" and self.rician_k > 0.0:
            from scipy import special
            k = self.rician_k
            laguerre = float((1.0 + k) * special.i0e(k / 2.0)
                             + k * special.i1e(k / 2.0))
            return base / laguerre
        return base

    def time_varying(self) -> bool:
        """True when the channel evolves every round: block fading, or a
        model (AR(1)) that is inherently a per-round process."""
        if self.block_fading:
            return True
        from repro import channels as _chl
        return _chl.get(self.model).time_varying


def draw_fading_state(key: jax.Array, num_devices: int) -> jax.Array:
    """[K, 2] standard-Gaussian I/Q pair underlying one envelope draw — the
    shared primitive of every registered fading model (and the persistent
    state of the AR(1) process)."""
    return jax.random.normal(key, (num_devices, 2))


def envelope(state: jax.Array, scale) -> jax.Array:
    """Amplitude envelope ``scale * |state|`` of a [K, 2] I/Q state.
    ``scale`` may be a scalar or a per-device [K] vector (and either may be
    traced)."""
    return scale * jnp.sqrt(jnp.sum(state * state, axis=-1))


def draw_channel(key: jax.Array, cfg: ChannelConfig,
                 scale: Optional[jax.Array] = None) -> jax.Array:
    """Draw ``h_k`` for k = 1..K, i.i.d. Rayleigh with the configured mean.

    A Rayleigh variate is the magnitude of a complex Gaussian:
    ``|CN(0, 2 sigma_r^2)| = sigma_r * sqrt(x1^2 + x2^2)``, x_i ~ N(0,1).

    ``scale`` overrides ``cfg.rayleigh_scale()`` with a (possibly traced)
    per-experiment scalar — the batched sweep engine's ``channel_mean`` axis
    redraws every experiment's channel from one vmapped program — or a
    per-device ``[K]`` vector: the geometry subsystem's heterogeneous
    means (``repro.channels.geometry``).  Scalar behavior is bitwise
    unchanged.
    """
    sigma_r = cfg.rayleigh_scale() if scale is None else scale
    if hasattr(sigma_r, "shape") and getattr(sigma_r, "ndim", 0) > 0:
        if sigma_r.shape != (cfg.num_devices,):
            raise ValueError(
                f"per-device scale must have shape ({cfg.num_devices},), "
                f"got {sigma_r.shape}")
    return envelope(draw_fading_state(key, cfg.num_devices), sigma_r)


def draw_fading_state_block(key: jax.Array, dev_idx: jax.Array) -> jax.Array:
    """[len(dev_idx), 2] I/Q pairs with a DEVICE-INDEXED key schedule:
    device i's pair folds from ``fold_in(key, i)``, so any blocking of
    ``[0, K)`` concatenates to the same state — the lazy sampler behind the
    100k-device streaming path, which draws one K-block of channel at a time
    instead of materializing a [K, 2] array it mostly won't touch this
    block.  Deliberately a different stream from ``draw_fading_state`` (one
    monolithic [K, 2] draw has no per-device lazy form), so pick one
    schedule per experiment and stay with it."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(dev_idx)
    return jax.vmap(lambda k: jax.random.normal(k, (2,)))(keys)


def draw_channel_block(key: jax.Array, cfg: ChannelConfig,
                       dev_idx: jax.Array,
                       scale: Optional[jax.Array] = None) -> jax.Array:
    """Rayleigh draw of ``h`` restricted to the devices ``dev_idx`` — the
    blocking-invariant twin of ``draw_channel`` (device-indexed key
    schedule, see ``draw_fading_state_block``).  ``scale`` is a scalar or
    the ALREADY-GATHERED [len(dev_idx)] per-device scale."""
    sigma_r = cfg.rayleigh_scale() if scale is None else scale
    return envelope(draw_fading_state_block(key, dev_idx), sigma_r)


def channel_for_round(key: jax.Array, cfg: ChannelConfig, round_idx,
                      scale: Optional[jax.Array] = None) -> jax.Array:
    """Channel draw for a given round honouring the block-fading switch.

    ``round_idx`` may be a traced int32 scalar: the fold_in/draw pair is
    jit- and scan-safe, which is how the compiled FL engine
    (``repro.fed.runtime``) redraws ``h_t`` inside its ``lax.scan`` body
    with no host callback."""
    if cfg.block_fading:
        return draw_channel(jax.random.fold_in(key, round_idx), cfg, scale)
    return draw_channel(key, cfg, scale)


def draw_noise(key: jax.Array, shape, noise_var: float, dtype=jnp.float32) -> jax.Array:
    """AWGN vector z ~ N(0, sigma^2 I) received at the edge server."""
    return jnp.sqrt(jnp.asarray(noise_var, dtype)) * jax.random.normal(key, shape, dtype)


def mean_snr_db(cfg: ChannelConfig, b: Optional[jax.Array] = None) -> float:
    """Diagnostic: mean received SNR (dB) of a unit-norm signal per device."""
    b_val = float(jnp.mean(b)) if b is not None else cfg.b_max
    sig = (cfg.channel_mean * b_val) ** 2
    return 10.0 * math.log10(sig / cfg.noise_var)
