"""Over-the-air gradient aggregation (the paper's core, Sec. II): the single
entry point over the scheme registry and the three execution backends.

Every scheme is a device-side transform of the local gradient pytree plus a
server-side post-transform of the superposed signal

    y = a * ( sum_k h_k b_k x_k + z ),      z ~ N(0, sigma^2 I)      (eq. 10)

followed by the model update ``w <- w - eta * y`` (eq. 11).  The schemes
themselves (normalized — eq. 12 —, benchmark1/2, onebit, clipped, ...) are
defined ONCE in ``repro.core.schemes``; this module contains only
backend-independent plumbing and the vmap backend's math.

Backends (``OTAConfig.backend`` / ``FLConfig.backend``):

``vmap``     transforms act on *stacked* pytrees whose leaves carry a leading
             device axis K (``jax.vmap`` over clients); superposition is one
             fused fp32 tensordot per leaf.  Implemented here.
``kernels``  same stacked layout through the fused Pallas kernels — one
             batched [K, N] moments kernel for the per-device statistics and
             one fused superpose kernel with a per-device scale vector
             (``repro.fed.kernel_path``).
``mesh``     each data shard of a TPU mesh is one device; the superposition
             is a single ``psum`` (``repro.distribution.ota_collectives``).

All three consume the same ``Scheme`` objects, draw channel noise through the
same per-leaf key schedule, and agree allclose on the update direction y for
every registered scheme (tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import schemes

PyTree = Any

BACKENDS = ("vmap", "kernels", "mesh")


def __getattr__(name):
    # SCHEMES stays live as the registry grows (PEP 562): schemes registered
    # after import (repro.core.register_scheme) appear immediately
    if name == "SCHEMES":
        return schemes.names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_EPS = schemes.EPS


@dataclasses.dataclass(frozen=True)
class OTAConfig:
    """Per-round aggregation parameters (see ``amplification`` for how a, b
    are chosen optimally)."""

    scheme: str = "normalized"
    a: float = 1.0                       # receiver gain (server side)
    noise_var: float = 0.0               # sigma^2 of the AWGN at the ES
    grad_bound: Optional[float] = None   # G, required by benchmark1/clipped
    # When True the noise term is omitted (ideal channel); used by tests that
    # isolate the deterministic part of a scheme.
    noiseless: bool = False
    # which execution backend aggregate() routes through
    backend: str = "vmap"
    # Streaming superposition: aggregate the device axis in K-blocks of this
    # size (``None`` = dense, the bitwise-pinned default).  The vmap backend
    # runs a ``lax.scan`` over K-blocks into a single fp32 accumulator; the
    # kernels backend grids the K-way reduction itself ((N-block, K-block)
    # Pallas grid).  Streaming == dense up to float associativity of the
    # blocked sums (the noise draw is bitwise-shared).
    k_block: Optional[int] = None
    # Sharded streaming (requires k_block): partition the K-blocks over this
    # many mesh shards — each shard left-folds its own contiguous run of
    # blocks, and ONE deterministic cross-shard fold closes eq. (10)
    # (``distribution.ota_collectives.fold_shards``).  The value DEFINES the
    # hierarchical accumulation order, so the math is a function of the
    # config alone: execution on a physical mesh (shard_map, when
    # ``distribution.sharding.device_mesh`` finds the devices) and the
    # emulated single-device fallback are bitwise-identical.  ``None`` keeps
    # the PR-6 flat left fold bitwise-pinned.
    device_mesh: Optional[int] = None

    def __post_init__(self):
        schemes.validate_config(self.scheme, self.grad_bound)
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; one of {BACKENDS}")
        if self.k_block is not None:
            if self.k_block < 1:
                raise ValueError(f"k_block must be >= 1, got {self.k_block}")
            if self.backend == "mesh":
                raise ValueError("the mesh backend's device axis IS the mesh "
                                 "— k_block streaming applies to the stacked "
                                 "(vmap/kernels) backends; to parallelize a "
                                 "streamed round over local devices use "
                                 "device_mesh (the sharded streaming engine)")
        if self.device_mesh is not None:
            if self.device_mesh < 1:
                raise ValueError(
                    f"device_mesh must be >= 1, got {self.device_mesh}")
            if self.k_block is None:
                raise ValueError(
                    "device_mesh shards the K-block stream — set k_block "
                    "(the dense path has no block axis to partition)")
        # the sweep engine constructs OTAConfig with a traced noise_var
        # inside the compiled round program; validate concrete values only
        if isinstance(self.noise_var, (int, float)) and self.noise_var < 0.0:
            raise ValueError(f"noise_var must be >= 0, got {self.noise_var}")


# OTAConfig has no batched sweep lanes (the sweep engine batches FLConfig /
# ChannelConfig and derives per-round OTA parameters); every field is a
# structural axis.  tracelint TL005 checks this table stays exhaustive so a
# new field cannot be added without deciding its sweep classification.
STRUCTURAL_OTA_FIELDS = ("scheme", "a", "noise_var", "grad_bound",
                         "noiseless", "backend", "k_block", "device_mesh")


# ---------------------------------------------------------------------------
# fusion fences / pinned reductions
#
# fp32 results depend on how XLA clusters producers into its reduction
# loops.  Most of the repo never cares — one program, one lowering — but the
# sharded streaming engine promises BITWISE-identical trajectories across
# two different programs (shard_map on a physical mesh vs the emulated
# outer scan), so every value they share must compile in an
# execution-independent cluster.  ``fusion_fence`` materializes a tree
# behind an ``optimization_barrier`` (vmap-safe — the sweep engine batches
# these rounds); ``pinned_sum`` sandwiches a K-way reduction between
# barriers so the reduce op sits in its own cluster and XLA's strategy for
# it is a function of shape/dtype alone.


@jax.custom_batching.custom_vmap
def fence_leaf(x):
    return jax.lax.optimization_barrier(x)


@fence_leaf.def_vmap
def _fence_leaf_vmap(axis_size, in_batched, x):
    # the fence is an identity: under vmap it is the SAME barrier on the
    # batched value (optimization_barrier itself has no batching rule, so
    # the vmapped sweep engine needs this indirection)
    return jax.lax.optimization_barrier(x), in_batched[0]


def fusion_fence(tree: PyTree) -> PyTree:
    """Per-leaf ``optimization_barrier``: forces XLA to materialize the tree
    before any consumer, so downstream reductions compile independently of
    how the values were produced.  vmap-safe (see ``fence_leaf``)."""
    return jax.tree_util.tree_map(fence_leaf, tree)


def _pairwise_fold(x: jax.Array) -> jax.Array:
    """Fixed-association pairwise (binary-tree) sum of a 1-D array, built
    from elementwise adds only — no ``reduce`` op, so XLA has no
    reduction-tree choice to make."""
    tail = jnp.zeros((), jnp.float32)
    while x.shape[0] > 1:
        n = x.shape[0]
        if n % 2:
            tail = tail + x[n - 1]
            x = x[:n - 1]
        x = x[0::2] + x[1::2]
    return x[0] + tail


def pinned_sum(v: jax.Array) -> jax.Array:
    """Full-array sum with an execution-independent lowering: the operand is
    chunked and left-folded by a ``lax.scan`` whose body runs the
    fixed-association ``_pairwise_fold``.  The scan body compiles as its own
    HLO computation, so the fold's arithmetic cannot be re-clustered or
    FMA-contracted with whatever surrounds the call — which is exactly what
    happens to a plain (or even barrier-sandwiched) ``jnp.sum``: its lowering
    varies with the enclosing program and drifts by an ulp.  The sharded
    streaming round routes every out-of-scan real-valued reduction
    (effective-gain folds, diagnostics) through this so the shard_map and
    emulated programs stay bitwise-identical.  May differ from ``jnp.sum``
    by documented ulps — the sharded engine's trajectory is its own math
    spec (see FLConfig.device_mesh)."""
    v = v.astype(jnp.float32).ravel()
    n = v.shape[0]
    if n == 0:
        return jnp.zeros((), jnp.float32)
    if n == 1:
        return v[0]
    # chunk so the scan's trip count is >= 2: XLA inlines trip-count-1 while
    # loops, which would put the fold back into the surrounding program
    chunk = max(1, 1 << (max((n - 1).bit_length() - 2, 0)))
    rows = -(-n // chunk)
    # zero padding is exact: x + 0.0 == x for every fp32 x, so the padded
    # fold realizes a fixed association of the original elements
    v = jnp.pad(v, (0, rows * chunk - n))

    def body(acc, row):
        return acc + _pairwise_fold(row), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            v.reshape(rows, chunk))
    return total


# ---------------------------------------------------------------------------
# pytree helpers (leading axis = device)


def tree_num_elements(tree: PyTree) -> int:
    """Total number of scalar coordinates in one device's gradient (= N)."""
    return sum(int(jnp.size(l)) // l.shape[0] for l in jax.tree_util.tree_leaves(tree))


def per_device_sq_norm(stacked: PyTree) -> jax.Array:
    """[K] vector of squared global L2 norms, one per device."""
    leaves = jax.tree_util.tree_leaves(stacked)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
               for l in leaves)


def per_device_norm(stacked: PyTree) -> jax.Array:
    return jnp.sqrt(per_device_sq_norm(stacked))


def per_device_mean_std(stacked: PyTree) -> Tuple[jax.Array, jax.Array]:
    """[K] global mean and std over each device's full gradient vector."""
    leaves = jax.tree_util.tree_leaves(stacked)
    n = tree_num_elements(stacked)
    s1 = sum(jnp.sum(l.astype(jnp.float32).reshape(l.shape[0], -1), axis=1) for l in leaves)
    mean = s1 / n
    s2 = sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
             for l in leaves)
    var = jnp.maximum(s2 / n - jnp.square(mean), 0.0)
    return mean, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# device-side transforms (registry-driven)


def device_transform(scheme: str, stacked_grads: PyTree,
                     grad_bound: Optional[float] = None) -> Tuple[PyTree, dict]:
    """Apply a scheme's device-side transform.  Returns (x_k stack, side_info)."""
    sch = schemes.get(scheme)
    if sch.baseline:
        return stacked_grads, {}
    stats = schemes.compute_stats(stacked_grads, sch, batched=True)
    x = schemes.transform(sch, stacked_grads, stats, grad_bound, batched=True)
    side = sch.collect_side(stats) if sch.collect_side else {}
    return x, side


# ---------------------------------------------------------------------------
# superposition + server-side post-transform (the vmap backend)


def superpose(stacked_x: PyTree, h: jax.Array, b: jax.Array, a: float,
              key: Optional[jax.Array], noise_var: float) -> PyTree:
    """The MAC channel: y = a (sum_k h_k b_k x_k + z), one fused reduction
    per leaf.  Accumulates in fp32 regardless of the gradient dtype (bf16
    gradients would otherwise lose mass in the K-way sum) — the same
    ``reduce_dtype`` contract as the mesh path — and returns fp32 leaves."""
    hb = (h * b).astype(jnp.float32)
    summed = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(hb, l.astype(jnp.float32), axes=(0, 0)), stacked_x)
    # maybe_positive: a traced sigma^2 (the batched sweep engine's
    # per-experiment noise axis) must resolve the branch at trace time
    if key is not None and schemes.maybe_positive(noise_var):
        summed = schemes.add_channel_noise(summed, key, noise_var)
    return jax.tree_util.tree_map(lambda l: jnp.asarray(a, l.dtype) * l, summed)


def server_post(scheme: str, y: PyTree, side: dict, h: jax.Array,
                b: jax.Array) -> PyTree:
    """Server-side reconstruction applied after the receiver gain.  ``h``
    here is the channel AS THE SERVER KNOWS IT — pass the CSI estimate
    ``h_hat`` under imperfect CSI (see ``aggregate``)."""
    sch = schemes.get(scheme)
    if sch.server_post is None:
        return y
    return sch.server_post(y, schemes.fold_side_stacked(side, h, b))


# ---------------------------------------------------------------------------
# streaming superposition (K-blocked accumulation; OTAConfig.k_block)
#
# The carry API below is the single definition of "accumulate one K-block of
# transmit signals into a running fp32 aggregate": ``aggregate`` drives it
# with a ``lax.scan`` over a reshaped stacked pytree, and the FL runtime
# drives it with per-block *gradient computation* inside its own scan (the
# flat-memory 100k-device round, where a dense [K, ...] stack never exists).
# Parity with the dense path is exact up to float associativity of the
# blocked sums; the channel-noise draw is bitwise-shared (same key schedule
# on the same single-device template).


def _device_template(stacked: PyTree) -> PyTree:
    """Single-device fp32 zeros with the stacked tree's per-device shapes."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape[1:], jnp.float32), stacked)


def _side_parts(sch: schemes.Scheme, count: int):
    """Split a scheme's side info into (array-valued names, number-valued
    dict) using dummy stats — the array parts are hb-weighted running sums in
    the streaming carry, the numbers (dimension constants) pass through."""
    if sch.collect_side is None:
        return (), {}
    z = jnp.zeros((1,), jnp.float32)
    dummy = schemes.DeviceStats(count=count, sq_norm=z,
                                total=z if sch.needs_moments else None)
    side = sch.collect_side(dummy)
    arrays = tuple(k for k, v in side.items() if isinstance(v, jax.Array))
    numbers = {k: v for k, v in side.items() if not isinstance(v, jax.Array)}
    return arrays, numbers


def streaming_carry(cfg: OTAConfig, template: PyTree) -> dict:
    """Zero accumulator carry for a K-blocked aggregation.  ``template`` is a
    single-device gradient pytree (shapes only).  The carry holds the running
    fp32 superposition (a pytree on the vmap backend, the raveled flat vector
    on the kernels backend), the hb-weighted side-info sums, the running
    server-side hb mass, and the kernels path's scalar shift correction."""
    sch = schemes.get(cfg.scheme)
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(template))
    if cfg.backend == "kernels" and not sch.baseline:
        acc = jnp.zeros((n,), jnp.float32)
    else:
        acc = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), template)
    side_names, _ = _side_parts(sch, n)
    zero = jnp.zeros((), jnp.float32)
    return {"acc": acc, "hb_srv": zero, "shift": zero,
            "side": {name: zero for name in side_names}}


def streaming_block(cfg: OTAConfig, carry: dict, block_tree: PyTree,
                    hb_air: jax.Array, hb_srv: jax.Array, *,
                    stats: Optional[schemes.DeviceStats] = None,
                    grad_bound=None,
                    baseline_weights: Optional[jax.Array] = None) -> dict:
    """Accumulate one K-block of device gradients into the streaming carry.

    ``hb_air`` is the true-channel superposition weight h_k b_k of the block
    (the air); ``hb_srv`` the server-known weight h_hat_k b_k (side-info
    folding).  ``stats`` lets a caller that already computed the block's
    per-device statistics (the runtime's diagnostics pass) share them.
    ``grad_bound`` overrides ``cfg.grad_bound`` with a traced value (the
    batched sweep lane).  ``baseline_weights`` (baseline schemes only) turns
    the running plain sum into a weighted one — the FL runtime's masked
    participant mean — in which case the caller passes ``num_devices=1`` at
    finish."""
    sch = schemes.get(cfg.scheme)
    if grad_bound is None:
        grad_bound = cfg.grad_bound
    if stats is None:
        stats = schemes.compute_stats(block_tree, sch, batched=True)
    hb_air = hb_air.astype(jnp.float32)
    hb_srv = hb_srv.astype(jnp.float32)

    if sch.baseline:
        # ideal reference: running (optionally weighted) sum — the caller
        # divides at finish
        if baseline_weights is None:
            acc = jax.tree_util.tree_map(
                lambda A, l: A + jnp.sum(l.astype(jnp.float32), axis=0),
                carry["acc"], block_tree)
        else:
            w = baseline_weights.astype(jnp.float32)
            acc = jax.tree_util.tree_map(
                lambda A, l: A + jnp.tensordot(w, l.astype(jnp.float32),
                                               axes=(0, 0)),
                carry["acc"], block_tree)
        return {**carry, "acc": acc,
                "hb_srv": carry["hb_srv"] + jnp.sum(hb_srv)}

    shift = carry["shift"]
    if cfg.backend == "kernels":
        from repro.kernels import ops
        leaves = jax.tree_util.tree_leaves(block_tree)
        kb = leaves[0].shape[0]
        flat2d = [l.astype(jnp.float32).reshape(kb, -1) for l in leaves]
        if sch.per_tensor:
            pre_fn = schemes.PRE_TRANSFORMS[sch.pre]
            scales = sch.tensor_scale(stats, grad_bound)
            flat = jnp.concatenate(
                [pre_fn(l2) * s[:, None] for l2, s in zip(flat2d, scales)],
                axis=1)
            scale = hb_air
            kernel_pre = "identity"
        else:
            flat = jnp.concatenate(flat2d, axis=1)
            scale = sch.device_scale(stats, grad_bound) * hb_air
            if sch.device_shift is not None:
                shift = shift + jnp.sum(
                    scale * sch.device_shift(stats, grad_bound))
            kernel_pre = sch.pre
        zeros = jnp.zeros((flat.shape[1],), jnp.float32)
        partial = ops.ota_superpose(flat, scale, zeros, 1.0, pre=kernel_pre)
        acc = carry["acc"] + partial
    else:
        x = schemes.transform(sch, block_tree, stats, grad_bound,
                              batched=True, out_dtype=jnp.float32)
        acc = jax.tree_util.tree_map(
            lambda A, l: A + jnp.tensordot(hb_air, l, axes=(0, 0)),
            carry["acc"], x)

    side = carry["side"]
    if side:  # tracelint: disable=TL003 side is the carry's static dict STRUCTURE (empty for sideless schemes); emptiness is fixed at trace time
        collected = sch.collect_side(stats)
        side = {name: side[name] + jnp.sum(hb_srv * collected[name])
                for name in side}
    return {"acc": acc, "hb_srv": carry["hb_srv"] + jnp.sum(hb_srv),
            "shift": shift, "side": side}


def streaming_finish(cfg: OTAConfig, carry: dict, template: PyTree, a,
                     key: Optional[jax.Array], *, noise_var=None,
                     num_devices: Optional[jax.Array] = None) -> PyTree:
    """Close a K-blocked aggregation: add the channel noise ONCE (bitwise the
    dense draw — same key schedule, same single-device template), apply the
    receiver gain and the scheme's server post-transform with the
    accumulated side-info fold.  For baseline schemes ``num_devices`` (or
    the participant count) divides the running sum into the mean."""
    sch = schemes.get(cfg.scheme)
    if noise_var is None:
        noise_var = cfg.noise_var
    if sch.baseline:
        inv = 1.0 / num_devices
        return jax.tree_util.tree_map(
            lambda l: l * jnp.asarray(inv, l.dtype), carry["acc"])

    if cfg.backend == "kernels":
        from jax.flatten_util import ravel_pytree
        _, unravel = ravel_pytree(template)
        n = carry["acc"].shape[0]
        if (key is not None and not cfg.noiseless
                and schemes.maybe_positive(noise_var)):
            noise, _ = ravel_pytree(
                schemes.add_channel_noise(
                    jax.tree_util.tree_map(jnp.zeros_like, template),
                    key, noise_var))
        else:
            noise = jnp.zeros((n,), jnp.float32)
        af = jnp.asarray(a, jnp.float32)
        y_flat = af * (carry["acc"] + noise) + af * carry["shift"]
        y = unravel(y_flat)
    else:
        summed = carry["acc"]
        if (key is not None and not cfg.noiseless
                and schemes.maybe_positive(noise_var)):
            summed = schemes.add_channel_noise(summed, key, noise_var)
        y = jax.tree_util.tree_map(
            lambda l: jnp.asarray(a, l.dtype) * l, summed)

    if sch.server_post is None:
        return y
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(template))
    _, numbers = _side_parts(sch, n)
    folded = dict(numbers)
    denom = carry["hb_srv"] + _EPS
    for name, total in carry["side"].items():
        folded[name] = total / denom
    return sch.server_post(y, folded)


def _aggregate_streaming(cfg: OTAConfig, stacked_grads: PyTree, h: jax.Array,
                         b: jax.Array, key: Optional[jax.Array],
                         h_hat: jax.Array) -> PyTree:
    """``lax.scan`` K-block fallback behind ``aggregate`` (vmap backend, and
    the kernels backend's per-block ops): the stacked input is viewed as
    [num_blocks, k_block, ...] and folded block-by-block through the carry
    API — the [K, N] transmit matrix is never formed.

    With ``cfg.device_mesh = D`` the blocks are further partitioned into D
    contiguous shards, [D, nb/D, k_block, ...]: each shard left-folds its
    own blocks into a private carry and the D partial carries reduce through
    the deterministic ``fold_shards`` combine (every carry field is a sum).
    When a physical mesh is available the per-shard folds run SPMD under
    ``shard_map`` with ONE cross-shard collective; otherwise an outer scan
    emulates the shards — bitwise the same result, because the blocking and
    the combine order are fixed by the config, not the execution."""
    leaves = jax.tree_util.tree_leaves(stacked_grads)
    k = leaves[0].shape[0]
    kb = min(cfg.k_block, k)
    if k % kb != 0:
        raise ValueError(f"k_block {kb} must divide the device count {k}")
    nb = k // kb
    template = _device_template(stacked_grads)
    blocks = jax.tree_util.tree_map(
        lambda l: l.reshape((nb, kb) + l.shape[1:]), stacked_grads)
    hb_air = (h * b).astype(jnp.float32).reshape(nb, kb)
    hb_srv = (h_hat * b).astype(jnp.float32).reshape(nb, kb)

    def body(carry, xs):
        blk, ha, hs = xs
        return streaming_block(cfg, carry, blk, ha, hs), None

    def shard_fold(xs_shard):
        """One shard's left fold over its [nbl, kb, ...] run of blocks."""
        return jax.lax.scan(body, streaming_carry(cfg, template), xs_shard)[0]

    if cfg.device_mesh is not None and cfg.device_mesh > 1:
        from repro.distribution import ota_collectives as coll
        from repro.distribution import sharding as shardlib
        d = cfg.device_mesh
        if nb % d != 0:
            raise ValueError(
                f"device_mesh {d} must divide the block count {nb} "
                f"(= K {k} / k_block {kb}) — pick a k_block so that "
                "K / k_block is a multiple of the mesh size")
        resh = lambda l: l.reshape((d, nb // d) + l.shape[1:])
        xs = (jax.tree_util.tree_map(resh, blocks), resh(hb_air),
              resh(hb_srv))
        mesh = shardlib.device_mesh(d)
        if mesh is None:
            # emulated shards: same blocking, same combine, no collectives
            stacked = jax.lax.scan(
                lambda _, xs_s: (None, shard_fold(xs_s)), None, xs)[1]
        else:
            from jax.sharding import PartitionSpec as P
            axis = shardlib.FL_DEVICE_AXIS

            def per_shard(xs_s):
                local = jax.tree_util.tree_map(lambda l: l[0], xs_s)
                return coll.gather_shards(shard_fold(local), axis)

            spec_in = jax.tree_util.tree_map(lambda _: P(axis), xs)
            stacked = jax.shard_map(
                per_shard, mesh=mesh, in_specs=(spec_in,), out_specs=P(),
                axis_names={axis}, check_vma=False)(xs)
        # fenced so streaming_finish compiles independently of which
        # execution path produced the partials (bitwise phys == emulated)
        carry = fusion_fence(coll.fold_shards(stacked))
    else:
        carry, _ = jax.lax.scan(body, streaming_carry(cfg, template),
                                (blocks, hb_air, hb_srv))
    return streaming_finish(cfg, carry, template, cfg.a, key,
                            num_devices=float(k))


def aggregate(cfg: OTAConfig, stacked_grads: PyTree, h: jax.Array, b: jax.Array,
              key: Optional[jax.Array] = None,
              h_hat: Optional[jax.Array] = None) -> PyTree:
    """Full OTA aggregation: device transform -> superpose -> server post,
    on the backend selected by ``cfg.backend``.

    ``h`` is the TRUE channel — the air superposes with it (eq. 10).
    ``h_hat`` is the server's CSI estimate, used by everything the SERVER
    computes (the side-info folding of the server post-transform); ``None``
    means perfect CSI (``h_hat = h``), which is bitwise the historical
    behavior.  Returns the update direction ``y`` such that
    ``w <- w - eta * y``.

    ``cfg.k_block`` streams the device axis: the kernels backend grids the
    K-way reduction itself ((N-block, K-block) Pallas kernels / lax.scan
    oracles), the vmap backend scans the carry API above.  ``cfg.device_mesh``
    (either stacked backend) routes through the sharded streaming path —
    per-shard block folds (per-shard kernel launches on the kernels backend)
    closed by one deterministic cross-shard combine.
    """
    if h_hat is None:
        h_hat = h
    if cfg.backend == "kernels":
        if cfg.device_mesh is not None and cfg.device_mesh > 1:
            # the sharded form drives the per-block kernel launches through
            # the carry API (streaming_block's kernels branch) so each shard
            # grids only its own K-blocks
            return _aggregate_streaming(cfg, stacked_grads, h, b, key, h_hat)
        from repro.fed.kernel_path import aggregate_kernels
        return aggregate_kernels(cfg, stacked_grads, h, b, key, h_hat=h_hat,
                                 k_block=cfg.k_block)
    if cfg.backend == "mesh":
        from repro.distribution.ota_collectives import aggregate_mesh
        return aggregate_mesh(cfg, stacked_grads, h, b, key, h_hat=h_hat)
    if cfg.k_block is not None:
        return _aggregate_streaming(cfg, stacked_grads, h, b, key, h_hat)

    if schemes.get(cfg.scheme).baseline:
        return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), stacked_grads)
    x, side = device_transform(cfg.scheme, stacked_grads, cfg.grad_bound)
    noise_key = None if cfg.noiseless else key
    y = superpose(x, h, b, cfg.a, noise_key, cfg.noise_var)
    return server_post(cfg.scheme, y, side, h_hat, b)


def apply_update(params: PyTree, y: PyTree, eta) -> PyTree:
    """w <- w - eta y  (eq. 11)."""
    return jax.tree_util.tree_map(
        lambda w, u: w - jnp.asarray(eta, w.dtype) * u.astype(w.dtype), params, y)


# ---------------------------------------------------------------------------
# partial participation


def participation_fold(h: jax.Array, b: jax.Array, a,
                       mask: jax.Array,
                       sum_fn=jnp.sum) -> Tuple[jax.Array, jax.Array]:
    """Fold a per-round 0/1 participation mask into the channel parameters.

    A non-participating device transmits nothing, which on every backend is
    exactly ``b_k = 0`` (zero superposition weight, zero side-info weight,
    zero eq.-8 energy).  The server schedules the round, so it knows the
    participant set and rescales its receiver gain to hold the *effective*
    gain ``a * sum_k h_k b_k`` at the full-cohort design value — the quantity
    the paper's convergence bounds see.  The rescale is a SERVER computation:
    under imperfect CSI pass the estimate ``h_hat`` for ``h`` (the runtime
    does).  If nobody participates the gain is zeroed: the server applies no
    update rather than amplifying pure noise.

    ``sum_fn`` is the K-way reduction used for the gain folds (default
    ``jnp.sum``); the sharded streaming round passes ``pinned_sum`` so
    ``a_eff`` is bitwise-independent of the execution path.

    Returns ``(b_eff, a_eff)``.
    """
    mask = mask.astype(jnp.float32)
    b_eff = b * mask
    hb_full = sum_fn(h * b)
    hb_eff = sum_fn(h * b_eff)
    a_eff = jnp.where(hb_eff > _EPS * jnp.maximum(hb_full, 1.0),
                      a * hb_full / jnp.maximum(hb_eff, _EPS),
                      0.0).astype(jnp.float32)
    return b_eff, a_eff


# ---------------------------------------------------------------------------
# power accounting


def transmit_norms(scheme: str, stacked_grads: PyTree,
                   grad_bound: Optional[float] = None) -> jax.Array:
    """[K] transmit-signal norms ||x_k|| — the quantity the paper's power
    discipline is about.  For ``normalized`` this is exactly 1 for every
    device at every round; for ``benchmark1`` it is ||g_k||/G <= 1 (wasting
    headroom); for ``benchmark2`` it is sqrt(N) (unbounded per element)."""
    x, _ = device_transform(scheme, stacked_grads, grad_bound)
    return per_device_norm(x)


def transmit_energy(scheme: str, stacked_grads: PyTree, b: jax.Array,
                    grad_bound: Optional[float] = None,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """[K] per-round transmit energies b_k^2 ||x_k||^2 (the paper's eq. 8
    power budget), via each scheme's analytic ``transmit_sq_norm`` — no
    second pass over the gradients.  ``mask`` zeroes the energy of devices
    that sat the round out (see ``participation_fold``)."""
    sch = schemes.get(scheme)
    stats = schemes.compute_stats(stacked_grads, sch, batched=True)
    return schemes.transmit_energy(sch, stats, b, grad_bound, mask)
