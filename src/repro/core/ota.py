"""Over-the-air gradient aggregation schemes (the paper's core, Sec. II).

Every scheme is expressed as a *device-side transform* of the local gradient
pytree plus a *server-side post-transform* of the superposed signal

    y = a * ( sum_k h_k b_k x_k + z ),      z ~ N(0, sigma^2 I)      (eq. 10)

followed by the model update ``w <- w - eta * y`` (eq. 11).

Schemes
-------
``normalized``      x_k = g_k / ||g_k||                 (the paper, eq. 12)
``raw``             x_k = g_k                            (no power discipline; diagnostic)
``benchmark1``      x_k = g_k / G                        (raw gradient under the
                    conservative max-norm assumption of [7] — the worst-case
                    bound G is what keeps the transmit amplitude <= b_k^max)
``benchmark2``      x_k = (g_k - mean_k) / std_k         ([13]; mean/std sent as
                    error-free side info and folded back in at the server)
``onebit``          x_k = sign(g_k)/sqrt(N)              ([12]; server takes the
                    sign of the aggregate — over-the-air signSGD-MV.  The 1/sqrt(N)
                    keeps ||x_k|| = 1 so the transmit power discipline matches.)
``mean``            ideal noiseless FedSGD mean          (upper-bound reference)

All transforms act on *stacked* gradient pytrees whose leaves carry a leading
device axis K (produced by ``jax.vmap`` over clients).  The mesh/shard_map
variant, where each data shard is one device and the superposition is a single
``psum``, lives in ``repro.distribution.ota_collectives``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

SCHEMES = ("normalized", "normalized_per_tensor", "raw", "benchmark1",
           "benchmark2", "onebit", "mean")

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class OTAConfig:
    """Per-round aggregation parameters (see ``amplification`` for how a, b
    are chosen optimally)."""

    scheme: str = "normalized"
    a: float = 1.0                       # receiver gain (server side)
    noise_var: float = 0.0               # sigma^2 of the AWGN at the ES
    grad_bound: Optional[float] = None   # G, required by benchmark1
    # When True the noise term is omitted (ideal channel); used by tests that
    # isolate the deterministic part of a scheme.
    noiseless: bool = False

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; one of {SCHEMES}")
        if self.scheme == "benchmark1" and self.grad_bound is None:
            raise ValueError("benchmark1 requires grad_bound (the max-norm G)")


# ---------------------------------------------------------------------------
# pytree helpers (leading axis = device)


def tree_num_elements(tree: PyTree) -> int:
    """Total number of scalar coordinates in one device's gradient (= N)."""
    return sum(int(jnp.size(l)) // l.shape[0] for l in jax.tree_util.tree_leaves(tree))


def per_device_sq_norm(stacked: PyTree) -> jax.Array:
    """[K] vector of squared global L2 norms, one per device."""
    leaves = jax.tree_util.tree_leaves(stacked)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
               for l in leaves)


def per_device_norm(stacked: PyTree) -> jax.Array:
    return jnp.sqrt(per_device_sq_norm(stacked))


def per_device_mean_std(stacked: PyTree) -> Tuple[jax.Array, jax.Array]:
    """[K] global mean and std over each device's full gradient vector."""
    leaves = jax.tree_util.tree_leaves(stacked)
    n = tree_num_elements(stacked)
    s1 = sum(jnp.sum(l.astype(jnp.float32).reshape(l.shape[0], -1), axis=1) for l in leaves)
    mean = s1 / n
    s2 = sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
             for l in leaves)
    var = jnp.maximum(s2 / n - jnp.square(mean), 0.0)
    return mean, jnp.sqrt(var)


def _scale_per_device(stacked: PyTree, scale: jax.Array) -> PyTree:
    """Multiply each device's slice by scale[k] (broadcast over trailing dims)."""
    def one(l):
        s = scale.astype(l.dtype).reshape((l.shape[0],) + (1,) * (l.ndim - 1))
        return l * s
    return jax.tree_util.tree_map(one, stacked)


def _shift_per_device(stacked: PyTree, shift: jax.Array) -> PyTree:
    def one(l):
        s = shift.astype(l.dtype).reshape((l.shape[0],) + (1,) * (l.ndim - 1))
        return l + s
    return jax.tree_util.tree_map(one, stacked)


# ---------------------------------------------------------------------------
# device-side transforms


def device_transform(scheme: str, stacked_grads: PyTree,
                     grad_bound: Optional[float] = None) -> Tuple[PyTree, dict]:
    """Apply a scheme's device-side transform.  Returns (x_k stack, side_info)."""
    if scheme in ("mean", "raw"):
        return stacked_grads, {}
    if scheme == "normalized":
        norms = per_device_norm(stacked_grads)
        return _scale_per_device(stacked_grads, 1.0 / (norms + _EPS)), {}
    if scheme == "normalized_per_tensor":
        # beyond-paper variant (DESIGN.md §4): each tensor normalized by its
        # own norm (LARS-flavoured), then scaled by 1/sqrt(#tensors) so the
        # total transmit norm is 1 — useful for MoE where a cold expert's
        # gradient would otherwise be drowned by the dense layers.
        leaves = jax.tree_util.tree_leaves(stacked_grads)
        n_t = len(leaves)
        def one(l):
            lf = l.astype(jnp.float32)
            norm = jnp.sqrt(jnp.sum(jnp.square(lf.reshape(l.shape[0], -1)), axis=1))
            scale = (1.0 / ((norm + _EPS) * jnp.sqrt(float(n_t))))
            return lf * scale.reshape((l.shape[0],) + (1,) * (l.ndim - 1))
        return jax.tree_util.tree_map(one, stacked_grads), {}
    if scheme == "benchmark1":
        g = jnp.asarray(grad_bound, jnp.float32)
        leaves0 = jax.tree_util.tree_leaves(stacked_grads)
        k = leaves0[0].shape[0]
        return _scale_per_device(stacked_grads, jnp.full((k,), 1.0) / g), {}
    if scheme == "benchmark2":
        # Standardize, then scale by 1/sqrt(N) so the transmitted signal obeys
        # the SAME per-round energy budget as the other schemes (||x|| = 1).
        # The raw [13] operation leaves ||x|| = sqrt(N) — an unbounded
        # amplitude, which is exactly the paper's critique; comparing at
        # sqrt(N)x the transmit energy would be meaningless.  The server
        # folds the sqrt(N) back in (it knows the model dimension).
        mean, std = per_device_mean_std(stacked_grads)
        n = tree_num_elements(stacked_grads)
        centred = _shift_per_device(stacked_grads, -mean)
        x = _scale_per_device(centred, 1.0 / ((std + _EPS) * jnp.sqrt(float(n))))
        return x, {"mean": mean, "std": std, "sqrt_n": float(n) ** 0.5}
    if scheme == "onebit":
        n = tree_num_elements(stacked_grads)
        inv_sqrt_n = 1.0 / jnp.sqrt(jnp.asarray(n, jnp.float32))
        x = jax.tree_util.tree_map(lambda l: jnp.sign(l) * inv_sqrt_n, stacked_grads)
        return x, {}
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# superposition + server-side post-transform


def superpose(stacked_x: PyTree, h: jax.Array, b: jax.Array, a: float,
              key: Optional[jax.Array], noise_var: float) -> PyTree:
    """The MAC channel: y = a (sum_k h_k b_k x_k + z).  One fused reduction."""
    hb = (h * b).astype(jnp.float32)
    summed = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(hb.astype(l.dtype), l, axes=(0, 0)), stacked_x)
    if key is not None and noise_var > 0.0:
        flat, treedef = jax.tree_util.tree_flatten(summed)
        keys = jax.random.split(key, len(flat))
        flat = [l + jnp.sqrt(jnp.asarray(noise_var, l.dtype))
                * jax.random.normal(k, l.shape, l.dtype) for l, k in zip(flat, keys)]
        summed = jax.tree_util.tree_unflatten(treedef, flat)
    return jax.tree_util.tree_map(lambda l: jnp.asarray(a, l.dtype) * l, summed)


def server_post(scheme: str, y: PyTree, side: dict, h: jax.Array,
                b: jax.Array) -> PyTree:
    """Server-side reconstruction applied after the receiver gain."""
    if scheme == "benchmark2":
        hb = h * b
        w = hb / (jnp.sum(hb) + _EPS)
        std_bar = jnp.sum(w * side["std"]) * side["sqrt_n"]
        mean_bar = jnp.sum(w * side["mean"])
        return jax.tree_util.tree_map(lambda l: l * std_bar + mean_bar, y)
    if scheme == "onebit":
        return jax.tree_util.tree_map(jnp.sign, y)
    return y


def aggregate(cfg: OTAConfig, stacked_grads: PyTree, h: jax.Array, b: jax.Array,
              key: Optional[jax.Array] = None) -> PyTree:
    """Full OTA aggregation: device transform -> superpose -> server post.

    Returns the update direction ``y`` such that ``w <- w - eta * y``.
    """
    if cfg.scheme == "mean":
        k = jax.tree_util.tree_leaves(stacked_grads)[0].shape[0]
        return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), stacked_grads)
    x, side = device_transform(cfg.scheme, stacked_grads, cfg.grad_bound)
    noise_key = None if cfg.noiseless else key
    y = superpose(x, h, b, cfg.a, noise_key, cfg.noise_var)
    return server_post(cfg.scheme, y, side, h, b)


def apply_update(params: PyTree, y: PyTree, eta) -> PyTree:
    """w <- w - eta y  (eq. 11)."""
    return jax.tree_util.tree_map(
        lambda w, u: w - jnp.asarray(eta, w.dtype) * u.astype(w.dtype), params, y)


def transmit_norms(scheme: str, stacked_grads: PyTree,
                   grad_bound: Optional[float] = None) -> jax.Array:
    """[K] transmit-signal norms ||x_k|| — the quantity the paper's power
    discipline is about.  For ``normalized`` this is exactly 1 for every
    device at every round; for ``benchmark1`` it is ||g_k||/G <= 1 (wasting
    headroom); for ``benchmark2`` it is sqrt(N) (unbounded per element)."""
    x, _ = device_transform(scheme, stacked_grads, grad_bound)
    return per_device_norm(x)
