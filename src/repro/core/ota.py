"""Over-the-air gradient aggregation (the paper's core, Sec. II): the single
entry point over the scheme registry and the three execution backends.

Every scheme is a device-side transform of the local gradient pytree plus a
server-side post-transform of the superposed signal

    y = a * ( sum_k h_k b_k x_k + z ),      z ~ N(0, sigma^2 I)      (eq. 10)

followed by the model update ``w <- w - eta * y`` (eq. 11).  The schemes
themselves (normalized — eq. 12 —, benchmark1/2, onebit, clipped, ...) are
defined ONCE in ``repro.core.schemes``; this module contains only
backend-independent plumbing and the vmap backend's math.

Backends (``OTAConfig.backend`` / ``FLConfig.backend``):

``vmap``     transforms act on *stacked* pytrees whose leaves carry a leading
             device axis K (``jax.vmap`` over clients); superposition is one
             fused fp32 tensordot per leaf.  Implemented here.
``kernels``  same stacked layout through the fused Pallas kernels — one
             batched [K, N] moments kernel for the per-device statistics and
             one fused superpose kernel with a per-device scale vector
             (``repro.fed.kernel_path``).
``mesh``     each data shard of a TPU mesh is one device; the superposition
             is a single ``psum`` (``repro.distribution.ota_collectives``).

All three consume the same ``Scheme`` objects, draw channel noise through the
same per-leaf key schedule, and agree allclose on the update direction y for
every registered scheme (tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import schemes

PyTree = Any

BACKENDS = ("vmap", "kernels", "mesh")


def __getattr__(name):
    # SCHEMES stays live as the registry grows (PEP 562): schemes registered
    # after import (repro.core.register_scheme) appear immediately
    if name == "SCHEMES":
        return schemes.names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_EPS = schemes.EPS


@dataclasses.dataclass(frozen=True)
class OTAConfig:
    """Per-round aggregation parameters (see ``amplification`` for how a, b
    are chosen optimally)."""

    scheme: str = "normalized"
    a: float = 1.0                       # receiver gain (server side)
    noise_var: float = 0.0               # sigma^2 of the AWGN at the ES
    grad_bound: Optional[float] = None   # G, required by benchmark1/clipped
    # When True the noise term is omitted (ideal channel); used by tests that
    # isolate the deterministic part of a scheme.
    noiseless: bool = False
    # which execution backend aggregate() routes through
    backend: str = "vmap"

    def __post_init__(self):
        schemes.validate_config(self.scheme, self.grad_bound)
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; one of {BACKENDS}")


# ---------------------------------------------------------------------------
# pytree helpers (leading axis = device)


def tree_num_elements(tree: PyTree) -> int:
    """Total number of scalar coordinates in one device's gradient (= N)."""
    return sum(int(jnp.size(l)) // l.shape[0] for l in jax.tree_util.tree_leaves(tree))


def per_device_sq_norm(stacked: PyTree) -> jax.Array:
    """[K] vector of squared global L2 norms, one per device."""
    leaves = jax.tree_util.tree_leaves(stacked)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
               for l in leaves)


def per_device_norm(stacked: PyTree) -> jax.Array:
    return jnp.sqrt(per_device_sq_norm(stacked))


def per_device_mean_std(stacked: PyTree) -> Tuple[jax.Array, jax.Array]:
    """[K] global mean and std over each device's full gradient vector."""
    leaves = jax.tree_util.tree_leaves(stacked)
    n = tree_num_elements(stacked)
    s1 = sum(jnp.sum(l.astype(jnp.float32).reshape(l.shape[0], -1), axis=1) for l in leaves)
    mean = s1 / n
    s2 = sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(l.shape[0], -1), axis=1)
             for l in leaves)
    var = jnp.maximum(s2 / n - jnp.square(mean), 0.0)
    return mean, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# device-side transforms (registry-driven)


def device_transform(scheme: str, stacked_grads: PyTree,
                     grad_bound: Optional[float] = None) -> Tuple[PyTree, dict]:
    """Apply a scheme's device-side transform.  Returns (x_k stack, side_info)."""
    sch = schemes.get(scheme)
    if sch.baseline:
        return stacked_grads, {}
    stats = schemes.compute_stats(stacked_grads, sch, batched=True)
    x = schemes.transform(sch, stacked_grads, stats, grad_bound, batched=True)
    side = sch.collect_side(stats) if sch.collect_side else {}
    return x, side


# ---------------------------------------------------------------------------
# superposition + server-side post-transform (the vmap backend)


def superpose(stacked_x: PyTree, h: jax.Array, b: jax.Array, a: float,
              key: Optional[jax.Array], noise_var: float) -> PyTree:
    """The MAC channel: y = a (sum_k h_k b_k x_k + z), one fused reduction
    per leaf.  Accumulates in fp32 regardless of the gradient dtype (bf16
    gradients would otherwise lose mass in the K-way sum) — the same
    ``reduce_dtype`` contract as the mesh path — and returns fp32 leaves."""
    hb = (h * b).astype(jnp.float32)
    summed = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(hb, l.astype(jnp.float32), axes=(0, 0)), stacked_x)
    # maybe_positive: a traced sigma^2 (the batched sweep engine's
    # per-experiment noise axis) must resolve the branch at trace time
    if key is not None and schemes.maybe_positive(noise_var):
        summed = schemes.add_channel_noise(summed, key, noise_var)
    return jax.tree_util.tree_map(lambda l: jnp.asarray(a, l.dtype) * l, summed)


def server_post(scheme: str, y: PyTree, side: dict, h: jax.Array,
                b: jax.Array) -> PyTree:
    """Server-side reconstruction applied after the receiver gain.  ``h``
    here is the channel AS THE SERVER KNOWS IT — pass the CSI estimate
    ``h_hat`` under imperfect CSI (see ``aggregate``)."""
    sch = schemes.get(scheme)
    if sch.server_post is None:
        return y
    return sch.server_post(y, schemes.fold_side_stacked(side, h, b))


def aggregate(cfg: OTAConfig, stacked_grads: PyTree, h: jax.Array, b: jax.Array,
              key: Optional[jax.Array] = None,
              h_hat: Optional[jax.Array] = None) -> PyTree:
    """Full OTA aggregation: device transform -> superpose -> server post,
    on the backend selected by ``cfg.backend``.

    ``h`` is the TRUE channel — the air superposes with it (eq. 10).
    ``h_hat`` is the server's CSI estimate, used by everything the SERVER
    computes (the side-info folding of the server post-transform); ``None``
    means perfect CSI (``h_hat = h``), which is bitwise the historical
    behavior.  Returns the update direction ``y`` such that
    ``w <- w - eta * y``.
    """
    if h_hat is None:
        h_hat = h
    if cfg.backend == "kernels":
        from repro.fed.kernel_path import aggregate_kernels
        return aggregate_kernels(cfg, stacked_grads, h, b, key, h_hat=h_hat)
    if cfg.backend == "mesh":
        from repro.distribution.ota_collectives import aggregate_mesh
        return aggregate_mesh(cfg, stacked_grads, h, b, key, h_hat=h_hat)

    if schemes.get(cfg.scheme).baseline:
        return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), stacked_grads)
    x, side = device_transform(cfg.scheme, stacked_grads, cfg.grad_bound)
    noise_key = None if cfg.noiseless else key
    y = superpose(x, h, b, cfg.a, noise_key, cfg.noise_var)
    return server_post(cfg.scheme, y, side, h_hat, b)


def apply_update(params: PyTree, y: PyTree, eta) -> PyTree:
    """w <- w - eta y  (eq. 11)."""
    return jax.tree_util.tree_map(
        lambda w, u: w - jnp.asarray(eta, w.dtype) * u.astype(w.dtype), params, y)


# ---------------------------------------------------------------------------
# partial participation


def participation_fold(h: jax.Array, b: jax.Array, a,
                       mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Fold a per-round 0/1 participation mask into the channel parameters.

    A non-participating device transmits nothing, which on every backend is
    exactly ``b_k = 0`` (zero superposition weight, zero side-info weight,
    zero eq.-8 energy).  The server schedules the round, so it knows the
    participant set and rescales its receiver gain to hold the *effective*
    gain ``a * sum_k h_k b_k`` at the full-cohort design value — the quantity
    the paper's convergence bounds see.  The rescale is a SERVER computation:
    under imperfect CSI pass the estimate ``h_hat`` for ``h`` (the runtime
    does).  If nobody participates the gain is zeroed: the server applies no
    update rather than amplifying pure noise.

    Returns ``(b_eff, a_eff)``.
    """
    mask = mask.astype(jnp.float32)
    b_eff = b * mask
    hb_full = jnp.sum(h * b)
    hb_eff = jnp.sum(h * b_eff)
    a_eff = jnp.where(hb_eff > _EPS * jnp.maximum(hb_full, 1.0),
                      a * hb_full / jnp.maximum(hb_eff, _EPS),
                      0.0).astype(jnp.float32)
    return b_eff, a_eff


# ---------------------------------------------------------------------------
# power accounting


def transmit_norms(scheme: str, stacked_grads: PyTree,
                   grad_bound: Optional[float] = None) -> jax.Array:
    """[K] transmit-signal norms ||x_k|| — the quantity the paper's power
    discipline is about.  For ``normalized`` this is exactly 1 for every
    device at every round; for ``benchmark1`` it is ||g_k||/G <= 1 (wasting
    headroom); for ``benchmark2`` it is sqrt(N) (unbounded per element)."""
    x, _ = device_transform(scheme, stacked_grads, grad_bound)
    return per_device_norm(x)


def transmit_energy(scheme: str, stacked_grads: PyTree, b: jax.Array,
                    grad_bound: Optional[float] = None,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """[K] per-round transmit energies b_k^2 ||x_k||^2 (the paper's eq. 8
    power budget), via each scheme's analytic ``transmit_sq_norm`` — no
    second pass over the gradients.  ``mask`` zeroes the energy of devices
    that sat the round out (see ``participation_fold``)."""
    sch = schemes.get(scheme)
    stats = schemes.compute_stats(stacked_grads, sch, batched=True)
    return schemes.transmit_energy(sch, stats, b, grad_bound, mask)
