"""Pallas TPU kernel: fused Mamba (S6) selective scan.

The §Perf hillclimb showed jamba's train memory term is structural in the
XLA path: the chunked associative scan materializes O(log chunk) levels of
[B, chunk, D_inner, N] fp32 intermediates, and no chunk-size / dtype /
sharding lever moves it more than a few percent (EXPERIMENTS.md §Perf).
This kernel is the TPU analogue of the CUDA reference's fused scan: the
discretization (``da = exp(dt*A)``, ``dbu = dt*u*B``) and the recurrence

    h_t = da_t * h_{t-1} + dbu_t ;    y_t = <h_t, C_t>

happen *in registers/VMEM*, so HBM traffic is just u/dt/B/C in and y out —
the [S, D, N] state never exists in memory.  The grid is
(batch, d-blocks, seq-chunks) with the seq axis innermost-sequential and the
carried state h [bd, N] in VMEM scratch (same idiom as flash attention's
running softmax).

Validated on CPU via interpret=True against ``ref.selective_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _selective_scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr,
                           *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)          # [cs, bd]
    dt = dt_ref[0].astype(jnp.float32)        # [cs, bd]
    a = a_ref[...].astype(jnp.float32)        # [bd, N]
    bmat = b_ref[0].astype(jnp.float32)       # [cs, N]
    cmat = c_ref[0].astype(jnp.float32)       # [cs, N]

    def body(t, h):
        da = jnp.exp(dt[t][:, None] * a)                       # [bd, N]
        dbu = (dt[t] * u[t])[:, None] * bmat[t][None, :]       # [bd, N]
        h = da * h + dbu
        y_t = jnp.sum(h * cmat[t][None, :], axis=1)            # [bd]
        # dslice(0, 1) instead of a bare 0: older pallas discharge rules
        # reject scalar-int indices mixed with dynamic slices
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y_t[None, None, :])
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h


def selective_scan_blocked(u: jax.Array, dt: jax.Array, a: jax.Array,
                           bmat: jax.Array, cmat: jax.Array, *,
                           block_d: int = 128, chunk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """u/dt: [B, S, D]; a: [D, N] (= -exp(A_log)); bmat/cmat: [B, S, N].

    Returns y [B, S, D] f32 with y_t = <h_t, C_t>, h_t = exp(dt_t a) h_{t-1}
    + dt_t u_t B_t (h_0 = 0).
    """
    b, s, d = u.shape
    n = a.shape[1]
    bd = min(block_d, d)
    cs = min(chunk, s)
    if d % bd or s % cs:
        raise ValueError("D and S must divide block_d / chunk")
    grid = (b, d // bd, s // cs)
    kernel = functools.partial(_selective_scan_kernel, chunk=cs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((bd, n), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((1, cs, n), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((1, cs, n), lambda ib, id_, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, a, bmat, cmat)
