"""Pallas TPU kernel: flash attention (blockwise online-softmax).

The assigned architectures' compute hot-spot.  Grid is
``(batch, heads, q_blocks, kv_blocks)`` with the kv axis innermost: the
running max / denominator / output accumulator live in VMEM scratch across
kv steps (the TPU idiom for the flash recurrence — sequential grid instead of
a CUDA thread-block loop), so the S x S score matrix never exists and HBM
traffic is O(S * d) per head.  Causal + sliding-window masking supported.

Block shapes are (block_q x d_head) / (block_k x d_head) MXU-aligned tiles;
block_q/block_k are the §Perf tuning levers.

Validated on CPU via interpret=True against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < kv_len
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window: Optional[int] = None,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool = True) -> jax.Array:
    """q/k/v: [B, H, S, d] (kv already head-expanded).  Returns [B, H, S, d]."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        raise ValueError("sequence lengths must divide block sizes")
    grid = (b, h, sq // bq, skv // bk)
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk,
        causal=causal, window=window, kv_len=skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
