"""Pallas TPU kernels: blocked global reductions over flat gradients.

The paper's device-side transforms need per-device statistics over the
*entire* flat gradient (millions of elements) before any element can be
scaled — HBM-bandwidth-bound reductions.  Two kernels:

``blocked_sumsq``          single-device [R, C] -> per-block sum-of-squares
                           partials (the original kernel, kept for the
                           single-vector ``ops.grad_norm``).
``batched_blocked_moments`` the registry-refactor kernel: ALL K devices in one
                           ``pallas_call`` over a ``(K, blocks)`` grid on a
                           [K, R, C] view of the stacked flat gradients,
                           emitting per-(device, block) sum-of-squares AND sum
                           partials.  One launch replaces the old Python loop
                           of K ``grad_norm`` calls, and the sum output gives
                           the moments schemes (benchmark2) their mean/std
                           from the same HBM pass.

The (tiny) final block-sum + sqrt happens in the jitted wrappers
(``ops.batched_moments`` / ``ops.batched_grad_norms``).

Target: TPU (MXU/VPU 8x128 tiling); validated on CPU via interpret=True
against ``ref.grad_norm_ref`` / ``ref.batched_moments_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(x * x)


def blocked_sumsq(x: jax.Array, *, block_rows: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Partial sums of squares of a [R, 128k]-shaped view of the flat vector.

    x must be 2-D with a lane-aligned trailing dim; returns [num_blocks] f32.
    """
    rows, cols = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"block_rows {br} must divide rows {rows}")
    grid = (rows // br,)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, 0]


def _moments_kernel(x_ref, sq_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)          # [br, cols] tile of device i
    sq_ref[0, 0] = jnp.sum(x * x)
    s_ref[0, 0] = jnp.sum(x)


def batched_blocked_moments(x: jax.Array, *, block_rows: int = 256,
                            interpret: bool = True):
    """Per-(device, block) partial moments of stacked flat gradients.

    x: [K, R, C] (C lane-aligned; zero padding is moment-neutral).  One
    ``pallas_call`` over a (K, R // block_rows) grid.  Returns
    ``(sumsq, sums)`` each [K, num_blocks] f32.
    """
    k, rows, cols = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"block_rows {br} must divide rows {rows}")
    grid = (k, rows // br)
    out_shape = jax.ShapeDtypeStruct((k, grid[1]), jnp.float32)
    sumsq, sums = pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, br, cols), lambda i, j: (i, j, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(x)
    return sumsq, sums


def _stream_moments_kernel(x_ref, sq_ref, s_ref, *, num_blocks):
    """(K-block, N-block) grid step: the N-block axis is the fast grid
    dimension, so the per-device output tiles are revisited ``num_blocks``
    times and act as fp32 accumulators — the final block-sum happens
    in-kernel instead of materializing [K, num_blocks] partials."""
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)            # [kb, br, cols]
    sq = jnp.sum(x * x, axis=(1, 2))              # [kb]
    s = jnp.sum(x, axis=(1, 2))

    @pl.when(j == 0)
    def _init():
        sq_ref[:, 0] = sq
        s_ref[:, 0] = s

    @pl.when(j > 0)
    def _accumulate():
        sq_ref[:, 0] += sq
        s_ref[:, 0] += s


def streaming_blocked_moments(x: jax.Array, *, k_block: int,
                              block_rows: int = 256,
                              interpret: bool = True):
    """Per-device moments over a (K-block, N-block) grid with in-kernel
    accumulation: only a ``(k_block, block_rows, C)`` tile is resident and
    the outputs come back fully reduced.

    x: [K, R, C].  Returns ``(sumsq, sums)`` each [K] f32.  Accumulation
    order differs from ``batched_blocked_moments`` + wrapper block-sum by
    float associativity only (documented-ulp)."""
    k, rows, cols = x.shape
    kb = min(k_block, k)
    if k % kb != 0:
        raise ValueError(f"k_block {kb} must divide K {k}")
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"block_rows {br} must divide rows {rows}")
    nb = rows // br
    grid = (k // kb, nb)
    out_shape = jax.ShapeDtypeStruct((k, 1), jnp.float32)
    sumsq, sums = pl.pallas_call(
        functools.partial(_stream_moments_kernel, num_blocks=nb),
        grid=grid,
        in_specs=[pl.BlockSpec((kb, br, cols), lambda i, j: (i, j, 0))],
        out_specs=[pl.BlockSpec((kb, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((kb, 1), lambda i, j: (i, 0))],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(x)
    return sumsq[:, 0], sums[:, 0]
