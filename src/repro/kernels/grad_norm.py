"""Pallas TPU kernel: blocked global L2-norm reduction.

The paper's device-side transform needs ``||g_k||`` over the *entire* flat
gradient (millions of elements) before any element can be scaled — an
HBM-bandwidth-bound two-pass reduction.  The kernel streams the vector
through VMEM in lane-aligned ``(8, 1024)``-shaped blocks and emits one
partial sum-of-squares per grid step; the (tiny) final add + sqrt happens in
the jitted wrapper (``ops.grad_norm``).

Target: TPU (MXU/VPU 8x128 tiling); validated on CPU via interpret=True
against ``ref.grad_norm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(x * x)


def blocked_sumsq(x: jax.Array, *, block_rows: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Partial sums of squares of a [R, 128k]-shaped view of the flat vector.

    x must be 2-D with a lane-aligned trailing dim; returns [num_blocks] f32.
    """
    rows, cols = x.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"rows {rows} must divide block_rows {br}")
    grid = (rows // br,)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, 0]
