"""Pallas TPU kernel: fused OTA superposition.

Computes, for a block of the flat gradient dimension,

    y[j] = a * ( sum_k (h_k b_k / ||g_k||) * g[k, j] + z[j] )

in one HBM pass: the K stacked device gradients stream through VMEM
``(K, block)`` tiles, the per-device scale (amplification x channel x inverse
norm — precomputed by ``grad_norm``) is applied in-register, the K-way
reduction happens in VMEM, and the channel noise + receiver gain fuse into
the same tile before write-back.  An unfused implementation reads the K
gradients once for the scale, once for the sum and touches y three times;
this kernel is the paper's entire eq. (10) as a single memory-bound sweep.

Target: TPU VPU (8x128 lanes); validated on CPU via interpret=True against
``ref.ota_aggregate_ref``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ota_kernel(g_ref, scale_ref, noise_ref, a_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)              # [K, blk]
    scale = scale_ref[...].astype(jnp.float32)      # [K, 1]
    acc = jnp.sum(g * scale, axis=0)                # superposition
    z = noise_ref[...].astype(jnp.float32)[0]       # [blk]
    out_ref[0, :] = a_ref[0, 0] * (acc + z)


def ota_aggregate_blocked(g: jax.Array, scale: jax.Array, noise: jax.Array,
                          a: jax.Array, *, block: int = 2048,
                          interpret: bool = True) -> jax.Array:
    """g: [K, N] stacked flat device gradients; scale: [K] per-device
    h_k*b_k/||g_k||; noise: [N]; a: scalar receiver gain.  Returns y [N]."""
    k, n = g.shape
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"N={n} must be divisible by block={blk}")
    grid = (n // blk,)
    out = pl.pallas_call(
        _ota_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, blk), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(g, scale.reshape(k, 1), noise.reshape(1, n), a.reshape(1, 1))
    return out[0]
