"""Pallas TPU kernel: fused OTA superposition for every norm-scaling scheme.

Computes, for a block of the flat gradient dimension,

    y[j] = a * ( sum_k scale_k * pre(g[k, j]) + z[j] )

in one HBM pass: the K stacked device gradients stream through VMEM
``(K, block)`` tiles, the optional element-wise pre-transform (``sign`` for
the one-bit scheme) and the per-device scale vector are applied in-register,
the K-way reduction happens in VMEM, and the channel noise + receiver gain
fuse into the same tile before write-back.

``scale`` is a free per-device vector — the caller composes it as
``h_k * b_k * scheme.device_scale(stats)`` — so the SAME kernel serves
``normalized`` (h b / ||g||), ``benchmark1`` (h b / G), ``clipped``
(h b / max(||g||, G)), ``onebit`` (h b / sqrt(N), pre='sign'), and the
per-tensor variant (pre-scaled leaves, scale = h b).  An unfused
implementation reads the K gradients once for the scale, once for the sum and
touches y three times; this kernel is the paper's entire eq. (10) as a single
memory-bound sweep.

Target: TPU VPU (8x128 lanes); validated on CPU via interpret=True against
``ref.ota_superpose_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PRE_KINDS = ("identity", "sign")


def _ota_kernel(g_ref, scale_ref, noise_ref, a_ref, out_ref, *, pre):
    g = g_ref[...].astype(jnp.float32)              # [K, blk]
    if pre == "sign":
        g = jnp.sign(g)
    scale = scale_ref[...].astype(jnp.float32)      # [K, 1]
    acc = jnp.sum(g * scale, axis=0)                # superposition
    z = noise_ref[...].astype(jnp.float32)[0]       # [blk]
    out_ref[0, :] = a_ref[0, 0] * (acc + z)


def ota_aggregate_blocked(g: jax.Array, scale: jax.Array, noise: jax.Array,
                          a: jax.Array, *, block: int = 2048,
                          interpret: bool = True,
                          pre: str = "identity") -> jax.Array:
    """g: [K, N] stacked flat device gradients; scale: [K] per-device
    composite scale (h_k b_k x scheme scale); noise: [N]; a: scalar receiver
    gain; pre: element-wise pre-transform applied in-register.  Returns y [N].
    """
    if pre not in PRE_KINDS:
        raise ValueError(f"unknown pre-transform {pre!r}; one of {PRE_KINDS}")
    k, n = g.shape
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"N={n} must be divisible by block={blk}")
    grid = (n // blk,)
    out = pl.pallas_call(
        functools.partial(_ota_kernel, pre=pre),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, blk), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(g, scale.reshape(k, 1), noise.reshape(1, n), a.reshape(1, 1))
    return out[0]


def _ota_stream_kernel(g_ref, scale_ref, noise_ref, a_ref, out_ref, *,
                       pre, num_k_blocks):
    """One (N-block, K-block) grid step: the device axis is the FAST grid
    dimension, so the output tile is revisited ``num_k_blocks`` times in a
    row and serves as the fp32 accumulator — only a ``(k_block, blk)`` tile
    of the stacked gradients is ever resident."""
    kb = pl.program_id(1)
    g = g_ref[...].astype(jnp.float32)              # [kb, blk]
    if pre == "sign":
        g = jnp.sign(g)
    scale = scale_ref[...].astype(jnp.float32)      # [kb, 1]
    partial = jnp.sum(g * scale, axis=0)            # this K-block's share

    @pl.when(kb == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(kb > 0)
    def _accumulate():
        out_ref[0, :] += partial

    @pl.when(kb == num_k_blocks - 1)
    def _finish():
        z = noise_ref[...].astype(jnp.float32)[0]
        out_ref[0, :] = a_ref[0, 0] * (out_ref[0, :] + z)


def ota_aggregate_streaming(g: jax.Array, scale: jax.Array, noise: jax.Array,
                            a: jax.Array, *, k_block: int,
                            block: int = 2048, interpret: bool = True,
                            pre: str = "identity") -> jax.Array:
    """Streaming variant of ``ota_aggregate_blocked``: the K-way reduction
    itself is gridded, so VMEM holds ``(k_block, block)`` tiles instead of
    full-K columns — the kernel-level half of the 100k-device path.  The
    accumulation order (K-blocks summed sequentially per N-block) differs
    from the dense kernel's single K-way sum by float-associativity only
    (documented-ulp parity, tests/test_streaming.py)."""
    if pre not in PRE_KINDS:
        raise ValueError(f"unknown pre-transform {pre!r}; one of {PRE_KINDS}")
    k, n = g.shape
    kb = min(k_block, k)
    if k % kb != 0:
        raise ValueError(f"K={k} must be divisible by k_block={kb}")
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"N={n} must be divisible by block={blk}")
    nk = k // kb
    grid = (n // blk, nk)
    out = pl.pallas_call(
        functools.partial(_ota_stream_kernel, pre=pre, num_k_blocks=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((kb, blk), lambda i, j: (j, i)),
            pl.BlockSpec((kb, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, blk), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(g, scale.reshape(k, 1), noise.reshape(1, n), a.reshape(1, 1))
    return out[0]
