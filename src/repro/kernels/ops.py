"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container), so the
same call sites compile to real Mosaic kernels on TPU and to the Python
interpreter on CPU (the correctness-validation path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_blocked
from repro.kernels.grad_norm import blocked_sumsq
from repro.kernels.ota_aggregate import ota_aggregate_blocked


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


LANES = 1024  # trailing-dim packing for flat-vector kernels (8x128-aligned)


def _pack_flat(x: jax.Array, lanes: int = LANES):
    """Flatten + zero-pad a vector to [rows, lanes] (padding is norm-neutral)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // lanes)
    pad = rows * lanes - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, lanes), n


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def grad_norm(x: jax.Array, *, block_rows: int = 256,
              interpret: Optional[bool] = None) -> jax.Array:
    """Global L2 norm of a gradient vector via the blocked Pallas reduction."""
    interpret = _default_interpret() if interpret is None else interpret
    x2, _ = _pack_flat(x)
    rows = x2.shape[0]
    br = block_rows
    while rows % br != 0:   # static: shapes are concrete under jit
        br -= 1
    partials = blocked_sumsq(x2, block_rows=br, interpret=interpret)
    return jnp.sqrt(jnp.sum(partials))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ota_aggregate(g: jax.Array, hb: jax.Array, norms: jax.Array,
                  noise: jax.Array, a, *, block: int = LANES,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Fused normalize-amplify-superpose (paper eq. 10 with eq. 12).

    g: [K, N] stacked device gradients; hb: [K] h_k*b_k; norms: [K] ||g_k||;
    noise: [N]; a: scalar.  Returns y [N] f32.
    """
    interpret = _default_interpret() if interpret is None else interpret
    k, n = g.shape
    scale = hb.astype(jnp.float32) / (norms.astype(jnp.float32) + 1e-12)
    pad_rows = -(-n // block) * block - n
    if pad_rows:
        g = jnp.concatenate([g, jnp.zeros((k, pad_rows), g.dtype)], axis=1)
        noise = jnp.concatenate([noise, jnp.zeros((pad_rows,), noise.dtype)])
    y = ota_aggregate_blocked(g, scale, noise, jnp.asarray(a, jnp.float32),
                              block=block, interpret=interpret)
    return y[:n]


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention over [B, H, S, d] (kv head-expanded)."""
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_blocked(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(u, dt, a, bmat, cmat, *, block_d: int = 128,
                   chunk: int = 256, interpret: Optional[bool] = None):
    """Fused Mamba selective scan (see kernels/selective_scan.py)."""
    from repro.kernels.selective_scan import selective_scan_blocked
    interpret = _default_interpret() if interpret is None else interpret
    d, s = u.shape[2], u.shape[1]
    bd = block_d
    while d % bd != 0:
        bd //= 2
    cs = chunk
    while s % cs != 0:
        cs //= 2
    return selective_scan_blocked(u, dt, a, bmat, cmat, block_d=max(bd, 1),
                                  chunk=max(cs, 1), interpret=interpret)
