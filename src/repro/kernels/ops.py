"""Jitted public wrappers around the Pallas kernels.

``interpret`` semantics (the flat-reduction/OTA kernels):

* ``None`` (default) — Mosaic on TPU; on hosts without a TPU the wrapper
  routes to the mathematically-identical XLA oracle in ``repro.kernels.ref``.
  The Pallas *interpreter* costs ~1 ms per grid step on CPU, which made the
  kernels FL backend ~10x slower than vmap for no extra coverage; the oracle
  keeps non-TPU callers (the compiled FL engine, benchmarks on this
  container) at full XLA speed.
* ``True`` — force the Pallas interpreter: the correctness-validation path
  every kernel test pins explicitly (tests/test_kernels.py,
  tests/test_backends.py).
* ``False`` — force Mosaic compilation.

``flash_attention`` / ``selective_scan`` keep the old behaviour (interpreter
when no TPU): their CPU call sites are numerics-validation only.

Sharded streaming (``FLConfig.device_mesh``): the streaming variants
(``k_block != None``) are also the per-shard launch — inside the engine's
``shard_map`` each mesh device calls them on its OWN [k_block, N] tiles, so
the grid, VMEM working set, and in-kernel fp32 accumulation are all
shard-local and identical to the single-device stream over the same blocks.
The kernels never see the mesh: cross-shard closure is the runtime's
deterministic accumulator fold (``distribution.ota_collectives``), which is
what keeps the kernels backend bitwise across physical/emulated execution
(tests/test_sharded_streaming.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_blocked
from repro.kernels.grad_norm import (batched_blocked_moments, blocked_sumsq,
                                     streaming_blocked_moments)
from repro.kernels.ota_aggregate import (ota_aggregate_blocked,
                                         ota_aggregate_streaming)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> Union[bool, str]:
    """None -> Mosaic on TPU, 'ref' (XLA oracle) elsewhere; explicit bools
    force the Pallas path (True = interpreter, False = Mosaic)."""
    if interpret is None:
        return False if jax.default_backend() == "tpu" else "ref"
    return interpret


LANES = 1024  # trailing-dim packing for flat-vector kernels (8x128-aligned)


def _pack_flat(x: jax.Array, lanes: int = LANES,
               block_rows: Optional[int] = None):
    """Flatten + zero-pad a vector to [rows, lanes] (padding is norm- and
    moment-neutral).  With ``block_rows``, rows are further padded to a
    multiple of ``min(block_rows, rows)`` so the blocked kernels keep full
    tiles for ANY N (instead of degrading the block size to a divisor);
    returns (packed, n, effective_block_rows)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = max(1, -(-n // lanes))
    br = rows if block_rows is None else min(block_rows, rows)
    rows = -(-rows // br) * br
    pad = rows * lanes - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, lanes), n, br


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def grad_norm(x: jax.Array, *, block_rows: int = 256,
              interpret: Optional[bool] = None) -> jax.Array:
    """Global L2 norm of a gradient vector via the blocked Pallas reduction."""
    interpret = _resolve_interpret(interpret)
    if interpret == "ref":
        return ref.grad_norm_ref(x)
    x2, _, br = _pack_flat(x, block_rows=block_rows)
    partials = blocked_sumsq(x2, block_rows=br, interpret=interpret)
    return jnp.sqrt(jnp.sum(partials))


def _pack_flat_batched(g: jax.Array, lanes: int = LANES,
                       block_rows: int = 256):
    """[K, N] -> zero-padded [K, rows, lanes] with rows a multiple of the
    effective block size (padding is moment-neutral); returns
    (packed, n, effective_block_rows)."""
    k, n = g.shape
    rows = max(1, -(-n // lanes))
    br = min(block_rows, rows)
    rows = -(-rows // br) * br
    pad = rows * lanes - n
    if pad:
        g = jnp.concatenate([g, jnp.zeros((k, pad), g.dtype)], axis=1)
    return g.reshape(k, rows, lanes), n, br


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "k_block"))
def batched_moments(g: jax.Array, *, block_rows: int = 256,
                    interpret: Optional[bool] = None,
                    k_block: Optional[int] = None):
    """Per-device (sum of squares, sum) of stacked flat gradients.

    g: [K, N].  One batched Pallas reduction over a (K, blocks) grid — this
    replaces K separate ``grad_norm`` launches.  Returns ([K], [K]) f32.

    ``k_block`` selects the streaming variant: a (K-block, N-block) grid
    with in-kernel fp32 accumulation (the oracle is a ``lax.scan`` over
    K-blocks), so the working set is one (k_block, N) tile — the 100k-device
    path.  ``None`` keeps the dense kernel bitwise-unchanged.
    """
    interpret = _resolve_interpret(interpret)
    if k_block is not None:
        kb = min(k_block, g.shape[0])
        if g.shape[0] % kb != 0:
            raise ValueError(f"k_block {kb} must divide K {g.shape[0]}")
        if interpret == "ref":
            return ref.streaming_moments_ref(g, kb)
        g3, _, br = _pack_flat_batched(g, block_rows=block_rows)
        return streaming_blocked_moments(g3, k_block=kb, block_rows=br,
                                         interpret=interpret)
    if interpret == "ref":
        return ref.batched_moments_ref(g)
    g3, _, br = _pack_flat_batched(g, block_rows=block_rows)
    sumsq, sums = batched_blocked_moments(g3, block_rows=br, interpret=interpret)
    return jnp.sum(sumsq, axis=1), jnp.sum(sums, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def batched_grad_norms(g: jax.Array, *, block_rows: int = 256,
                       interpret: Optional[bool] = None) -> jax.Array:
    """[K] global L2 norms of stacked flat gradients, one pallas_call."""
    sumsq, _ = batched_moments(g, block_rows=block_rows, interpret=interpret)
    return jnp.sqrt(sumsq)


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "pre", "k_block"))
def ota_superpose(g: jax.Array, scale: jax.Array, noise: jax.Array, a, *,
                  pre: str = "identity", block: int = LANES,
                  interpret: Optional[bool] = None,
                  k_block: Optional[int] = None) -> jax.Array:
    """Fused superposition y = a (sum_k scale_k pre(g_k) + z) (paper eq. 10).

    g: [K, N]; scale: [K] composite per-device scale (h_k b_k x scheme
    scale); noise: [N]; a: scalar; pre: 'identity' | 'sign'.  Every
    norm-scaling scheme in the registry lowers to this one kernel.
    Returns y [N] f32.

    ``k_block`` selects the streaming kernel: the K-way reduction runs over
    an (N-block, K-block) grid whose output tile is the fp32 accumulator
    (oracle: sequential ``lax.scan`` over K-blocks), so VMEM holds
    (k_block, block) tiles instead of full-K columns.  ``None`` keeps the
    dense kernel bitwise-unchanged.
    """
    interpret = _resolve_interpret(interpret)
    a = jnp.asarray(a, jnp.float32)
    if k_block is not None:
        kb = min(k_block, g.shape[0])
        if g.shape[0] % kb != 0:
            raise ValueError(f"k_block {kb} must divide K {g.shape[0]}")
        if interpret == "ref":
            return ref.ota_superpose_streaming_ref(g, scale, noise, a,
                                                   pre=pre, k_block=kb)
    elif interpret == "ref":
        return ref.ota_superpose_ref(g, scale, noise, a, pre=pre)
    k, n = g.shape
    pad_rows = -(-n // block) * block - n
    if pad_rows:
        g = jnp.concatenate([g, jnp.zeros((k, pad_rows), g.dtype)], axis=1)
        noise = jnp.concatenate([noise, jnp.zeros((pad_rows,), noise.dtype)])
    if k_block is not None:
        y = ota_aggregate_streaming(g, scale.astype(jnp.float32), noise, a,
                                    k_block=min(k_block, k), block=block,
                                    interpret=interpret, pre=pre)
    else:
        y = ota_aggregate_blocked(g, scale.astype(jnp.float32), noise, a,
                                  block=block, interpret=interpret, pre=pre)
    return y[:n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ota_aggregate(g: jax.Array, hb: jax.Array, norms: jax.Array,
                  noise: jax.Array, a, *, block: int = LANES,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Fused normalize-amplify-superpose (eq. 10 with eq. 12) — the
    ``normalized``-scheme specialization of ``ota_superpose``, kept for
    callers that already hold per-device norms.
    """
    scale = hb.astype(jnp.float32) / (norms.astype(jnp.float32) + 1e-12)
    return ota_superpose(g, scale, noise, a, block=block, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention over [B, H, S, d] (kv head-expanded)."""
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_blocked(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(u, dt, a, bmat, cmat, *, block_d: int = 128,
                   chunk: int = 256, interpret: Optional[bool] = None):
    """Fused Mamba selective scan (see kernels/selective_scan.py)."""
    from repro.kernels.selective_scan import selective_scan_blocked
    interpret = _default_interpret() if interpret is None else interpret
    d, s = u.shape[2], u.shape[1]
    bd = block_d
    while d % bd != 0:
        bd //= 2
    cs = chunk
    while s % cs != 0:
        cs //= 2
    return selective_scan_blocked(u, dt, a, bmat, cmat, block_d=max(bd, 1),
                                  chunk=max(cs, 1), interpret=interpret)
