"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def grad_norm_ref(x: jax.Array) -> jax.Array:
    """Global L2 norm of a flat (or any-shape) gradient vector."""
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def blocked_sumsq_ref(x: jax.Array, block_rows: int) -> jax.Array:
    rows, cols = x.shape
    br = min(block_rows, rows)
    xb = x.reshape(rows // br, br * cols).astype(jnp.float32)
    return jnp.sum(xb * xb, axis=1)


def ota_aggregate_ref(g: jax.Array, scale: jax.Array, noise: jax.Array,
                      a: jax.Array) -> jax.Array:
    """y = a * (sum_k scale_k g_k + z), scale_k = h_k b_k / ||g_k||."""
    acc = jnp.einsum("k,kn->n", scale.astype(jnp.float32),
                     g.astype(jnp.float32))
    return a * (acc + noise.astype(jnp.float32))


def batched_moments_ref(g: jax.Array):
    """Per-device (sum of squares, sum) of [K, N] stacked flat gradients."""
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=1), jnp.sum(gf, axis=1)


def ota_superpose_ref(g: jax.Array, scale: jax.Array, noise: jax.Array,
                      a: jax.Array, pre: str = "identity") -> jax.Array:
    """y = a * (sum_k scale_k pre(g_k) + z) with pre in {identity, sign}."""
    gf = g.astype(jnp.float32)
    if pre == "sign":
        gf = jnp.sign(gf)
    acc = jnp.einsum("k,kn->n", scale.astype(jnp.float32), gf)
    return a * (acc + noise.astype(jnp.float32))


def streaming_moments_ref(g: jax.Array, k_block: int):
    """Oracle for the streaming moments path: per-device (sum of squares,
    sum) computed K-block by K-block with a ``lax.scan`` — the XLA lowering
    the non-TPU wrappers route to.  g: [K, N]; returns ([K], [K]) f32.
    The working set is one [k_block, N] view per step."""
    k, n = g.shape
    kb = min(k_block, k)
    if k % kb != 0:
        raise ValueError(f"k_block {kb} must divide K {k}")
    gb = g.reshape(k // kb, kb, n)

    def step(_, blk):
        bf = blk.astype(jnp.float32)
        return None, (jnp.sum(bf * bf, axis=1), jnp.sum(bf, axis=1))

    _, (sumsq, sums) = jax.lax.scan(step, None, gb)
    return sumsq.reshape(k), sums.reshape(k)


def ota_superpose_streaming_ref(g: jax.Array, scale: jax.Array,
                                noise: jax.Array, a: jax.Array,
                                pre: str = "identity", *,
                                k_block: int) -> jax.Array:
    """Oracle for the streaming superposition: the K-way reduction runs as a
    sequential ``lax.scan`` over K-blocks into a single fp32 [N] accumulator
    — the same association order as the (N-block, K-block) Pallas grid, and
    the XLA lowering the non-TPU wrappers use.  Never materializes the
    [K, N] product."""
    k, n = g.shape
    kb = min(k_block, k)
    if k % kb != 0:
        raise ValueError(f"k_block {kb} must divide K {k}")
    gb = g.reshape(k // kb, kb, n)
    sb = scale.astype(jnp.float32).reshape(k // kb, kb)

    def step(acc, xs):
        blk, s = xs
        bf = blk.astype(jnp.float32)
        if pre == "sign":
            bf = jnp.sign(bf)
        return acc + jnp.einsum("k,kn->n", s, bf), None

    acc, _ = jax.lax.scan(step, jnp.zeros((n,), jnp.float32), (gb, sb))
    return a * (acc + noise.astype(jnp.float32))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None) -> jax.Array:
    """q/k/v: [B, H, S, d].  Plain softmax attention, fp32 math."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(u, dt, a, bmat, cmat):
    """Sequential-scan oracle for the fused selective-scan kernel.

    u/dt: [B,S,D]; a: [D,N]; bmat/cmat: [B,S,N] -> y [B,S,D] f32."""
    b, s, d = u.shape
    n = a.shape[1]
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        da = jnp.exp(dtf[:, t, :, None] * a[None])              # [B,D,N]
        dbu = (dtf[:, t] * uf[:, t])[..., None] * bmat[:, t, None, :]
        h = da * h + dbu
        y = jnp.sum(h * cmat[:, t, None, :], axis=-1)           # [B,D]
        return h, y

    h0 = jnp.zeros((b, d, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.transpose(1, 0, 2)
