"""jamba-v0.1-52b — Mamba+attention 1:7 hybrid with MoE every 2nd layer
[arXiv:2403.19887].  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_d_ff=14336, moe_every=2,
    attn_period=8,                # 1 attention layer per 8 (1:7 interleave)
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    citation="arXiv:2403.19887",
)
