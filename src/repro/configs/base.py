"""Unified model/run configuration for every assigned architecture family.

One frozen dataclass covers dense decoders (GQA / SWA / QKV-bias), MoE,
Mamba-attention hybrids, xLSTM stacks, encoder-decoder, and modality-stub
VLM/audio backbones.  Each ``src/repro/configs/<arch>.py`` instantiates it
with the exact assigned numbers (cited), plus the paper's own models.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- attention ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False           # qwen2 uses QKV bias
    sliding_window: Optional[int] = None   # SWA window (h2o-danube / mistral-style)
    attn_logit_softcap: Optional[float] = None

    # --- MLP ---
    mlp_act: str = "silu"            # silu => SwiGLU; gelu => GeGLU

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # expert hidden size (d_ff is then unused/dense-layer size)
    moe_every: int = 1               # MoE MLP every n-th layer (jamba: 2), others dense MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- hybrid (jamba): one attention layer every attn_period layers, rest Mamba ---
    attn_period: int = 0             # 0 => not hybrid
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: Optional[int] = None    # default ceil(d_model/16)
    mamba_chunk: int = 256                 # chunked-scan length (memory lever)
    # dtype of the selective-scan intermediates (da/dbu/h).  float32 is the
    # reference; bfloat16 halves the scan's HBM traffic (§Perf lever) at a
    # small state-precision cost (carry stays fp32 at chunk boundaries).
    mamba_scan_dtype: str = "float32"

    # --- xLSTM ---
    slstm_every: int = 0             # sLSTM block every n-th layer; others mLSTM. 0 => no xLSTM
    mlstm_chunk: int = 256           # chunkwise-parallel chunk length for mLSTM

    # --- encoder-decoder ---
    num_encoder_layers: int = 0      # >0 => encoder-decoder (seamless)

    # --- modality stub (the one sanctioned carve-out: frontend not built) ---
    modality: Optional[str] = None   # 'vision' (pixtral) | 'audio' (seamless)
    modal_embed_dim: int = 0         # dim of precomputed patch/frame embeddings
    num_modal_tokens: int = 1024     # patches/frames per example at train shape

    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # q-chunk length for blockwise attention (memory roofline lever)
    attn_q_chunk: int = 512
    # vocab chunk: sequence-chunked cross-entropy (lever)
    loss_seq_chunk: int = 512
    # analysis mode: unroll every depth/chunk loop so XLA cost_analysis sees
    # the true op counts (scan bodies are otherwise counted ONCE —
    # EXPERIMENTS.md §Methodology).  Never used for the fits/compiles run.
    unroll: bool = False
    # ---- beyond-paper performance levers (EXPERIMENTS.md §Perf) ----
    # Megatron-style sequence parallelism: constrain the residual stream's
    # sequence dim to this mesh axis between blocks (activations stop being
    # replicated across the TP axis; per-layer all-reduces become
    # reduce-scatter + all-gather pairs).  None = paper-faithful baseline.
    seq_shard_activations: Optional[str] = None
    # decode KV-cache update: 'dynamic' (dynamic_update_slice; baseline) or
    # 'select' (masked full-cache write — GSPMD-friendly when the cache seq
    # dim is sharded across the mesh; trades one cache sweep of HBM traffic
    # for eliminating cross-shard gather/scatter of the whole cache).
    decode_cache_update: str = "dynamic"
    # remat policy for the depth scan: 'full' (recompute everything) or
    # 'dots' (save matmul outputs — trades activation memory for NOT
    # recomputing the TP collectives in the backward pass).
    remat_policy: str = "full"
    # decode: mesh axis that shards the KV-cache *sequence* dim (set by the
    # serve builder with shard_cache_seq).  decode_attention then pins the
    # flash-decoding sharding explicitly — q replicated (it is ~100 KB),
    # scores/softmax sharded over seq — because GSPMD's default is to keep q
    # head-sharded and all-gather the multi-GB cache instead.
    decode_cache_seq_axis: Optional[str] = None
    # Mamba-native parallelism: shard the D_inner (channel) dim of the
    # selective-scan intermediates over this mesh axis (the S6 recurrence is
    # diagonal over channels, so channel sharding is collective-free inside
    # the scan).  None = leave it to GSPMD propagation.
    mamba_shard_channels: Optional[str] = None
    # how many layers one scan "superblock" covers (hybrid period or pattern len)
    citation: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.attn_period and self.num_layers % self.attn_period != 0:
            raise ValueError("num_layers must be a multiple of attn_period")
        if self.slstm_every and self.num_layers % self.slstm_every != 0:
            raise ValueError("num_layers must be a multiple of slstm_every")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be a multiple of num_kv_heads")
        if self.mamba_dt_rank is None:
            object.__setattr__(self, "mamba_dt_rank", max(self.d_model // 16, 8))

    # ---- derived ----
    @property
    def is_hybrid(self) -> bool:
        return self.attn_period > 0

    @property
    def is_xlstm(self) -> bool:
        return self.slstm_every > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def block_pattern(self) -> Tuple[str, ...]:
        """Layer-type pattern of one scan superblock.

        Homogeneous stacks have a period-1 pattern; jamba has period
        ``attn_period`` (1 attention + (period-1) mamba, with MoE on every
        ``moe_every``-th layer); xLSTM has period ``slstm_every``.
        """
        if self.is_hybrid:
            pat = []
            for i in range(self.attn_period):
                # jamba places its attention layer mid-period (layer index 4 of 8);
                # we put it at position 0 of each superblock — same 1:7 ratio.
                kind = "attn" if i == 0 else "mamba"
                mlp = "moe" if (self.is_moe and i % self.moe_every == 1) else "dense"
                pat.append(f"{kind}+{mlp}")
            return tuple(pat)
        if self.is_xlstm:
            pat = ["mlstm"] * self.slstm_every
            pat[-1] = "slstm"
            return tuple(pat)
        mlp = "moe" if self.is_moe else "dense"
        return (f"attn+{mlp}",)

    @property
    def num_superblocks(self) -> int:
        return self.num_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND rooflines."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.modality:
            total += self.modal_embed_dim * d
        for blk in self.block_pattern * self.num_superblocks:
            kind, _, mlp = blk.partition("+")
            if kind == "attn" or kind == "":
                total += d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
            if kind == "mamba":
                di = self.mamba_d_inner
                total += d * 2 * di + di * self.mamba_d_conv \
                    + di * (self.mamba_dt_rank + 2 * self.mamba_d_state) \
                    + self.mamba_dt_rank * di + di * self.mamba_d_state + di + di * d
            if kind in ("mlstm", "slstm"):
                # up-proj (2x), qkv-ish projections, gates, down-proj (see models/xlstm.py)
                di = 2 * d
                total += d * 2 * di + 3 * di * di // max(self.num_heads, 1) + 4 * di + di * d
            if mlp == "dense":
                total += 3 * d * self.d_ff
            elif mlp == "moe":
                total += d * self.num_experts + 3 * d * self.moe_d_ff * self.num_experts
        if self.is_encoder_decoder:
            # encoder self-attn + dense mlp + decoder cross-attn
            enc = self.num_encoder_layers * (
                d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
                + 3 * d * self.d_ff)
            xattn = self.num_layers * (
                d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2)
            total += enc + xattn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only) — for 6·N_active·D."""
        if not self.is_moe:
            return self.param_count()
        full_moe = 3 * self.d_model * self.moe_d_ff * self.num_experts
        act_moe = 3 * self.d_model * self.moe_d_ff * self.experts_per_token
        n_moe_layers = sum(1 for b in self.block_pattern if b.endswith("moe")) \
            * self.num_superblocks
        return self.param_count() - n_moe_layers * (full_moe - act_moe)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch, kind) shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # 'train' | 'prefill' | 'decode'


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
