"""Architecture registry + input specs + reduced (smoke) variants.

``get_config(arch_id)`` returns the exact assigned configuration;
``reduce_config(cfg)`` produces the family-preserving smoke variant
(<=2 layers, d_model<=512, <=4 experts); ``input_specs(cfg, shape)`` builds
``jax.ShapeDtypeStruct`` stand-ins for every model input — weak-type-correct,
shardable, zero allocation — used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = (
    "h2o-danube-1.8b", "jamba-v0.1-52b", "qwen2-7b", "xlstm-1.3b",
    "olmoe-1b-7b", "granite-moe-1b-a400m", "phi3-mini-3.8b", "pixtral-12b",
    "seamless-m4t-medium", "llama3-405b",
)

_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-7b": "qwen2_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama3-405b": "llama3_405b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; one of {ARCH_IDS}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduce_config(cfg: ModelConfig, *, seq_len: int = 64) -> ModelConfig:
    """Family-preserving reduced variant for CPU smoke tests."""
    changes = dict(
        name=cfg.name + "-smoke",
        d_model=256, num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=None,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, seq_len // 2) if cfg.sliding_window else None,
        mlstm_chunk=16,
        attn_q_chunk=32, loss_seq_chunk=32,
        num_modal_tokens=8, modal_embed_dim=32,
        mamba_dt_rank=None,
    )
    if cfg.is_moe:
        changes.update(num_experts=4, experts_per_token=2, moe_d_ff=128)
    if cfg.is_hybrid:
        changes.update(attn_period=2, num_layers=4, moe_every=2)
    elif cfg.is_xlstm:
        changes.update(slstm_every=2, num_layers=4)
    else:
        changes.update(num_layers=2)
    if cfg.is_encoder_decoder:
        changes.update(num_encoder_layers=2)
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)


def _enc_len(seq_len: int) -> int:
    return max(seq_len // 4, 8)


def applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if this (arch x shape) pair runs; else a skip reason (DESIGN.md §4)."""
    if shape.name == "long_500k":
        subquadratic = cfg.is_hybrid or cfg.is_xlstm or cfg.sliding_window is not None
        if not subquadratic:
            return "full attention, no sub-quadratic variant (DESIGN.md §4)"
    return None


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch, input-shape) pair, as ShapeDtypeStructs.

    train/prefill: token batch (+ stub modality embeddings).
    decode: one new token per sequence (the KV/state cache is built separately
    by the launcher, since its sharding differs).
    """
    b, s = shape.global_batch, shape.seq_len
    emb_dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind in ("train", "prefill"):
        s_text = s - cfg.num_modal_tokens if cfg.modality == "vision" else s
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), dtype)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_text), dtype)
        if cfg.modality == "vision":
            specs["modal_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_modal_tokens, cfg.modal_embed_dim), emb_dt)
        if cfg.is_encoder_decoder:
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (b, _enc_len(s), cfg.modal_embed_dim), emb_dt)
    else:  # decode: one token, position scalar
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), dtype)
        specs["pos"] = jax.ShapeDtypeStruct((), dtype)
        if cfg.is_encoder_decoder:
            specs["src_embeds"] = jax.ShapeDtypeStruct(
                (b, _enc_len(min(s, 4096)), cfg.modal_embed_dim), emb_dt)
    return specs


def make_dummy_inputs(cfg: ModelConfig, shape: InputShape, key=None) -> Dict:
    """Concrete small inputs matching input_specs (smoke tests only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        sub = jax.random.fold_in(key, hash(name) % (2 ** 31))
        if jnp.issubdtype(spec.dtype, jnp.integer):
            if name == "pos":
                out[name] = jnp.zeros((), spec.dtype)
            else:
                out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab_size,
                                               spec.dtype)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32).astype(spec.dtype)
    return out
