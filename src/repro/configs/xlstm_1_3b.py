"""xlstm-1.3b — sLSTM + mLSTM block stack [arXiv:2405.04517].
48L d_model=2048 4H d_ff=0 (blocks carry their own projections) vocab=50304.
7:1 mLSTM:sLSTM ratio (xLSTM[7:1])."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=8,                # one sLSTM per 8 blocks (xLSTM[7:1])
    mlstm_chunk=256,
    citation="arXiv:2405.04517",
)
