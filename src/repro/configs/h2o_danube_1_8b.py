"""h2o-danube-1.8b — dense decoder, llama+mistral mix with sliding-window
attention [arXiv:2401.16818].  24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096,          # mistral-style SWA (model card)
    rope_theta=10000.0,
    citation="arXiv:2401.16818",
)
