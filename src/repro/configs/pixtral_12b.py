"""pixtral-12b — VLM: pixtral-ViT frontend (STUB: precomputed patch
embeddings) + mistral-nemo decoder backbone [hf:mistralai/Pixtral-12B-2409].
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072,
    head_dim=128, rope_theta=1000000.0,
    modality="vision", modal_embed_dim=1024, num_modal_tokens=1024,
    citation="hf:mistralai/Pixtral-12B-2409",
)
