"""seamless-m4t-medium — encoder-decoder, audio frontend STUB (precomputed
frame embeddings) [arXiv:2308.11596].  12L (x2: enc+dec) d_model=1024 16H
(kv=16) d_ff=4096 vocab=256206."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    num_encoder_layers=12,
    modality="audio", modal_embed_dim=1024, num_modal_tokens=1024,
    citation="arXiv:2308.11596",
)
