"""The built-in telemetry sinks: in-memory, JSONL event log, CSV, null.

All four consume the event schema documented in :mod:`repro.obs.base`; they
differ only in where events land.  Registering happens at import time (the
``repro.obs`` package imports this module), after which::

    rec = obs.make("jsonl", path="results/run.jsonl")
    experiment.run(300, recorder=rec)
    rec.close()
"""
from __future__ import annotations

import csv
import json
from typing import Any, Dict, List, Optional

from .base import Recorder, register


class NullRecorder(Recorder):
    """Discards every event — the 'recorder on, sink off' overhead floor."""

    name = "null"

    def emit(self, event: Dict[str, Any]) -> None:
        pass


class MemoryRecorder(Recorder):
    """Keeps every event in ``self.events`` and a latest-state snapshot —
    the sink behind the live-metrics endpoint (``repro.launch.serve
    .serve_metrics``) and the parity tests."""

    name = "memory"

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._latest: Dict[str, Optional[Dict[str, Any]]] = {
            "manifest": None, "round": None, "eval": None, "chunk": None}

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)
        kind = event.get("event")
        if kind in self._latest:
            self._latest[kind] = event

    def latest(self) -> Dict[str, Any]:
        """Latest-round snapshot: the most recent ``round`` / ``eval`` /
        ``chunk`` / ``manifest`` events plus the event count (what the
        live-metrics endpoint serves)."""
        return {"events": len(self.events), **self._latest}

    def select(self, kind: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e.get("event") == kind]


class JsonlRecorder(Recorder):
    """One JSON line per event.  Lines are buffered and flushed in batches
    so the engine's per-chunk emission stays off the dispatch critical path
    (the engine benchmark asserts the <= 1.05x overhead budget with this
    sink on)."""

    name = "jsonl"

    def __init__(self, path: str, flush_every: int = 256) -> None:
        self.path = path
        self._flush_every = max(int(flush_every), 1)
        self._buf: List[str] = []
        self._file = open(path, "w")

    def emit(self, event: Dict[str, Any]) -> None:
        self._buf.append(json.dumps(event, default=str))
        if len(self._buf) >= self._flush_every:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            self._file.write("\n".join(self._buf) + "\n")
            self._buf = []
        self._file.flush()

    def close(self) -> None:
        self.flush()
        self._file.close()


class CsvRecorder(Recorder):
    """Flat per-round table: one CSV row per ``round`` event, columns locked
    to the first row's keys (``round`` + the engine's ``DIAG_KEYS``).  Other
    event kinds are ignored — CSV is the quick-plot sink, the JSONL log is
    the faithful one."""

    name = "csv"

    def __init__(self, path: str) -> None:
        self.path = path
        self._file = open(path, "w", newline="")
        self._writer: Optional[csv.DictWriter] = None

    def emit(self, event: Dict[str, Any]) -> None:
        if event.get("event") != "round":
            return
        row = {k: (json.dumps(v) if isinstance(v, list) else v)
               for k, v in event.items() if k != "event"}
        if self._writer is None:
            self._writer = csv.DictWriter(self._file,
                                          fieldnames=list(row))
            self._writer.writeheader()
        self._writer.writerow(row)

    def close(self) -> None:
        self._file.flush()
        self._file.close()


register("null", NullRecorder)
register("memory", MemoryRecorder)
register("jsonl", JsonlRecorder)
register("csv", CsvRecorder)
