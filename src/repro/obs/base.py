"""Recorder registry and event model for the flight recorder.

Mirrors the ``core.schemes`` / ``channels.base`` register pattern: every
telemetry sink is one factory in a module-level registry, looked up by name
(``make("jsonl", path=...)``), so engines and benchmarks stay agnostic of
where events land.

The hard contract every sink inherits: telemetry is **trajectory-invisible**.
A :class:`Recorder` only ever sees host-side values that the engine already
transferred at a chunk boundary (``jax.device_get`` of the per-chunk
diagnostics, eval metrics, wall-clock) — it never touches device buffers,
PRNG keys, or traced values, so recorder on vs off (and any sink choice) is
bitwise-identical on params and history.  tracelint TL009 enforces the
static half of this contract: no ``obs`` call may appear inside a traced
context.

Event schema (one JSON-able dict per event; the JSONL sink writes exactly
one line per event, and ``Experiment.dump_history`` reproduces the same
``round``/``eval`` lines post-hoc):

* ``{"event": "manifest", "manifest": {...}}`` — run identity (see
  :mod:`repro.obs.manifest`); emitted once at run start.
* ``{"event": "round", "round": t, "<diag>": v, ...}`` — one FL round's
  ``DIAG_KEYS`` values; ``v`` is a float (``run``) or an [E] list
  (``run_batched``: one lane per experiment).
* ``{"event": "eval", "round": t, "<metric>": v, ...}`` — eval metrics at an
  eval boundary, same scalar/list convention.
* ``{"event": "chunk", "chunk": i, "round_start": .., "round_end": ..,
  "wall_time_s": .., "dispatches": .., "retraces": {kind: delta},
  "rss_mb": ..}`` — per-chunk engine attribution: wall clock around the
  device dispatch, dispatch count, re-trace deltas per
  ``runtime.TRACE_KINDS`` builder, and the host RSS sample.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np


def _round_value(values: Any, j: int) -> Any:
    """The round-``j`` slice of one diagnostic: float for a [T] series, an
    [E] list for a batched [E, T] series."""
    arr = np.asarray(values)
    if arr.ndim <= 1:
        return float(arr[j]) if arr.ndim == 1 else float(arr)
    return [float(x) for x in arr[:, j]]


def _scalar_or_list(v: Any) -> Any:
    arr = np.asarray(v)
    return float(arr) if arr.ndim == 0 else [float(x) for x in arr]


class Recorder:
    """Base telemetry sink: subclasses implement :meth:`emit` (one host-side
    event dict); the ``on_*`` helpers build the documented event schema so
    every sink agrees on it.  Recorders are context managers (``close`` on
    exit) and safe to reuse across runs — events just keep appending."""

    name = "base"

    # ------------------------------------------------------------------ sink

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Flush/release the sink (no-op by default)."""

    def __enter__(self) -> "Recorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- events

    def on_manifest(self, manifest: Mapping[str, Any]) -> None:
        self.emit({"event": "manifest", "manifest": dict(manifest)})

    def on_round(self, t: int, diag_row: Mapping[str, Any]) -> None:
        event: Dict[str, Any] = {"event": "round", "round": int(t)}
        for k, v in diag_row.items():
            event[k] = _scalar_or_list(v)
        self.emit(event)

    def on_chunk(self, index: int, ts: Sequence[int],
                 diag: Mapping[str, Any], *,
                 wall_time_s: Optional[float] = None, dispatches: int = 1,
                 retraces: Optional[Mapping[str, int]] = None,
                 rss_mb: Optional[float] = None) -> None:
        """One engine chunk: the chunk-attribution event followed by one
        ``round`` event per round in ``ts`` (``diag`` maps each diagnostic
        to its [T] — or batched [E, T] — chunk series)."""
        self.emit({
            "event": "chunk", "chunk": int(index),
            "round_start": int(ts[0]), "round_end": int(ts[-1]),
            "wall_time_s": wall_time_s, "dispatches": int(dispatches),
            "retraces": dict(retraces or {}), "rss_mb": rss_mb,
        })
        for j, t in enumerate(ts):
            self.on_round(int(t), {k: _round_value(v, j)
                                   for k, v in diag.items()})

    def on_eval(self, t: int, metrics: Mapping[str, Any]) -> None:
        event: Dict[str, Any] = {"event": "eval", "round": int(t)}
        for k, v in metrics.items():
            event[k] = _scalar_or_list(v)
        self.emit(event)


# ---------------------------------------------------------------------------
# registry (same idiom as core.schemes / channels.base)

_REGISTRY: Dict[str, Callable[..., Recorder]] = {}


def register(name: str, factory: Callable[..., Recorder]) -> None:
    if not callable(factory):
        raise TypeError(f"recorder factory for {name!r} must be callable")
    _REGISTRY[name] = factory


def get(name: str) -> Callable[..., Recorder]:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(names())
        raise KeyError(f"unknown recorder {name!r}; known: {known}")


def names() -> List[str]:
    return sorted(_REGISTRY)


def make(name: str, **kwargs) -> Recorder:
    """Instantiate a registered sink: ``make("jsonl", path="run.jsonl")``."""
    return get(name)(**kwargs)
