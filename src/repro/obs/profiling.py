"""Profiling hooks: env-gated ``jax.profiler`` traces and /proc RSS
sampling.

Everything here is host-side and inert by default: with ``REPRO_OBS_PROFILE``
unset, ``start_profile`` returns ``None`` and ``annotate_chunk`` hands back a
shared null context, so the engine's chunk loop pays nothing.  Setting the
variable to a directory turns every ``Experiment.run`` into a profiler trace
(``start_trace``/``stop_trace`` around the run, one ``StepTraceAnnotation``
per engine chunk) viewable in TensorBoard/Perfetto.

The RSS readers are the ``benchmarks/kscale_case.py`` /proc pattern promoted
to a library: ``VmHWM`` (peak) is a property of the current mm — exec-fresh,
unlike the fork-inherited ``ru_maxrss`` — and ``VmRSS`` (current) is the
per-chunk sample the recorder's ``chunk`` events carry.
"""
from __future__ import annotations

import contextlib
import os
import resource
from typing import Optional

PROFILE_ENV = "REPRO_OBS_PROFILE"

_NULL_CTX = contextlib.nullcontext()
# one trace at a time: nested Experiment.run calls (sweep fallbacks) must
# not try to re-enter jax.profiler.start_trace
_ACTIVE = False


def profile_dir() -> Optional[str]:
    """The profiler output directory, or None when profiling is off."""
    return os.environ.get(PROFILE_ENV) or None


def enabled() -> bool:
    return profile_dir() is not None


def _proc_status_mb(field: str) -> Optional[float]:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    return None


def rss_mb() -> Optional[float]:
    """Current resident set (VmRSS) in MB; None off-/proc platforms."""
    return _proc_status_mb("VmRSS")


def peak_rss_mb() -> float:
    """This process's peak resident set in MB: exec-fresh ``VmHWM`` when
    /proc exists (fork-inherited ``ru_maxrss`` would report the launcher's
    high-water mark), ``ru_maxrss`` as the non-/proc fallback."""
    hwm = _proc_status_mb("VmHWM")
    if hwm is not None:
        return hwm
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def start_profile() -> Optional[str]:
    """Begin a ``jax.profiler`` trace when ``REPRO_OBS_PROFILE`` names a
    directory (and no trace is already active).  Returns the directory as
    the handle for :func:`stop_profile`, else None."""
    global _ACTIVE
    out = profile_dir()
    if out is None or _ACTIVE:
        return None
    import jax

    jax.profiler.start_trace(out)
    _ACTIVE = True
    return out


def stop_profile(handle: Optional[str]) -> None:
    """End the trace started by :func:`start_profile` (no-op on None)."""
    global _ACTIVE
    if handle is None:
        return
    import jax

    jax.profiler.stop_trace()
    _ACTIVE = False


def annotate_chunk(index: int):
    """A ``StepTraceAnnotation`` naming one engine chunk inside an active
    profile; the shared null context when profiling is off."""
    if not enabled():
        return _NULL_CTX
    import jax

    return jax.profiler.StepTraceAnnotation("obs_chunk", step_num=int(index))
