"""Flight recorder: streaming telemetry, profiling hooks, and run manifests
for the compiled OTA-FL engine.

Three layers, all host-side:

* **Engine streaming** — ``repro.fed.runtime.run``/``run_batched`` accept a
  ``recorder`` and emit the per-chunk ``DIAG_KEYS`` series, eval metrics,
  per-chunk wall clock, dispatch counts, and re-trace attribution at chunk
  boundaries (after the on-device chunk returns — never inside the trace).
* **Profiling hooks** (:mod:`repro.obs.profiling`) — ``REPRO_OBS_PROFILE``
  env-gated ``jax.profiler`` traces around runs and chunks, plus the
  /proc RSS readers the K-scale benchmark pioneered.
* **Run manifests** (:mod:`repro.obs.manifest`) — spec JSON, structural
  signature, params sha-256, config hash, jax/platform versions: the
  identity block ``results/`` files and recorder streams carry.

The contract: telemetry is trajectory-invisible.  Recorder on vs off (any
sink) is bitwise-identical on params and history across both drivers, all
backends, ``k_block`` streaming, and ``device_mesh`` sharding — pinned by
``tests/test_obs.py`` and statically enforced by tracelint TL009.
"""
from .base import Recorder, get, make, names, register  # noqa: F401

# importing the sink module populates the registry (same idiom as
# repro.channels importing its model modules)
from .recorders import (CsvRecorder, JsonlRecorder,  # noqa: F401
                        MemoryRecorder, NullRecorder)

from . import manifest  # noqa: F401
from . import profiling  # noqa: F401
from .manifest import (config_sha256, params_sha256,  # noqa: F401
                       run_manifest, spec_json, structural_signature)
