"""Run manifests: the self-describing identity block every ``results/``
file (and recorder stream) carries.

A manifest answers "what produced these numbers" without re-reading the
producing script: the full spec as JSON, a *structural signature* (the
sha-256 of the runtime's ``structural_config`` collapse — two runs with
equal signatures compiled the same traced program), a params digest (the
bitwise trajectory fingerprint the parity suites pin), the config hash, and
the jax/platform versions.  ``benchmarks/compare.py --manifest`` fails a
comparison whose baseline was produced under a different structural
signature — a changed traced program is a different workload, not a noisy
rerun.

Imports of :mod:`repro.fed.runtime` stay function-local: the runtime
imports ``repro.obs`` for its profiling hooks, and manifests are built on
the host path only.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
from typing import Any, Dict, Optional

import numpy as np

MANIFEST_VERSION = 1


def _sanitize(obj: Any) -> Any:
    """JSON-able view of nested dataclasses/tuples/numpy scalars."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _sanitize(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def spec_json(spec: Any) -> Dict[str, Any]:
    """The spec (an ``ExperimentSpec`` or a bare ``FLConfig``) as plain
    JSON-able nesting."""
    return _sanitize(spec)


def config_sha256(spec: Any) -> str:
    """The tier-0 config hash: sha-256 of the canonical (sorted-key) JSON
    dump of the spec.  Equal hashes mean equal declared experiments."""
    blob = json.dumps(spec_json(spec), sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def params_sha256(params: Any) -> str:
    """Bitwise digest of a params pytree: dtype/shape-tagged raw bytes of
    every leaf in tree-flatten order.  The parity suites pin recorder-on vs
    recorder-off trajectories on exactly this digest."""
    import jax

    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten(params)[0]
    for leaf in leaves:
        arr = np.ascontiguousarray(np.asarray(leaf))
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def structural_signature(cfg: Any) -> str:
    """sha-256 of the runtime's structural collapse of ``cfg``: equal
    signatures <=> the same traced program (the sweep engine's sub-batch
    grouping key, hashed so manifests can carry and compare it)."""
    from repro.fed import runtime

    return hashlib.sha256(
        repr(runtime.structural_config(cfg)).encode()).hexdigest()


def run_manifest(spec: Any = None, cfg: Any = None, params: Any = None, *,
                 params_digest: Optional[str] = None,
                 extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble one manifest dict.

    ``spec`` contributes the spec JSON + config hash (and, via
    ``spec.fl_config()``, the structural signature when ``cfg`` is not given
    explicitly); ``params`` (or a precomputed ``params_digest``) contributes
    the trajectory fingerprint; ``extra`` rides along verbatim (round
    counters, sweep axes, benchmark knobs).
    """
    import jax

    out: Dict[str, Any] = {
        "manifest_version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "backend": jax.default_backend(),
        "local_devices": jax.local_device_count(),
    }
    if spec is not None:
        out["spec"] = spec_json(spec)
        out["config_sha256"] = config_sha256(spec)
        if cfg is None and hasattr(spec, "fl_config"):
            cfg = spec.fl_config()
    if cfg is not None:
        if spec is None:
            out["spec"] = spec_json(cfg)
            out["config_sha256"] = config_sha256(cfg)
        out["structural_signature"] = structural_signature(cfg)
    if params is not None:
        params_digest = params_sha256(params)
    if params_digest is not None:
        out["params_sha256"] = params_digest
    if extra:
        out.update(extra)
    return out
