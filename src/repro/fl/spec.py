"""The declarative experiment spec: one frozen object describes a full
OTA-FL experiment — channel/scheme/schedule (``FLConfig``), data (task,
split, batch size), model/loss, eval policy, and the scenario axes (server
optimizer, local steps, participation).

The paper's system is an iterative *spec* (scheme, channel, amplification
policy, schedule); running it should be declaring it.  ``ExperimentSpec``
replaces the historical hand-wiring of ~8 pieces (setup + run + grad_fn +
batch_provider + eval_fn + split + channel + constants) that every example
and benchmark duplicated.  ``repro.fl.Experiment`` compiles a spec into a
runnable object.

All spec dataclasses are frozen and hashable, so task construction and the
engine's compiled executables are cached across ``Experiment`` instances
with equal specs (sweeps and repeated benchmark runs re-use both the data
and the jitted round programs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.fed.runtime import FLConfig

DATASETS = ("synthetic_mnist", "ridge")
SPLITS = ("iid", "dirichlet")
MODEL_KINDS = ("auto", "mlp", "ridge")
# dataset -> model kind resolved by ModelSpec(kind='auto')
_AUTO_MODEL = {"synthetic_mnist": "mlp", "ridge": "ridge"}


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """What the devices train on and how it is partitioned across them."""

    dataset: str = "synthetic_mnist"   # 'synthetic_mnist' | 'ridge'
    split: str = "dirichlet"           # 'iid' | 'dirichlet'
    alpha: float = 1.0                 # dirichlet concentration (non-IID skew)
    batch_size: int = 50
    num_train: int = 4000
    num_test: int = 1000
    dim: int = 30                      # ridge feature dimension
    seed: int = 0                      # data/split/init/provider key root

    def __post_init__(self):
        if self.dataset not in DATASETS:
            raise ValueError(f"unknown dataset {self.dataset!r}; "
                             f"one of {DATASETS}")
        if self.split not in SPLITS:
            raise ValueError(f"unknown split {self.split!r}; one of {SPLITS}")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Model + loss.  ``kind='auto'`` picks the paper's model for the
    dataset: the 3-FC-layer ReLU classifier for synthetic MNIST (Case I),
    ridge regression for the ridge task (Case II)."""

    kind: str = "auto"
    hidden: int = 64                   # MLP hidden width
    lam: float = 0.1                   # ridge regularization

    def __post_init__(self):
        if self.kind not in MODEL_KINDS:
            raise ValueError(f"unknown model kind {self.kind!r}; "
                             f"one of {MODEL_KINDS}")

    def resolve(self, dataset: str) -> str:
        return _AUTO_MODEL[dataset] if self.kind == "auto" else self.kind


@dataclasses.dataclass(frozen=True)
class EvalSpec:
    """When the held-out metrics are computed (always at t == 1 and every
    ``every``-th round, matching both runtime drivers)."""

    every: int = 10
    enabled: bool = True

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"eval every must be >= 1, got {self.every}")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One declarative OTA-FL experiment: spec -> compiled run.

    ``fl`` carries the paper's system parameters (scheme, channel, case,
    amplification policy, backend) plus the scenario axes.  The optional
    top-level fields override the corresponding ``FLConfig`` fields when set,
    so a sweep can vary one axis with ``dataclasses.replace(spec,
    server_opt='adamw')`` without re-stating the whole config.
    """

    fl: FLConfig = dataclasses.field(default_factory=FLConfig)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    eval: EvalSpec = dataclasses.field(default_factory=EvalSpec)
    # scenario-axis overrides (None -> inherit the FLConfig value)
    server_opt: Optional[str] = None
    local_steps: Optional[int] = None
    local_lr: Optional[float] = None
    participation: Optional[float] = None
    participation_mode: Optional[str] = None
    # execution
    driver: str = "scan"
    chunk_size: int = 16

    def __post_init__(self):
        from repro.fed.runtime import DRIVERS
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r}; "
                             f"one of {DRIVERS}")
        self.fl_config()   # fail on an invalid axis override at spec time

    def fl_config(self) -> FLConfig:
        """The effective ``FLConfig`` with the spec's axis overrides folded
        in (constructing it re-runs FLConfig validation)."""
        over = {k: v for k, v in (
            ("server_opt", self.server_opt),
            ("local_steps", self.local_steps),
            ("local_lr", self.local_lr),
            ("participation", self.participation),
            ("participation_mode", self.participation_mode),
        ) if v is not None}
        return dataclasses.replace(self.fl, **over) if over else self.fl
