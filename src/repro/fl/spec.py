"""The declarative experiment spec: one frozen object describes a full
OTA-FL experiment — channel/scheme/schedule (``FLConfig``), data (task,
split, batch size), model/loss, eval policy, and the scenario axes (server
optimizer, local steps, participation).

The paper's system is an iterative *spec* (scheme, channel, amplification
policy, schedule); running it should be declaring it.  ``ExperimentSpec``
replaces the historical hand-wiring of ~8 pieces (setup + run + grad_fn +
batch_provider + eval_fn + split + channel + constants) that every example
and benchmark duplicated.  ``repro.fl.Experiment`` compiles a spec into a
runnable object.

All spec dataclasses are frozen and hashable, so task construction and the
engine's compiled executables are cached across ``Experiment`` instances
with equal specs (sweeps and repeated benchmark runs re-use both the data
and the jitted round programs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple

from repro.core.channel import ChannelConfig
from repro.fed.runtime import FLConfig
from repro.fl.clients import ClientConfig

DATASETS = ("synthetic_mnist", "ridge")
SPLITS = ("iid", "dirichlet")
MODEL_KINDS = ("auto", "mlp", "ridge")
# dataset -> model kind resolved by ModelSpec(kind='auto')
_AUTO_MODEL = {"synthetic_mnist": "mlp", "ridge": "ridge"}


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """What the devices train on and how it is partitioned across them."""

    dataset: str = "synthetic_mnist"   # 'synthetic_mnist' | 'ridge'
    split: str = "dirichlet"           # 'iid' | 'dirichlet'
    alpha: float = 1.0                 # dirichlet concentration (non-IID skew)
    batch_size: int = 50
    num_train: int = 4000
    num_test: int = 1000
    dim: int = 30                      # ridge feature dimension
    seed: int = 0                      # data/split/init/provider key root

    def __post_init__(self):
        if self.dataset not in DATASETS:
            raise ValueError(f"unknown dataset {self.dataset!r}; "
                             f"one of {DATASETS}")
        if self.split not in SPLITS:
            raise ValueError(f"unknown split {self.split!r}; one of {SPLITS}")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Model + loss.  ``kind='auto'`` picks the paper's model for the
    dataset: the 3-FC-layer ReLU classifier for synthetic MNIST (Case I),
    ridge regression for the ridge task (Case II)."""

    kind: str = "auto"
    hidden: int = 64                   # MLP hidden width
    lam: float = 0.1                   # ridge regularization

    def __post_init__(self):
        if self.kind not in MODEL_KINDS:
            raise ValueError(f"unknown model kind {self.kind!r}; "
                             f"one of {MODEL_KINDS}")

    def resolve(self, dataset: str) -> str:
        return _AUTO_MODEL[dataset] if self.kind == "auto" else self.kind


@dataclasses.dataclass(frozen=True)
class EvalSpec:
    """When the held-out metrics are computed (always at t == 1 and every
    ``every``-th round, matching both runtime drivers)."""

    every: int = 10
    enabled: bool = True

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"eval every must be >= 1, got {self.every}")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One declarative OTA-FL experiment: spec -> compiled run.

    ``fl`` carries the paper's system parameters (scheme, channel, case,
    amplification policy, backend) plus the scenario axes.  The optional
    top-level fields override the corresponding ``FLConfig`` fields when set,
    so a sweep can vary one axis with ``dataclasses.replace(spec,
    server_opt='adamw')`` without re-stating the whole config.
    """

    fl: FLConfig = dataclasses.field(default_factory=FLConfig)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    eval: EvalSpec = dataclasses.field(default_factory=EvalSpec)
    # scenario-axis overrides (None -> inherit the FLConfig value)
    server_opt: Optional[str] = None
    local_steps: Optional[int] = None
    local_lr: Optional[float] = None
    participation: Optional[float] = None
    participation_mode: Optional[str] = None
    # K-scale overrides (None -> inherit): the streaming block size, the
    # fixed-mode active-set gather, and the sharded-streaming mesh width
    # (see the FLConfig fields of the same name)
    k_block: Optional[int] = None
    active_gather: Optional[bool] = None
    device_mesh: Optional[int] = None
    # execution
    driver: str = "scan"
    chunk_size: int = 16

    def __post_init__(self):
        from repro.fed.runtime import DRIVERS
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r}; "
                             f"one of {DRIVERS}")
        self.fl_config()   # fail on an invalid axis override at spec time

    def fl_config(self) -> FLConfig:
        """The effective ``FLConfig`` with the spec's axis overrides folded
        in (constructing it re-runs FLConfig validation)."""
        over = {k: v for k, v in (
            ("server_opt", self.server_opt),
            ("local_steps", self.local_steps),
            ("local_lr", self.local_lr),
            ("participation", self.participation),
            ("participation_mode", self.participation_mode),
            ("k_block", self.k_block),
            ("active_gather", self.active_gather),
            ("device_mesh", self.device_mesh),
        ) if v is not None}
        return dataclasses.replace(self.fl, **over) if over else self.fl


# ---------------------------------------------------------------------------
# sweep-axis resolution: one flat namespace over the nested spec
#
# A sweep axis addresses a field of the nested spec by bare name
# ("noise_var", "scheme", "alpha") or, to disambiguate, by dotted scope
# ("fl.seed", "data.seed").  Scopes are searched in the order below; the
# first hit wins, so e.g. bare "seed" is the CHANNEL/RUN seed (fl.seed) and
# the data/init seed must be spelled "data.seed".  The wireless-environment
# axes (repro.channels) live on the "channel" scope: "channel.model",
# "channel.rho", "channel.csi_error", "channel.rician_k",
# "channel.geometry" (GeometryConfig values), ... — note bare "model"
# resolves to the CHANNEL model; the model *spec* scope is only reachable
# dotted ("model.hidden").  rho/csi_error are batchable lanes
# (runtime.BATCHED_CHANNEL_FIELDS); model/geometry/rician_k are structural.

_SCOPE_ORDER: Tuple[Tuple[str, type], ...] = (
    ("fl", FLConfig),
    ("channel", ChannelConfig),
    ("data", DataSpec),
    ("model", ModelSpec),
    # LAST: ClientConfig.alpha would otherwise shadow DataSpec.alpha — bare
    # "alpha" stays the dirichlet concentration; spell the feddyn strength
    # "client.alpha" (bare "mu" and "algo" are unambiguous and resolve here)
    ("client", ClientConfig),
)
_SCOPE_FIELDS = {scope: tuple(f.name for f in dataclasses.fields(cls))
                 for scope, cls in _SCOPE_ORDER}
# ExperimentSpec-level execution knobs are deliberately NOT sweepable: the
# sweep engine owns eval alignment / driver / chunking.  The scenario-axis
# override fields sweep through their FLConfig name (apply_axis writes the
# spec-level override so it can never be shadowed).
_UNSWEEPABLE = ("eval", "driver", "chunk_size")
_OVERRIDE_FIELDS = ("server_opt", "local_steps", "local_lr",
                    "participation", "participation_mode", "k_block",
                    "active_gather", "device_mesh")


def resolve_axis(name: str) -> Tuple[str, str]:
    """Resolve a sweep-axis name to ``(scope, field)`` with scope one of
    ``fl`` / ``channel`` / ``data`` / ``model``.  Raises ``ValueError`` for
    unknown or unsweepable names."""
    if "." in name:
        scope, _, field = name.partition(".")
        if scope not in _SCOPE_FIELDS:
            raise ValueError(f"unknown sweep scope {scope!r} in {name!r}; "
                             f"one of {tuple(_SCOPE_FIELDS)}")
        if field not in _SCOPE_FIELDS[scope]:
            raise ValueError(f"{scope!r} spec has no field {field!r}; "
                             f"one of {_SCOPE_FIELDS[scope]}")
        return scope, field
    for scope, fields in _SCOPE_FIELDS.items():
        if name in fields:
            return scope, name
    if name in _UNSWEEPABLE or name in {
            f.name for f in dataclasses.fields(ExperimentSpec)}:
        raise ValueError(f"{name!r} is not sweepable (execution/eval knobs "
                         "are owned by the sweep engine; scenario-axis "
                         "overrides sweep via their FLConfig field)")
    known = sorted(set().union(*_SCOPE_FIELDS.values()))
    raise ValueError(f"unknown sweep axis {name!r}; known fields: {known}")


def apply_axis(spec: ExperimentSpec, name: str, value: Any) -> ExperimentSpec:
    """Return ``spec`` with one resolved axis field replaced (validation of
    the resulting spec runs via the dataclass ``__post_init__``s)."""
    scope, field = resolve_axis(name)
    if scope == "fl":
        if field in _OVERRIDE_FIELDS:
            # scenario axes have a spec-level override that outranks the
            # FLConfig field in fl_config(); write the override so an axis
            # value can never be shadowed by a base-spec override
            return dataclasses.replace(spec, **{field: value})
        if field == "num_devices":
            # K lives in BOTH FLConfig and the already-built ChannelConfig;
            # a sweep over the cohort size must move them together or setup
            # draws a channel of the stale length
            channel = dataclasses.replace(spec.fl.channel, num_devices=value)
            return dataclasses.replace(
                spec, fl=dataclasses.replace(spec.fl, num_devices=value,
                                             channel=channel))
        return dataclasses.replace(
            spec, fl=dataclasses.replace(spec.fl, **{field: value}))
    if scope == "channel":
        if field == "num_devices":
            raise ValueError("sweep the cohort size via 'num_devices' (the "
                             "FLConfig field) — it keeps the channel length "
                             "in sync")
        channel = dataclasses.replace(spec.fl.channel, **{field: value})
        return dataclasses.replace(
            spec, fl=dataclasses.replace(spec.fl, channel=channel))
    if scope == "client":
        client = dataclasses.replace(spec.fl.client, **{field: value})
        return dataclasses.replace(
            spec, fl=dataclasses.replace(spec.fl, client=client))
    if scope == "data":
        return dataclasses.replace(
            spec, data=dataclasses.replace(spec.data, **{field: value}))
    return dataclasses.replace(
        spec, model=dataclasses.replace(spec.model, **{field: value}))


def apply_axes(spec: ExperimentSpec,
               coords: Mapping[str, Any]) -> ExperimentSpec:
    """Fold a mapping of axis name -> value into a spec, one grid point."""
    for name, value in coords.items():
        spec = apply_axis(spec, name, value)
    return spec
