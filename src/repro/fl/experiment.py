"""``Experiment``: compile an ``ExperimentSpec`` into a runnable object.

    spec = ExperimentSpec(fl=FLConfig(scheme="normalized", case="II"),
                          data=DataSpec(dataset="ridge"))
    e = Experiment(spec)
    e.run(300)                      # setup() is implicit on first run
    e.history["gap"]                # accumulated across run() calls
    e.save("ckpt.msgpack")          # params + server-opt state + channel
    ...
    e2 = Experiment(spec); e2.load("ckpt.msgpack"); e2.run(300)  # resumes

One object drives both runtime drivers (``scan``/``python``) and all three
execution backends; with the default axes (``server_opt='sgd'``,
``local_steps=1``, ``participation=1.0``) the produced history is exactly
``repro.fed.runtime.run``'s (bitwise on CPU) — the facade adds declaration,
not new math.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import store
from repro.fed import runtime
from repro.fl.spec import ExperimentSpec
from repro.fl.tasks import Task, build_task

PyTree = Any


class Experiment:
    """A declarative OTA-FL experiment: spec -> setup() -> run(num_rounds).

    ``history`` accumulates every per-round diagnostic and eval metric across
    ``run()`` calls; ``save()``/``load()`` checkpoint the full resumable
    state (model params, server-optimizer state, channel/round) through
    ``repro.checkpoint.store``.

    Checkpoints are portable across PHYSICAL device counts: the spec's
    ``device_mesh`` defines the round's accumulation order (the math), not
    where it runs, and the checkpoint tree carries no placement — so a run
    saved on a 4-device host resumes bitwise-identically on 1 device (the
    sharded engine falls back to its emulated path; see
    ``FLConfig.device_mesh`` and tests/test_sharded_streaming.py).
    """

    def __init__(self, spec: ExperimentSpec, task: Optional[Task] = None,
                 recorder: Optional[obs.Recorder] = None):
        self.spec = spec
        self.cfg = spec.fl_config()
        # a caller that already built the task (the sweep engine's sequential
        # fallback, parity tests) may inject it; it must match the spec —
        # build_task's lru cache makes the default path equally shared
        self.task: Optional[Task] = task
        self.state: Optional[runtime.FLState] = None
        self.history: Dict[str, List] = {}
        # default flight-recorder sink for every run() (a per-call recorder
        # overrides it); telemetry is trajectory-invisible, see repro.obs
        self.recorder: Optional[obs.Recorder] = recorder

    # ------------------------------------------------------------------ setup

    def setup(self) -> "Experiment":
        """Build (or fetch the cached) task, draw the channel, and run the
        paper's parameter optimization (Problem 3 / Algorithm 1)."""
        if self.task is None:
            self.task = build_task(self.spec.data, self.spec.model,
                                   self.cfg.num_devices)
        self.state = runtime.setup(self.cfg, self.task.params0,
                                   self.task.model_dim)
        self.history = {}
        return self

    def reset(self) -> "Experiment":
        """Re-setup from round 0 (fresh params/optimizer/channel state); the
        cached task — and therefore the compiled executables keyed on its
        ``grad_fn`` — is reused."""
        return self.setup()

    def _ensure_setup(self):
        if self.state is None:
            self.setup()

    # -------------------------------------------------------------------- run

    def run(self, num_rounds: int, *, driver: Optional[str] = None,
            chunk_size: Optional[int] = None,
            eval_every: Optional[int] = None,
            evaluate: Optional[bool] = None,
            recorder: Optional[obs.Recorder] = None) -> Dict[str, List]:
        """Run ``num_rounds`` FL rounds and merge the produced history into
        ``self.history``.  Keyword overrides exist for benchmarking both
        drivers from one spec; experiments normally declare everything in
        the spec.  Returns this call's history (the increment, not the
        accumulated ``self.history``).

        ``recorder`` (or the constructor's default) streams the run live —
        one manifest event, then chunk/round/eval events from the engine;
        with ``REPRO_OBS_PROFILE`` set, the whole call is wrapped in a
        ``jax.profiler`` trace.  Both are trajectory-invisible."""
        self._ensure_setup()
        ev = self.spec.eval
        enabled = ev.enabled if evaluate is None else evaluate
        rec = recorder if recorder is not None else self.recorder
        if rec is not None:
            rec.on_manifest(self.manifest())
        handle = obs.profiling.start_profile()
        try:
            self.state, hist = runtime.run(
                self.cfg, self.state, self.task.grad_fn,
                self.task.batch_provider, num_rounds,
                eval_fn=self.task.eval_fn if enabled else None,
                eval_every=eval_every if eval_every is not None else ev.every,
                driver=driver or self.spec.driver,
                chunk_size=chunk_size or self.spec.chunk_size,
                chunk_batch_provider=self.task.chunk_batch_provider,
                recorder=rec)
        finally:
            obs.profiling.stop_profile(handle)
        for k, v in hist.items():
            self.history.setdefault(k, []).extend(v)
        return hist

    # ---------------------------------------------------------- observability

    def manifest(self) -> Dict[str, Any]:
        """This experiment's run manifest: spec JSON, config hash,
        structural signature, the current params digest, and the jax /
        platform identity block (see :mod:`repro.obs.manifest`)."""
        self._ensure_setup()
        return obs.run_manifest(spec=self.spec, cfg=self.cfg,
                                params=self.state.params,
                                extra={"round": int(self.state.round)})

    def dump_history(self, path: str) -> str:
        """Write the accumulated ``self.history`` to ``path`` as the same
        JSONL event stream a live :class:`repro.obs.JsonlRecorder` produces
        (manifest line, then one ``round`` line per round and one ``eval``
        line per eval boundary) — post-hoc telemetry for runs that did not
        record live."""
        self._ensure_setup()
        diag_keys = [k for k in runtime.DIAG_KEYS if k in self.history]
        eval_keys = [k for k in self.history
                     if k not in ("round", "eval_round")
                     and k not in runtime.DIAG_KEYS]
        with obs.JsonlRecorder(path) as rec:
            rec.on_manifest(self.manifest())
            for j, t in enumerate(self.history.get("round", [])):
                rec.on_round(int(t), {k: self.history[k][j]
                                      for k in diag_keys})
            for j, t in enumerate(self.history.get("eval_round", [])):
                rec.on_eval(int(t), {k: self.history[k][j]
                                     for k in eval_keys})
        return path

    # ------------------------------------------------------------- properties

    @property
    def params(self) -> PyTree:
        self._ensure_setup()
        return self.state.params

    @property
    def round(self) -> int:
        return 0 if self.state is None else self.state.round

    # ------------------------------------------------------------ checkpoints

    def _ckpt_tree(self) -> PyTree:
        st = self.state
        channel = {
            "h": np.asarray(st.h, np.float64),
            "b": np.asarray(st.b, np.float64),
            "a": np.asarray(st.a, np.float64),
            "eta0": np.asarray(st.eta0, np.float64),
            # the server's CSI estimate (== h under perfect CSI)
            "h_hat": np.asarray(st.h_hat if st.h_hat is not None else st.h,
                                np.float64),
        }
        # optional wireless-environment state: present iff the spec's
        # channel model/geometry produces it, so the tree structure is a
        # function of the spec alone (save/load on equal specs round-trips)
        if st.fad_state is not None:
            channel["fad_state"] = np.asarray(st.fad_state, np.float64)
        if st.scale is not None:
            channel["scale"] = np.asarray(st.scale, np.float64)
        out = {"params": st.params, "opt": st.opt_state, "channel": channel}
        # client-algorithm state (repro.fl.clients): present iff the spec's
        # algorithm is stateful — again a function of the spec alone
        if st.client_state is not None:
            out["client"] = jax.tree_util.tree_map(
                lambda l: np.asarray(l, np.float32), st.client_state)
        return out

    def save(self, path: str) -> str:
        """Checkpoint params + server-optimizer state + channel/round so a
        fresh ``Experiment`` on the same spec can ``load`` and resume the
        exact trajectory."""
        self._ensure_setup()
        if self.state.opt_state is None:
            # run() initializes lazily; a save before any run records step 0
            self.state.opt_state = runtime.server_optimizer(
                self.cfg).init(self.state.params)
        store.save(path, self._ckpt_tree(),
                   {"round": self.state.round,
                    "model_dim": self.state.model_dim,
                    "scheme": self.cfg.scheme,
                    "server_opt": self.cfg.server_opt})
        return path

    def load(self, path: str) -> "Experiment":
        """Restore a checkpoint written by ``save`` (shape/dtype checked
        against this spec's params and optimizer structure) and position the
        experiment at the checkpoint's round.  Non-strict on two scoped
        prefixes only: checkpoints from before the wireless-environment
        subsystem lack ``h_hat``/``fad_state``/``scale`` and keep the
        ``setup()`` values (exact for the default environment they were
        written under), and checkpoints from before the client-algorithm
        registry lack the ``['client']`` subtree and keep ``setup()``'s zero
        client state (exactly what those runs carried implicitly); a
        params/optimizer structure mismatch still fails loudly."""
        self._ensure_setup()
        if self.state.opt_state is None:
            self.state.opt_state = runtime.server_optimizer(
                self.cfg).init(self.state.params)
        restored, meta = store.restore(
            path, self._ckpt_tree(),
            # ONLY the post-subsystem leaves may be absent; a checkpoint
            # missing h/b/a/eta0 (or params/opt leaves) still fails loudly
            missing_ok=("['channel']['h_hat']", "['channel']['fad_state']",
                        "['channel']['scale']", "['client']"))
        st = self.state
        st.params = restored["params"]
        st.opt_state = restored["opt"]
        st.h = np.asarray(restored["channel"]["h"], np.float64)
        st.b = np.asarray(restored["channel"]["b"], np.float64)
        st.a = float(restored["channel"]["a"])
        st.eta0 = float(restored["channel"]["eta0"])
        st.h_hat = np.asarray(restored["channel"]["h_hat"], np.float64)
        if "fad_state" in restored["channel"]:
            st.fad_state = np.asarray(restored["channel"]["fad_state"],
                                      np.float64)
        if "scale" in restored["channel"]:
            st.scale = np.asarray(restored["channel"]["scale"], np.float64)
        if "client" in restored:
            st.client_state = restored["client"]
        st.round = int(meta["round"])
        return self
