"""The client-algorithm registry: what each device *optimizes locally*
during its H local steps, and what it transmits — generalizing the paper's
plain local SGD (Sec. II Step 1) the same way ``repro.core.schemes``
generalizes the transmit transform and ``repro.channels`` the radio
environment.  One ``ClientAlgorithm`` record = a local-objective correction,
optional per-client state ``[K, ...]`` (threaded through the scan carry,
``FLState``, and checkpoints by the runtime), and one-or-more transmitted
statistics: algorithms whose server-side state must itself be learned from
the cohort (SCAFFOLD's control variate ``c``, FedDyn's correction mean
``hbar``) declare a SECOND OTA transmission slot, and the runtime runs the
round as N slots — each with its own normalization scheme, superposition,
independent noise key, and eq.-8 energy accounting.

Registered algorithms (the drift-correction landscape of arXiv 2310.10089):

``sgd``      the paper's round, bitwise-pinned default: no correction, no
             state, one slot — the runtime's sgd trace is IDENTICAL to the
             pre-registry engine (tests/golden pins both drivers).
``fedprox``  stateless proximal term ``mu/2 ||w - w_t||^2`` added to each
             local objective: the local gradient becomes
             ``g + mu (w - w_t)``, pulling the H-step trajectory back to
             the round's broadcast model.
``feddyn``   dynamic regularization: per-client correction state ``h_k``
             (a gradient-shaped pytree) enters every local gradient as
             ``g + alpha (w - w_t) - h_k + hbar`` and integrates the
             client's realized drift after the round, ``h_k <- h_k -
             alpha (w_k^H - w_t)``.  Textbook FedDyn subtracts its server
             state ``hbar = mean_k h_k`` on the server (``-hbar/alpha``);
             the paper's eq.-11 step has no slot for that shift, so hbar
             re-enters the local objective as the tilt ``+<hbar, w>`` —
             on the air the ``-h_k + hbar`` pair cancels participant
             bias, and hbar is learned from a SECOND OTA slot carrying
             the refreshed ``h_k``.
``scaffold`` control variates: local gradient ``g - c_k + c`` with a
             per-client variate ``c_k`` and a server variate ``c``.  The
             refreshed variates ``c_k^+`` ride a SECOND OTA slot (scheme
             ``ClientConfig.variate_scheme``, default the
             magnitude-restoring ``normalized_restored``), and the server
             tracks ``c <- (1 - m/K) c + (m/K) mean_k c_k^+`` from the
             de-gained slot-2 aggregate — the variates never leave the
             air interface any more than the gradients do.

All callables must be jit/vmap/scan-safe (the compiled engine calls them
inside ``lax.scan``, and the sweep engine vmaps that body).  They operate on
pytrees with broadcasting-compatible shapes: ``correction`` runs per device
(inside the runtime's device vmap), the state transitions on stacked
``[K, ...]`` trees (server-state leaves broadcast against the leading K).

Registering is the only extension step::

    register(ClientAlgorithm(name="myalgo", correction=...))

after which ``ClientConfig(algo="myalgo")`` validates, both runtime drivers
and all three OTA backends run it, and sweeps accept a ``client.algo``
axis.  This module is imported by ``repro.fed.runtime`` (like
``repro.core.schemes``) and therefore must not import the runtime or its
``repro.fl`` siblings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import numpy as np

PyTree = Any

# ClientConfig sweep classification (tracelint TL005; consumed by
# repro.fl.sweep.classify_field and collapsed by runtime.structural_config):
# mu/alpha are per-experiment traced scalars of a batched run, algo and the
# slot-2 scheme change the traced program.
BATCHED_CLIENT_FIELDS = ("mu", "alpha")
STRUCTURAL_CLIENT_FIELDS = ("algo", "variate_scheme")


class ClientParams(NamedTuple):
    """The batchable client-algorithm numerics as (possibly traced) scalars:
    baked config floats in a single run, per-experiment ``BatchAxes`` lanes
    in a batched sweep."""

    mu: Any = 0.0        # fedprox proximal strength
    alpha: Any = 0.01    # feddyn regularization strength


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    """Which client algorithm runs on the devices, and its constants."""

    algo: str = "sgd"
    mu: float = 0.0          # fedprox: proximal term mu/2 ||w - w_t||^2
    alpha: float = 0.01      # feddyn: dynamic-regularization strength
    # transmit scheme of the second OTA slot (scaffold's variate deltas);
    # normalized_restored keeps the paper's unit-norm power discipline per
    # slot while the server folds the magnitude back from side info
    variate_scheme: str = "normalized_restored"

    def __post_init__(self):
        get(self.algo)       # raises ValueError naming the registry
        if self.mu < 0.0:
            raise ValueError(f"mu must be >= 0, got {self.mu}")
        if self.alpha < 0.0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")


# correction(cp, w_now, w_round, dev_state, srv_state, g) -> corrected g
CorrectionFn = Callable[..., PyTree]
# update_state(cp, hlr, dev_state, srv_state, delta) -> new dev_state
# (stacked [K, ...]; delta is the round's model delta
# (w_t - w_k^H)/(H local_lr), hlr the product H * local_lr)
UpdateStateFn = Callable[..., PyTree]
# variate_stat(cp, dev_old, dev_new, srv_state, delta) -> slot-2 stack
VariateStatFn = Callable[..., PyTree]
# apply_variate(cp, srv_state, y2, part_frac) -> new srv_state (y2 is the
# de-gained slot-2 aggregate: approximately the participant mean statistic)
ApplyVariateFn = Callable[..., PyTree]


@dataclasses.dataclass(frozen=True)
class ClientAlgorithm:
    """One client-side FL algorithm (see module docstring for the contract).

    ``uses_mu`` / ``uses_alpha`` declare which ``ClientConfig`` numerics the
    callables read — the batched sweep engine threads exactly those as
    per-experiment lanes (``BatchAxes.client_mu`` / ``client_alpha``)."""

    name: str
    doc: str = ""
    correction: Optional[CorrectionFn] = None
    # per-device state [K, <param shapes>] threaded by the runtime
    has_state: bool = False
    update_state: Optional[UpdateStateFn] = None
    # server-side state (one param-shaped pytree) + the second OTA slot
    has_server_state: bool = False
    num_slots: int = 1
    variate_stat: Optional[VariateStatFn] = None
    apply_variate: Optional[ApplyVariateFn] = None
    uses_mu: bool = False
    uses_alpha: bool = False

    def __post_init__(self):
        # registration IS the whole extension step; an inconsistent record
        # must fail here, not diverge between drivers/backends later
        if self.num_slots not in (1, 2):
            raise ValueError(f"algorithm {self.name!r}: num_slots must be 1 "
                             f"or 2, got {self.num_slots}")
        if self.has_state and self.update_state is None:
            raise ValueError(f"algorithm {self.name!r} threads per-client "
                             "state but has no update_state transition")
        if self.num_slots == 2:
            if self.variate_stat is None or self.apply_variate is None:
                raise ValueError(
                    f"algorithm {self.name!r} declares a second OTA slot; "
                    "it needs variate_stat (what the devices transmit) and "
                    "apply_variate (how the server consumes the aggregate)")
            if not self.has_server_state:
                raise ValueError(
                    f"algorithm {self.name!r}: a second slot exists to learn "
                    "server-side state; set has_server_state")
        elif self.has_server_state:
            raise ValueError(
                f"algorithm {self.name!r} carries server state with no slot "
                "to learn it from (num_slots must be 2)")

    @property
    def stateful(self) -> bool:
        return self.has_state or self.has_server_state


_REGISTRY: Dict[str, ClientAlgorithm] = {}


def register(alg: ClientAlgorithm) -> ClientAlgorithm:
    if alg.name in _REGISTRY:
        raise ValueError(f"client algorithm {alg.name!r} already registered")
    _REGISTRY[alg.name] = alg
    return alg


def get(name: str) -> ClientAlgorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown client algorithm {name!r}; one of {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def init_state(ccfg: ClientConfig, params0: PyTree,
               num_devices: int) -> Optional[Dict[str, Any]]:
    """Host-side zero client state for ``setup()``: ``{"dev": [K, ...] or
    None, "srv": param-shaped or None}``, or None for stateless algorithms
    (sgd/fedprox keep the pre-registry leafless carry/checkpoint)."""
    alg = get(ccfg.algo)
    if not alg.stateful:
        return None

    def zeros(leading=()):
        return jax.tree_util.tree_map(
            lambda p: np.zeros(leading + tuple(np.shape(p)), np.float32),
            params0)

    return {"dev": zeros((num_devices,)) if alg.has_state else None,
            "srv": zeros() if alg.has_server_state else None}


def resolve_params(ccfg: ClientConfig, over_mu=None,
                   over_alpha=None) -> ClientParams:
    """The (possibly traced) numerics the algorithm callables see: baked
    config values, each overridden by its batched sweep lane when set."""
    return ClientParams(
        mu=ccfg.mu if over_mu is None else over_mu,
        alpha=ccfg.alpha if over_alpha is None else over_alpha)


# ---------------------------------------------------------------------------
# the registered algorithms


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


register(ClientAlgorithm(
    name="sgd",
    doc="plain local SGD (the paper's round; bitwise-pinned default)",
))


register(ClientAlgorithm(
    name="fedprox",
    doc="proximal local objective f_k(w) + mu/2 ||w - w_t||^2 "
        "(stateless; mu = ClientConfig.mu)",
    uses_mu=True,
    correction=lambda cp, w, w0, dev, srv, g: _tmap(
        lambda gl, wl, w0l: gl + cp.mu * (wl - w0l), g, w, w0),
))


def _variate_refreshed(cp, dev_old, dev_new, srv, delta):
    # transmitted slot-2 statistic: the REFRESHED per-client state itself,
    # not the textbook increment (new - old).  Both are exact over a clean
    # channel (full participation: srv^+ = mean_k state_k^+ either way), but
    # they differ under OTA noise: with increments the server's tracking
    # error e = srv - mean_k state_k obeys e^+ = e + eta (the per-round
    # estimation noise INTEGRATES as a random walk; ||srv|| grows ~ sqrt(t),
    # the local corrections inflate every transmitted statistic with it, and
    # normalization then drowns the true gradients — training stalls).
    # Transmitting the state itself makes the noise enter once per round
    # with no feedback: e^+ = eta.
    return dev_new


def _apply_tracking(cp, srv, y2, part_frac):
    # tracking form of the server's state step:
    # srv <- (1 - m/K) srv + (m/K) mean_{k in S} state_k^+, the participant
    # mean read off the de-gained slot-2 aggregate.  Full participation
    # gives srv = mean_k state_k^+ exactly (the textbook invariant of both
    # SCAFFOLD's c and FedDyn's hbar); an empty round (m = 0) holds srv.
    return _tmap(lambda sl, yl: (1.0 - part_frac) * sl + part_frac * yl,
                 srv, y2)


def _feddyn_correction(cp, w, w0, dev, srv, g):
    # grad of f_k(w) - <h_k - hbar, w> + alpha/2 ||w - w_t||^2.  Textbook
    # FedDyn applies its server correction state hbar = mean_k h_k on the
    # server (w <- mean_k theta_k - hbar/alpha); the paper's eq.-11 step
    # w <- w - eta y has no slot for that shift, so hbar re-enters the LOCAL
    # objective as the linear tilt +<hbar, w> instead — the gradient form of
    # the same correction.  On the air the -h_k + hbar pair cancels
    # participant bias (mean over a full cohort of the corrected deltas is
    # the raw-gradient mean), while h_k's memory of absent clients persists
    # in hbar under partial participation.  Without the tilt (-h_k alone)
    # the aggregate keeps mean_k h_k — client-gradient memory at stale
    # iterates — inside every round, and feddyn trails plain sgd at every
    # alpha.
    return _tmap(
        lambda gl, wl, w0l, hl, sl: gl + cp.alpha * (wl - w0l) - hl + sl,
        g, w, w0, dev, srv)


def _feddyn_update(cp, hlr, dev, srv, delta):
    # h_k <- h_k - alpha (w_k^H - w_t) = h_k + alpha * H * local_lr * delta
    # (delta is the round's model delta (w_t - w_k^H)/(H local_lr))
    return _tmap(lambda hl, dl: hl + cp.alpha * hlr * dl, dev, delta)


register(ClientAlgorithm(
    name="feddyn",
    doc="dynamic regularization (FedDyn): per-client gradient-correction "
        "state h_k, local gradient g + alpha (w - w_t) - h_k + hbar; the "
        "refreshed h_k ride a second OTA slot to teach the server hbar",
    uses_alpha=True,
    has_state=True,
    has_server_state=True,
    num_slots=2,
    correction=_feddyn_correction,
    update_state=_feddyn_update,
    variate_stat=_variate_refreshed,
    apply_variate=_apply_tracking,
))


def _scaffold_update(cp, hlr, dev, srv, delta):
    # option-II variate refresh: c_k^+ = c_k - c + (w_t - w_k^H)/(H lr)
    # (srv leaves broadcast against the stacked [K, ...] dev leaves)
    return _tmap(lambda ck, cl, dl: ck - cl + dl, dev, srv, delta)


register(ClientAlgorithm(
    name="scaffold",
    doc="control variates (SCAFFOLD): local gradient g - c_k + c; the "
        "refreshed variates ride a second OTA slot and the server variate "
        "c is learned from its de-gained aggregate",
    has_state=True,
    has_server_state=True,
    num_slots=2,
    correction=lambda cp, w, w0, dev, srv, g: _tmap(
        lambda gl, ck, cl: gl - ck + cl, g, dev, srv),
    update_state=_scaffold_update,
    variate_stat=_variate_refreshed,
    apply_variate=_apply_tracking,
))
