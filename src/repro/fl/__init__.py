"""The declarative experiment-facing API: spec -> compiled run.

    from repro.fl import DataSpec, Experiment, ExperimentSpec, FLConfig

    spec = ExperimentSpec(fl=FLConfig(scheme="normalized", case="II"),
                          data=DataSpec(dataset="ridge"))
    Experiment(spec).run(300)

See ``repro.fl.spec`` for the spec fields and ``repro.fl.experiment`` for
the runnable object; ``repro.fed.runtime`` stays the underlying engine (and
its ``run()`` the stable compatibility wrapper for hand-wired callers).
"""
from repro.fed.runtime import FLConfig
from repro.fl.experiment import Experiment
from repro.fl.spec import (DataSpec, EvalSpec, ExperimentSpec, ModelSpec,
                           apply_axes, apply_axis, resolve_axis)
from repro.fl.sweep import SweepPoint, SweepResult, SweepSpec, run_sweep
from repro.fl.tasks import Task, build_task

__all__ = ["DataSpec", "EvalSpec", "Experiment", "ExperimentSpec",
           "FLConfig", "ModelSpec", "SweepPoint", "SweepResult", "SweepSpec",
           "Task", "apply_axes", "apply_axis", "build_task", "resolve_axis",
           "run_sweep"]
