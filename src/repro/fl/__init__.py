"""The declarative experiment-facing API: spec -> compiled run.

    from repro.fl import DataSpec, Experiment, ExperimentSpec, FLConfig

    spec = ExperimentSpec(fl=FLConfig(scheme="normalized", case="II"),
                          data=DataSpec(dataset="ridge"))
    Experiment(spec).run(300)

See ``repro.fl.spec`` for the spec fields and ``repro.fl.experiment`` for
the runnable object; ``repro.fed.runtime`` stays the underlying engine (and
its ``run()`` the stable compatibility wrapper for hand-wired callers).

Exports resolve lazily (PEP 562): ``repro.fl.clients`` is imported by the
runtime itself (the client-algorithm registry is engine-level, like
``repro.core.schemes``), so this package must be importable while
``repro.fed.runtime`` is still initializing — an eager ``from
repro.fed.runtime import FLConfig`` here would close that cycle.
"""
from typing import Any

_EXPORTS = {
    "FLConfig": ("repro.fed.runtime", "FLConfig"),
    "ClientConfig": ("repro.fl.clients", "ClientConfig"),
    "Experiment": ("repro.fl.experiment", "Experiment"),
    "DataSpec": ("repro.fl.spec", "DataSpec"),
    "EvalSpec": ("repro.fl.spec", "EvalSpec"),
    "ExperimentSpec": ("repro.fl.spec", "ExperimentSpec"),
    "ModelSpec": ("repro.fl.spec", "ModelSpec"),
    "apply_axes": ("repro.fl.spec", "apply_axes"),
    "apply_axis": ("repro.fl.spec", "apply_axis"),
    "resolve_axis": ("repro.fl.spec", "resolve_axis"),
    "SweepPoint": ("repro.fl.sweep", "SweepPoint"),
    "SweepResult": ("repro.fl.sweep", "SweepResult"),
    "SweepSpec": ("repro.fl.sweep", "SweepSpec"),
    "run_sweep": ("repro.fl.sweep", "run_sweep"),
    "Task": ("repro.fl.tasks", "Task"),
    "build_task": ("repro.fl.tasks", "build_task"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str) -> Any:
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value      # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
