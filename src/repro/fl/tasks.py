"""Task construction for the declarative API: ``DataSpec`` + ``ModelSpec``
-> one ``Task`` bundling everything the FL runtime needs (initial params,
model dimension, grad_fn, per-round and per-chunk batch providers, eval_fn)
plus task constants (ridge L/M/f*, the federated split).

Tasks are cached on the (frozen, hashable) specs, so two ``Experiment``
instances with equal specs share one ``Task`` object — same data arrays AND
the same ``grad_fn`` identity, which keeps the runtime's compiled
round/chunk executables (lru-cached on ``(FLConfig, grad_fn)``) hot across
sweeps, resumes, and benchmark repeats.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.datasets import (device_batches, device_batches_many,
                                 ridge_data, split_dirichlet, split_iid,
                                 synthetic_mnist)
from repro.fl.spec import DataSpec, ModelSpec
from repro.models.simple import (init_mlp_classifier, init_ridge,
                                 mlp_classifier_accuracy, mlp_classifier_loss,
                                 ridge_constants, ridge_loss, ridge_optimum)

PyTree = Any

# key derivation from DataSpec.seed: one root key; fold_in(1) = split,
# fold_in(2) = params init, PRNGKey(seed + 3) = per-round batch sampling.
# (Same shape as the historical hand-wiring, which used root PRNGKey(seed)
# but a FIXED PRNGKey(3) provider — here every stream derives from the one
# spec seed, so two seeds never share a batch sequence.)
_SPLIT_FOLD = 1
_INIT_FOLD = 2
_PROVIDER_OFFSET = 3


@dataclasses.dataclass
class Task:
    """Everything ``repro.fed.runtime.run`` needs, built once per spec."""

    params0: PyTree
    model_dim: int
    grad_fn: Callable[[PyTree, Any], PyTree]
    batch_provider: Callable[[int], Any]
    chunk_batch_provider: Callable[[Sequence[int]], Any]
    eval_fn: Callable[[PyTree], Dict[str, float]]
    constants: Dict[str, Any]


def _make_split(key, data: DataSpec, labels, num_devices: int):
    if data.split == "iid":
        return split_iid(key, data.num_train, num_devices)
    return split_dirichlet(key, np.asarray(labels[:data.num_train]),
                           num_devices, data.alpha)


def _providers(data: DataSpec, split):
    """Index-batch providers: a round's batch is the [K, B] example-INDEX
    pytree ``(idx,)``; the paired task ``grad_fn`` gathers the rows from the
    resident training arrays inside the trace.  The scan engine's chunk xs
    is then [T, K, B] int32 (a few hundred KB) instead of the gathered
    [T, K, B, features] floats (tens of MB at chunk 48): the host-side fancy
    index + transfer disappears and each scan iteration slices indices, not
    feature rows — the gather fuses into the gradient computation.  Gathers
    are exact, so the trajectory is BITWISE identical to the historical
    gathered-array providers."""
    pkey = jax.random.PRNGKey(data.seed + _PROVIDER_OFFSET)

    def provider(t):
        return (jnp.asarray(device_batches(pkey, split, data.batch_size, t)),)

    def provider_chunk(ts):
        return (jnp.asarray(
            device_batches_many(pkey, split, data.batch_size, ts)),)

    return provider, provider_chunk


def _model_dim(params) -> int:
    return sum(int(np.prod(np.asarray(l).shape))
               for l in jax.tree_util.tree_leaves(params))


def _build_mnist_task(data: DataSpec, model: ModelSpec,
                      num_devices: int) -> Task:
    key = jax.random.PRNGKey(data.seed)
    x, y = synthetic_mnist(key, data.num_train + data.num_test)
    x_tr, y_tr = x[:data.num_train], y[:data.num_train]
    x_te, y_te = x[data.num_train:], y[data.num_train:]
    split = _make_split(jax.random.fold_in(key, _SPLIT_FOLD), data, y,
                        num_devices)
    params0 = init_mlp_classifier(jax.random.fold_in(key, _INIT_FOLD),
                                  hidden=model.hidden)
    xd, yd = jnp.asarray(x_tr), jnp.asarray(y_tr)

    def grad_fn(params, batch):
        (idx,) = batch
        xb, yb = xd[idx], yd[idx]
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def eval_fn(params):
        return {
            "test_acc": float(mlp_classifier_accuracy(params, x_te, y_te)),
            "train_loss": float(mlp_classifier_loss(params, x_tr, y_tr)),
        }

    provider, provider_chunk = _providers(data, split)
    return Task(params0, _model_dim(params0), grad_fn, provider,
                provider_chunk, eval_fn, {"split": split})


def _build_ridge_task(data: DataSpec, model: ModelSpec,
                      num_devices: int) -> Task:
    key = jax.random.PRNGKey(data.seed)
    x, y, _ = ridge_data(key, data.num_train, data.dim)
    lam = model.lam
    L, M, _ = ridge_constants(x, lam)
    w_star = ridge_optimum(x, y, lam)
    f_star = float(ridge_loss({"w": w_star}, x, y, lam))
    split = _make_split(jax.random.fold_in(key, _SPLIT_FOLD), data, None,
                        num_devices)
    params0 = init_ridge(jax.random.fold_in(key, _INIT_FOLD), data.dim)
    xd, yd = jnp.asarray(x), jnp.asarray(y)

    def grad_fn(params, batch):
        (idx,) = batch
        xb, yb = xd[idx], yd[idx]
        return jax.grad(lambda p: ridge_loss(p, xb, yb, lam))(params)

    def eval_fn(params):
        loss = float(ridge_loss(params, x, y, lam))
        return {"loss": loss, "gap": loss - f_star}

    provider, provider_chunk = _providers(data, split)
    return Task(params0, data.dim, grad_fn, provider, provider_chunk,
                eval_fn, {"split": split, "smoothness_L": L,
                          "strong_convexity_M": M, "f_star": f_star,
                          "x": x, "y": y})


# Like the engine's executable caches (repro.fed.runtime), the task cache is
# sized for sweeps: a grid over data/model axes walks one entry per distinct
# (data, model, K) triple, and an eviction drops the shared arrays AND the
# grad_fn identity the compiled-executable caches key on.
TASK_CACHE_SIZE = int(os.environ.get("REPRO_TASK_CACHE_SIZE", "32"))


def task_cache_info() -> Dict[str, int]:
    """``lru_cache`` statistics of ``build_task`` (hits mean shared arrays
    and hot compiled executables across experiments/sweeps)."""
    return build_task.cache_info()._asdict()


@functools.lru_cache(maxsize=TASK_CACHE_SIZE)
def build_task(data: DataSpec, model: ModelSpec, num_devices: int) -> Task:
    """Build (or fetch the cached) ``Task`` for a data/model spec pair.

    ``dirichlet`` splits of the ridge task fall back to IID (the task has no
    labels to skew by) — normalized by recursing through the cache, so the
    dirichlet- and iid-keyed ridge specs share ONE Task (and therefore one
    ``grad_fn`` identity for the engine's compiled-executable cache); the
    MLP task honors both split kinds.
    """
    kind = model.resolve(data.dataset)
    if data.dataset == "synthetic_mnist":
        if kind != "mlp":
            raise ValueError(f"model kind {kind!r} cannot train on "
                             "synthetic_mnist (use 'mlp' or 'auto')")
        return _build_mnist_task(data, model, num_devices)
    if kind != "ridge":
        raise ValueError(f"model kind {kind!r} cannot train on the ridge "
                         "task (use 'ridge' or 'auto')")
    if data.split == "dirichlet":
        return build_task(dataclasses.replace(data, split="iid"), model,
                          num_devices)
    return _build_ridge_task(data, model, num_devices)
