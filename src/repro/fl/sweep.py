"""The vectorized sweep engine's declarative front door: expand an
``ExperimentSpec`` over named axes, classify each axis as *batchable*
(numeric — traced into one compiled program as a per-experiment input) or
*structural* (changes the traced program — compiled once per sub-batch),
and run the whole grid in as few dispatches as the structure allows.

    sweep = SweepSpec(base_spec, {"s_target": (0.98, 0.99, 0.995),
                                  "seed": (0, 1, 2, 3)})
    result = run_sweep(sweep, num_rounds=400)
    mean, std = result.band("gap", over="seed")     # [3, num_evals]

Axis names address the nested spec through one flat namespace
(``repro.fl.spec.resolve_axis``): bare field names ("seed", "noise_var",
"scheme", "alpha") or dotted scopes ("fl.seed", "data.seed").  Which fields
are batchable is owned by the runtime (``repro.fed.runtime
.BATCHED_FL_FIELDS`` / ``BATCHED_CHANNEL_FIELDS``): they are either consumed
by host-side ``setup`` (folded into the stacked per-experiment channel
state) or threaded through the compiled program as traced scalars — the
wireless-environment lanes ``channel.rho`` (AR(1) correlation) and
``channel.csi_error`` (imperfect CSI) included.  Everything else — scheme,
case, backend, amplification policy, scenario axes, the channel *model* /
geometry / Rician K-factor, any data/model field — is structural.

Grid points are grouped by *structural signature* (``runtime
.structural_config`` of the effective config + the data/model specs); each
group becomes ONE ``runtime.run_batched`` call — a single ``jax.vmap``-ed
``lax.scan`` program whose experiment axis is sharded across local devices
when a mesh is available.  Groups with equal data/model specs share one
lru-cached ``Task`` (same arrays AND ``grad_fn`` identity), so compiled
executables stay hot across groups and repeated sweeps.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fed import runtime
from repro.fl import clients
from repro.fl.experiment import Experiment
from repro.fl.spec import (ExperimentSpec, apply_axes, apply_axis,
                           resolve_axis)
from repro.fl.tasks import build_task

BATCHABLE = "batchable"
STRUCTURAL = "structural"


def classify_field(name: str) -> str:
    """``batchable`` or ``structural`` for one resolved spec field."""
    scope, field = resolve_axis(name)
    if scope == "fl" and field in runtime.BATCHED_FL_FIELDS:
        return BATCHABLE
    if scope == "channel" and field in runtime.BATCHED_CHANNEL_FIELDS:
        return BATCHABLE
    if scope == "client" and field in clients.BATCHED_CLIENT_FIELDS:
        return BATCHABLE
    return STRUCTURAL


def _is_composite(value: Any) -> bool:
    """Composite axis values bundle several field assignments under one
    label: ``("caseI", {"case": "I", "p": 0.75})``."""
    return (isinstance(value, tuple) and len(value) == 2
            and isinstance(value[0], str) and isinstance(value[1], Mapping))


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One grid point: its N-D index, its coordinates (axis name -> value,
    composite axes contribute their label), and the fully-applied spec."""

    index: Tuple[int, ...]
    coords: Tuple[Tuple[str, Any], ...]
    spec: ExperimentSpec


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A base ``ExperimentSpec`` plus named axes (mapping or sequence of
    ``(name, values)`` pairs; values in declaration order define the grid's
    C-order).  Axis values are field values, or ``(label, mapping)``
    composites assigning several fields at once (classified batchable only
    if every constituent field is)."""

    base: ExperimentSpec
    axes: Any

    def __post_init__(self):
        items = (tuple((k, tuple(v)) for k, v in self.axes.items())
                 if isinstance(self.axes, Mapping)
                 else tuple((k, tuple(v)) for k, v in self.axes))
        object.__setattr__(self, "axes", items)
        seen = set()
        for name, values in items:
            if name in seen:
                raise ValueError(f"duplicate sweep axis {name!r}")
            seen.add(name)
            if not values:
                raise ValueError(f"sweep axis {name!r} has no values")
            composite = [_is_composite(v) for v in values]
            if any(composite) and not all(composite):
                raise ValueError(
                    f"axis {name!r} mixes composite (label, mapping) values "
                    "with plain values")
            if all(composite):
                for _, mapping in values:
                    for field in mapping:
                        resolve_axis(field)
            else:
                resolve_axis(name)
        # expand ONCE — validates every grid point at declaration time, and
        # points()/run_sweep reuse the expansion (a thousand-point grid is
        # thousands of chained dataclasses.replace calls)
        object.__setattr__(self, "_points", tuple(self._expand()))

    # ----------------------------------------------------------- geometry

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(len(values) for _, values in self.axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.axes else 1

    def values(self, name: str) -> Tuple[Any, ...]:
        """The coordinate values of one axis (labels for composites)."""
        for axis, vals in self.axes:
            if axis == name:
                return tuple(v[0] if _is_composite(v) else v for v in vals)
        raise ValueError(f"no sweep axis named {name!r}; one of {self.names}")

    # ----------------------------------------------------- classification

    def classification(self) -> Dict[str, str]:
        """axis name -> ``batchable`` | ``structural``.  A batchable axis
        multiplies lanes of one compiled program; a structural axis
        multiplies compiled sub-batches."""
        out = {}
        for name, values in self.axes:
            if _is_composite(values[0]):
                fields = set()
                for _, mapping in values:
                    fields.update(mapping)
                out[name] = (BATCHABLE if all(classify_field(f) == BATCHABLE
                                              for f in fields)
                             else STRUCTURAL)
            else:
                out[name] = classify_field(name)
        return out

    # ----------------------------------------------------------- expansion

    def points(self) -> List[SweepPoint]:
        """The full grid in C-order (last axis fastest), expanded once at
        declaration time (every spec validated by its dataclass
        constructors)."""
        return list(self._points)

    def _expand(self) -> List[SweepPoint]:
        if not self.axes:
            return [SweepPoint((), (), self.base)]
        pts = []
        ranges = [range(len(values)) for _, values in self.axes]
        for index in itertools.product(*ranges):
            spec = self.base
            coords = []
            for (name, values), i in zip(self.axes, index):
                value = values[i]
                if _is_composite(value):
                    label, mapping = value
                    spec = apply_axes(spec, mapping)
                    coords.append((name, label))
                else:
                    spec = apply_axis(spec, name, value)
                    coords.append((name, value))
            pts.append(SweepPoint(tuple(index), tuple(coords), spec))
        return pts


@dataclasses.dataclass
class SweepResult:
    """Per-experiment histories of a sweep, flat over the grid.

    ``history[key]`` is ``[G, T]`` for the runtime's ``DIAG_KEYS`` and
    ``[G, num_evals]`` for eval metrics, where G = grid size in the C-order
    of ``points``; ``rounds`` / ``eval_rounds`` are shared by every point
    (the sweep engine aligns eval chunk boundaries across the whole grid).
    """

    sweep: SweepSpec
    num_rounds: int
    rounds: List[int]
    eval_rounds: List[int]
    history: Dict[str, np.ndarray]
    points: List[SweepPoint]
    # per-point final-params digests in grid C-order (repro.obs.params_sha256
    # of each trajectory's end state) — the sweep-level bitwise fingerprint
    params_digests: Optional[List[str]] = None

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.sweep.shape

    def grid(self, key: str) -> np.ndarray:
        """``history[key]`` reshaped to the grid: [*axis lengths, T]."""
        arr = self.history[key]
        return arr.reshape(self.shape + arr.shape[1:])

    def band(self, key: str, over: str = "seed") -> Tuple[np.ndarray,
                                                          np.ndarray]:
        """(mean, std) of ``history[key]`` reduced over one named axis —
        the seed-replicate error band of the figure benchmarks.  The
        returned arrays keep the remaining grid axes."""
        if over not in self.sweep.names:
            raise ValueError(f"no sweep axis named {over!r}; one of "
                             f"{self.sweep.names}")
        axis = self.sweep.names.index(over)
        g = self.grid(key)
        return g.mean(axis=axis), g.std(axis=axis)

    def point_index(self, **coords) -> int:
        """Flat index of the unique point matching the given coordinate
        values (every axis must be pinned)."""
        if set(coords) != set(self.sweep.names):
            raise ValueError(f"pin every axis {self.sweep.names}, got "
                             f"{tuple(coords)}")
        index = []
        for name in self.sweep.names:
            values = self.sweep.values(name)
            if coords[name] not in values:
                raise ValueError(f"{coords[name]!r} is not a value of axis "
                                 f"{name!r} ({values})")
            index.append(values.index(coords[name]))
        return int(np.ravel_multi_index(tuple(index), self.shape))

    # ---------------------------------------------------------- observability

    def params_sha256(self) -> Optional[str]:
        """One combined digest of the whole grid's final params: sha-256
        over the per-point digests in C-order (None when the run predates
        digesting)."""
        if not self.params_digests:
            return None
        h = hashlib.sha256()
        for d in self.params_digests:
            h.update(d.encode())
        return h.hexdigest()

    def curves(self, axis: str, metric: str, over: str = "seed",
               ) -> Dict[str, Dict[str, Any]]:
        """The figure benchmarks' curve payload for an (``axis`` x ``over``)
        sweep: one entry per ``axis`` value with the eval rounds, the
        ``metric`` mean across the ``over`` replicates, its std error band,
        and the replicate count."""
        mean, std = self.band(metric, over=over)
        n_over = len(self.sweep.values(over))
        out: Dict[str, Dict[str, Any]] = {}
        for i, value in enumerate(self.sweep.values(axis)):
            out[str(value)] = {
                "round": list(self.eval_rounds),
                metric: np.asarray(mean[i]).tolist(),
                f"{metric}_std": np.asarray(std[i]).tolist(),
                "seeds": n_over,
            }
        return out

    def manifest(self) -> Dict[str, Any]:
        """The sweep's run manifest: the base spec's identity block plus the
        grid geometry and the combined final-params digest."""
        return obs.run_manifest(
            spec=self.sweep.base, params_digest=self.params_sha256(),
            extra={
                "num_rounds": int(self.num_rounds),
                "sweep_axes": {name: [str(v) for v in self.sweep.values(name)]
                               for name in self.sweep.names},
                "sweep_shape": list(self.shape),
                "axis_classification": self.sweep.classification(),
            })

    def dump(self, path: str, over: Optional[str] = "seed") -> str:
        """Write the full result as one self-describing JSON file: manifest,
        grid geometry, per-point histories, and — when ``over`` names a swept
        axis — the ``band()`` mean/std summaries for every history key.
        This is the one sweep-serialization path (the figure benchmarks'
        hand-rolled payload assembly routes through ``curves``/here)."""
        payload: Dict[str, Any] = {
            "manifest": self.manifest(),
            "num_rounds": int(self.num_rounds),
            "rounds": [int(t) for t in self.rounds],
            "eval_rounds": [int(t) for t in self.eval_rounds],
            "axes": {name: [str(v) for v in self.sweep.values(name)]
                     for name in self.sweep.names},
            "shape": list(self.shape),
            "history": {k: np.asarray(v).tolist()
                        for k, v in self.history.items()},
        }
        if self.params_digests:
            payload["params_digests"] = list(self.params_digests)
        if over is not None and over in self.sweep.names:
            payload["bands"] = {
                k: {"over": over,
                    "mean": self.band(k, over=over)[0].tolist(),
                    "std": self.band(k, over=over)[1].tolist()}
                for k in self.history}
        with open(path, "w") as f:
            json.dump(payload, f, default=str)
        return path


def _structural_signature(spec: ExperimentSpec):
    """Hashable key under which grid points may share one compiled batched
    program: the runtime's structural config plus everything that shapes the
    task (data/model specs drive arrays, ``grad_fn``, and eval metrics)."""
    return (runtime.structural_config(spec.fl_config()), spec.data,
            spec.model)


def _run_group_sequential(specs, task, num_rounds, evaluate, eval_every,
                          recorder=None):
    """Per-point fallback (mesh backend / python driver, or
    ``vectorized=False`` — the benchmark's sequential baseline): N truly
    independent ``Experiment.run`` trajectories (sharing the group's cached
    ``Task``) assembled into the batched history layout.  Returns
    ``(hist, digests)`` — the stacked history plus one final-params digest
    per point."""
    rows, digests = [], []
    for spec in specs:
        e = Experiment(spec, task=task)
        rows.append(e.run(num_rounds, evaluate=evaluate,
                          eval_every=eval_every, recorder=recorder))
        digests.append(obs.params_sha256(e.state.params))
    out: Dict[str, Any] = {"round": rows[0]["round"],
                           "eval_round": rows[0]["eval_round"]}
    for key in rows[0]:
        if key not in out:
            out[key] = np.stack([np.asarray(r[key], np.float64)
                                 for r in rows])
    return out, digests


def run_sweep(sweep: SweepSpec, num_rounds: int, *, vectorized: bool = True,
              shard: bool = True, evaluate: Optional[bool] = None,
              recorder: Optional[obs.Recorder] = None) -> SweepResult:
    """Run every grid point of ``sweep`` for ``num_rounds`` rounds.

    Points are grouped by structural signature; each group runs as ONE
    compiled batched program (``runtime.run_batched``), its experiment axis
    sharded across local devices when available.  ``vectorized=False``
    forces the per-point sequential path for every group (the baseline the
    ``sweep`` benchmark compares against); the mesh backend, sharded
    streaming (``device_mesh > 1``), and the ``python`` driver always take
    the sequential path (the mesh's device axis belongs to the FL devices;
    the python driver is a host loop).

    Eval scheduling comes from ``sweep.base.eval`` (``evaluate`` overrides
    the enable switch) and is identical for every point, so histories align
    across the grid.  All groups must produce the same eval-metric key set —
    a sweep spanning tasks with different metrics should be split.

    ``recorder`` streams every group's engine events through one shared
    sink (manifest emitted once up front); the result's ``params_digests``
    carry each point's final-params fingerprint regardless.
    """
    pts = sweep.points()
    base = sweep.base
    enabled = base.eval.enabled if evaluate is None else evaluate
    eval_every = base.eval.every
    # the python driver is the per-round host loop — inherently sequential
    vectorized = vectorized and base.driver == "scan"

    groups: Dict[Any, List[int]] = {}
    for i, pt in enumerate(pts):
        groups.setdefault(_structural_signature(pt.spec), []).append(i)

    if recorder is not None:
        # the grid's identity block up front (per-point digests land on the
        # SweepResult once the trajectories exist)
        recorder.on_manifest(obs.run_manifest(spec=base, extra={
            "num_rounds": int(num_rounds),
            "sweep_axes": {name: [str(v) for v in sweep.values(name)]
                           for name in sweep.names},
            "sweep_shape": list(sweep.shape)}))

    flat: Dict[str, np.ndarray] = {}
    digests: List[Optional[str]] = [None] * len(pts)
    rounds: Optional[List[int]] = None
    eval_rounds: Optional[List[int]] = None
    metric_keys: Optional[frozenset] = None
    for idxs in groups.values():
        gspecs = [pts[i].spec for i in idxs]
        cfgs = [s.fl_config() for s in gspecs]
        task = build_task(gspecs[0].data, gspecs[0].model,
                          cfgs[0].num_devices)
        # device_mesh groups fall back to sequential like the mesh backend:
        # the local devices belong to the FL-device axis (run_batched rejects
        # the combination with the same rationale)
        if (vectorized and cfgs[0].backend != "mesh"
                and (cfgs[0].device_mesh is None or cfgs[0].device_mesh <= 1)):
            states = [runtime.setup(cfg, task.params0, task.model_dim)
                      for cfg in cfgs]
            _, hist = runtime.run_batched(
                cfgs, states, task.grad_fn, task.batch_provider, num_rounds,
                eval_fn=task.eval_fn if enabled else None,
                eval_every=eval_every, chunk_size=base.chunk_size,
                chunk_batch_provider=task.chunk_batch_provider, shard=shard,
                recorder=recorder)
            gdigests = [obs.params_sha256(s.params) for s in states]
        else:
            hist, gdigests = _run_group_sequential(
                gspecs, task, num_rounds, enabled, eval_every,
                recorder=recorder)
        for i, d in zip(idxs, gdigests):
            digests[i] = d
        keys = frozenset(k for k in hist if k not in ("round", "eval_round"))
        if rounds is None:
            rounds, eval_rounds = list(hist["round"]), list(hist["eval_round"])
            metric_keys = keys
        elif keys != metric_keys:
            raise ValueError(
                "sweep groups disagree on history keys "
                f"({sorted(keys ^ metric_keys)} differ) — split a sweep "
                "that spans tasks with different eval metrics")
        for key in keys:
            arr = np.asarray(hist[key], np.float64)
            buf = flat.get(key)
            if buf is None:
                buf = np.zeros((len(pts),) + arr.shape[1:])
                flat[key] = buf
            buf[idxs] = arr
    return SweepResult(sweep=sweep, num_rounds=num_rounds, rounds=rounds,
                       eval_rounds=eval_rounds, history=flat, points=pts,
                       params_digests=digests)
