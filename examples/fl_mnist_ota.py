"""End-to-end driver (paper Case I): federated 10-digit classification with
over-the-air normalized-gradient aggregation — a few hundred rounds, all
aggregation schemes, with checkpointing.  Rounds run on the compiled
``lax.scan`` engine by default (``--driver python`` for the host loop).

    PYTHONPATH=src python examples/fl_mnist_ota.py [--rounds 300] [--scheme all]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import CaseIExperiment
from repro.checkpoint import store


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--scheme", default="all",
                    help="normalized|benchmark1|benchmark2|onebit|mean|all")
    ap.add_argument("--driver", default="scan", choices=("scan", "python"),
                    help="round-loop driver: the compiled lax.scan engine "
                         "(default) or the per-round host loop")
    ap.add_argument("--ckpt-dir", default="results/ckpt_mnist")
    args = ap.parse_args()

    from benchmarks import common
    common.DEFAULT_DRIVER = args.driver
    exp = CaseIExperiment()
    print(f"K=20 devices, non-IID Dirichlet split, model dim {exp.dim}, "
          f"calibrated G = {exp.calibrate_G():.2f}")

    schemes = (["normalized", "benchmark1", "benchmark2", "onebit"]
               if args.scheme == "all" else [args.scheme])
    for scheme in schemes:
        cfg = exp.config(scheme=scheme)
        state, hist = exp.run(cfg, args.rounds,
                              eval_every=max(1, args.rounds // 10))
        accs = ", ".join(f"{t}:{a:.3f}" for t, a in
                         zip(hist["eval_round"], hist["test_acc"]))
        print(f"[{scheme:12s}] test acc over rounds: {accs}")
        path = store.save_round(os.path.join(args.ckpt_dir, scheme),
                                args.rounds, state.params,
                                {"scheme": scheme,
                                 "final_acc": hist["test_acc"][-1]})
        restored, meta = store.restore(path, state.params)
        print(f"             checkpoint -> {path} (acc {meta['final_acc']:.3f})")


if __name__ == "__main__":
    main()
