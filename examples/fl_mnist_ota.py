"""End-to-end driver (paper Case I): federated 10-digit classification with
over-the-air normalized-gradient aggregation — a few hundred rounds, all
aggregation schemes, with resumable ``Experiment`` checkpoints.  Rounds run
on the compiled ``lax.scan`` engine by default (``--driver python`` for the
host loop).

    PYTHONPATH=src python examples/fl_mnist_ota.py [--rounds 300] [--scheme all]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.fl import Experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--scheme", default="all",
                    help="normalized|benchmark1|benchmark2|onebit|mean|all")
    ap.add_argument("--driver", default="scan", choices=("scan", "python"),
                    help="round-loop driver: the compiled lax.scan engine "
                         "(default) or the per-round host loop")
    ap.add_argument("--ckpt-dir", default="results/ckpt_mnist")
    args = ap.parse_args()

    from benchmarks import common
    from benchmarks.common import CaseIExperiment
    common.DEFAULT_DRIVER = args.driver
    exp = CaseIExperiment()
    print(f"K=20 devices, non-IID Dirichlet split, model dim {exp.dim}, "
          f"calibrated G = {exp.calibrate_G():.2f}")

    schemes = (["normalized", "benchmark1", "benchmark2", "onebit"]
               if args.scheme == "all" else [args.scheme])
    for scheme in schemes:
        cfg = exp.config(scheme=scheme)
        e = exp.experiment(cfg, eval_every=max(1, args.rounds // 10))
        e.run(args.rounds)
        hist = e.history
        accs = ", ".join(f"{t}:{a:.3f}" for t, a in
                         zip(hist["eval_round"], hist["test_acc"]))
        print(f"[{scheme:12s}] test acc over rounds: {accs}")
        # full resumable checkpoint: params + server-opt state + channel/round
        os.makedirs(args.ckpt_dir, exist_ok=True)
        path = e.save(os.path.join(args.ckpt_dir, f"{scheme}.msgpack"))
        resumed = Experiment(e.spec).load(path)
        assert resumed.round == args.rounds
        print(f"             checkpoint -> {path} "
              f"(resumes at round {resumed.round})")


if __name__ == "__main__":
    main()
