"""The paper's technique at framework scale: OTA normalized-gradient
aggregation as the data-parallel collective of a *transformer* LM train step
on a JAX device mesh — the same code path the 256/512-chip dry-run lowers,
executed for real on forced host devices.

Each of the 4 data shards is one FL "mobile device" with its own data shard;
the gradient all-reduce is the over-the-air superposition (ota_psum).

    PYTHONPATH=src python examples/ota_transformer_fl.py [--steps 30]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, reduce_config
from repro.core import amplification as amp
from repro.core.channel import ChannelConfig, draw_channel
from repro.data.datasets import token_stream
from repro.launch import train as train_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.optimizers import sgd, inverse_power_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--scheme", default="normalized")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    mesh = make_host_mesh(4, 2)   # 4 FL clients x 2-way tensor parallel
    k_clients = mesh.shape["data"]
    cfg = reduce_config(get_config(args.arch), seq_len=args.seq)
    print(f"mesh {dict(mesh.shape)}; arch {cfg.name}; "
          f"params ~{cfg.param_count()/1e6:.1f}M; scheme {args.scheme}")

    # the paper's channel + Algorithm 1
    chan = ChannelConfig(num_devices=k_clients, channel_mean=1e-3)
    h = np.asarray(draw_channel(jax.random.PRNGKey(0), chan))
    sol = amp.solve_problem3(h, chan.noise_var, cfg.param_count(), chan.b_max)
    ota = train_lib.OTARunParams(h=h, b=sol.b,
                                 a=1.0 / float(np.sum(h * sol.b)),
                                 noise_var=chan.noise_var,
                                 grad_bound=5.0)
    print(f"Problem 3 -> Z={sol.Z:.3f}, a={ota.a:.1f}")

    params = T.init_params(cfg, jax.random.PRNGKey(1))
    opt = sgd(inverse_power_schedule(0.75, eta0=0.5))
    opt_state = opt.init(params)
    step, in_sh = train_lib.build_train_step(
        cfg, mesh, scheme=args.scheme, aggregation_axes=("data",),
        ota=ota, optimizer=opt)

    tokens = token_stream(jax.random.PRNGKey(2), args.batch, args.seq + 1,
                          cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    ps, os_, bs = in_sh(params, opt_state, batch)
    with jax.set_mesh(mesh):
        params = jax.device_put(params, ps)
        opt_state = jax.device_put(opt_state, os_)
        batch_s = jax.device_put(batch, bs)
        jitted = jax.jit(step, in_shardings=(ps, os_, bs, NamedSharding(mesh, P())),
                         out_shardings=(ps, os_, None))
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, m = jitted(
                params, opt_state, batch_s,
                jax.random.fold_in(jax.random.PRNGKey(3), i))
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"grad_norm {float(m['grad_norm']):.3f}")
        dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.0f} ms/step on CPU-mesh simulation)")


if __name__ == "__main__":
    main()
