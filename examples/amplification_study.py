"""Study of the paper's system-parameter optimization (Sec. IV).

Sweeps channel realizations and noise levels, solving Problem 3 optimally for
each, and prints how the solution structure changes — from the waterfilling-
like interior regime (low noise: equalize h_k b_k) to the corner regime
(high noise: every b_k at its cap), plus the Case-I S* (eq. 26) and the
Case-II rate/bias frontier.

    PYTHONPATH=src python examples/amplification_study.py
"""
import math

import jax
import numpy as np

from repro.core import (case2_bias_floor, optimal_S, solve_problem3)
from repro.core.channel import ChannelConfig, draw_channel

K = 20
B_MAX = math.sqrt(5.0)


def main() -> None:
    print("=== Problem 3 solution structure vs noise level ===")
    print(f"{'n*sigma^2':>12s} {'Z':>12s} {'#b at cap':>10s} {'cv(h*b)':>10s}")
    cfg = ChannelConfig(num_devices=K, channel_mean=1e-3)
    h = np.asarray(draw_channel(jax.random.PRNGKey(0), cfg))
    for log_c in (-12, -9, -7, -5, -3):
        c = 10.0 ** log_c
        sol = solve_problem3(h, c, 1, B_MAX)     # n*sigma^2 folded into c
        at_cap = int(np.sum(sol.b > B_MAX - 1e-6))
        hb = h * sol.b
        cv = float(np.std(hb) / np.mean(hb))
        print(f"{c:12.0e} {sol.Z:12.4f} {at_cap:10d} {cv:10.4f}")
    print("\nlow noise -> interior solution equalizing h_k b_k "
          "(cv ~ 0, few at cap);\nhigh noise -> corner solution "
          "(all b_k = b_max: maximize received power).")

    print("\n=== Case-I optimal S (eq. 26) vs expected loss drop ===")
    sol = solve_problem3(h, 1e-7 * 1000, 1, B_MAX)
    for drop in (0.5, 2.0, 10.0):
        s = optimal_S(sol.Z, L=2.0, p=0.75, expected_loss_drop=drop)
        print(f"  E[F(w1)-F(wT)] = {drop:5.1f}  ->  S* = {s:8.3f} "
              f"(a = {1.0 / (s * float(np.sum(h * sol.b))):10.1f})")

    print("\n=== Case-II rate/bias frontier (Remark 2) ===")
    print(f"{'q_max=s':>8s} {'bias floor eps':>16s} {'rounds to 2*eps':>16s}")
    for s in (0.95, 0.99, 0.999):
        eps = case2_bias_floor(sol.Z, L=2.0, G=10.0, M=0.5,
                               theta_th=math.pi / 3, s=s)
        import math as m
        rounds = m.ceil(m.log(0.5) / m.log(s))  # halve the linear term
        print(f"{s:8.3f} {eps:16.4f} {rounds:16d}")
    print("\nthe tradeoff: pushing the floor down (s -> 1) slows the "
          "geometric term — choose s for your tolerance (Fig. 3(b)).")

    # --- the same study end-to-end, declaratively -------------------------
    # amplification policy is a spec field: the Fig. 2(a)-style comparison is
    # one dataclasses.replace away from the baseline spec
    import dataclasses

    from repro.fl import DataSpec, EvalSpec, Experiment, ExperimentSpec, FLConfig

    print("\n=== optimal (a, b) vs b_k = b_k^max, via ExperimentSpec ===")
    base = ExperimentSpec(
        fl=FLConfig(num_devices=K, scheme="normalized", case="II", eta=0.01,
                    channel=cfg, grad_bound=25.0, s_target=0.995),
        data=DataSpec(dataset="ridge", num_train=2000),
        eval=EvalSpec(every=100))
    for policy in ("optimal", "bmax"):
        spec = dataclasses.replace(
            base, fl=dataclasses.replace(base.fl, amplification=policy))
        e = Experiment(spec)
        e.run(200)
        print(f"  amplification={policy:8s} -> final gap "
              f"{e.history['gap'][-1]:10.5f}  (tx energy/round "
              f"{e.history['tx_energy'][-1]:8.2f})")


if __name__ == "__main__":
    main()
