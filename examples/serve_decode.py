"""Serving example: batched greedy decode of a reduced model on a device
mesh — the serve_step the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py [--arch xlstm-1.3b]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, reduce_config
from repro.launch.mesh import make_host_mesh
from repro.launch import serve as serve_lib
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    mesh = make_host_mesh(4, 2)
    cfg = reduce_config(get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    decode_step, in_sh = serve_lib.build_decode_step(cfg, mesh)
    prefill_step, pre_in_sh = serve_lib.build_prefill_cache_step(cfg, mesh, max_len)
    cache = T.init_cache(cfg, args.batch, max_len)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    tokens_like = {"tokens": prompts[:, :1], "pos": jnp.asarray(0)}
    ps, cs, bs = in_sh(params, cache, tokens_like)
    pps, pbs = pre_in_sh(params, {"tokens": prompts})
    with jax.set_mesh(mesh):
        params = jax.device_put(params, ps)
        prompts = jax.device_put(prompts, pbs["tokens"])
        from jax.sharding import NamedSharding, PartitionSpec as P
        tok_out = NamedSharding(mesh, P("data"))
        # real prefill: one forward pass writes the whole decode cache
        prefill = jax.jit(prefill_step, in_shardings=(ps, pbs),
                          out_shardings=(tok_out, cs))
        nxt, cache = prefill(params, {"tokens": prompts})
        step = jax.jit(decode_step, in_shardings=(ps, cs, bs["tokens"], bs["pos"]),
                       out_shardings=(tok_out, cs))
        generated = [nxt]
        t0 = time.time()
        for pos in range(args.prompt_len, max_len - 1):
            nxt, cache = step(params, cache, generated[-1][:, None],
                              jnp.asarray(pos))
            generated.append(nxt)
        dt = time.time() - t0
    out = jnp.stack(generated, axis=1)
    print(f"arch {cfg.name}: generated {out.shape} tokens for "
          f"{args.batch} requests")
    print(f"first request: {out[0].tolist()}")
    print(f"decode throughput {args.batch * (len(generated)-1) / dt:.1f} tok/s "
          "(CPU-mesh simulation)")


if __name__ == "__main__":
    main()
