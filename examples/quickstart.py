"""Quickstart: the paper's system in ~60 lines.

Solves the amplification optimization (Problem 3 / Algorithm 1), runs OTA
federated ridge regression with normalized-gradient aggregation (Case II),
and compares the trajectory with the theoretical bound (Lemma 2).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import case2_bound, solve_problem3, solve_problem3_jax
from repro.core.channel import ChannelConfig
from repro.data.datasets import device_batches, ridge_data, split_iid
from repro.fed.runtime import FLConfig, run, setup
from repro.models.simple import (init_ridge, ridge_constants, ridge_loss,
                                 ridge_optimum)

DIM, NEX, K, LAM = 30, 2000, 20, 0.1


def main() -> None:
    key = jax.random.PRNGKey(0)
    x, y, _ = ridge_data(key, NEX, DIM)
    L, M, _ = ridge_constants(x, LAM)
    w_star = ridge_optimum(x, y, LAM)
    f_star = float(ridge_loss({"w": w_star}, x, y, LAM))
    split = split_iid(jax.random.fold_in(key, 1), NEX, K)

    # --- the paper's parameter optimization, standalone -------------------
    chan = ChannelConfig(num_devices=K, channel_mean=1e-3)
    cfg = FLConfig(num_devices=K, scheme="normalized", case="II", eta=0.01,
                   channel=chan, grad_bound=25.0, smoothness_L=L,
                   strong_convexity_M=M, s_target=0.995)
    params0 = init_ridge(jax.random.fold_in(key, 2), DIM)
    state = setup(cfg, params0, DIM)          # draws h, solves Problem 3
    sol = solve_problem3(state.h, chan.noise_var, DIM, chan.b_max)
    print(f"Problem 3: Z = {sol.Z:.4f}  (optimal b in "
          f"[{sol.b.min():.3f}, {sol.b.max():.3f}], {sol.iterations} bisection steps)")
    sol_jax = solve_problem3_jax(jnp.asarray(state.h, jnp.float32),
                                 chan.noise_var, DIM, chan.b_max)
    print(f"jax-native Algorithm 1 (runs inside the compiled round loop): "
          f"Z = {float(sol_jax.Z):.4f}, {int(sol_jax.iterations)} bisection steps")
    print(f"receiver gain a*eta = {state.a * state.eta0:.4f}, "
          f"contraction q_max = {cfg.s_target}")

    # --- run FL rounds ------------------------------------------------------
    xnp, ynp = np.asarray(x), np.asarray(y)

    def grad_fn(params, batch):
        xb, yb = batch
        return jax.grad(lambda p: ridge_loss(p, xb, yb, LAM))(params)

    def provider(t):
        idx = device_batches(jax.random.PRNGKey(3), split, 50, t)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    def ev(params):
        return {"gap": float(ridge_loss(params, x, y, LAM)) - f_star}

    state, hist = run(cfg, state, grad_fn, provider, 300, ev, eval_every=50)

    print(f"\n{'round':>6s} {'empirical gap':>14s} {'Lemma-2 bound':>14s}")
    for t, gap in zip(hist["eval_round"], hist["gap"]):
        bound = case2_bound(t, state.eta0, state.a, state.h, state.b, L, M,
                            cfg.grad_bound, cfg.theta_th, chan.noise_var, DIM,
                            w1_dist_sq=4.0 * hist["gap"][0])
        print(f"{t:6d} {gap:14.5f} {bound:14.5f}")
    print(f"\nfinal gap {hist['gap'][-1]:.5f} (f* = {f_star:.4f}) — "
          "linear convergence to the epsilon-ball, as Lemma 2 promises.")


if __name__ == "__main__":
    main()
