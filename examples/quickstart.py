"""Quickstart: the paper's system as one declarative spec.

An ``ExperimentSpec`` names what the paper iterates on — aggregation scheme,
channel, amplification policy, learning-rate case — plus the data split and
model; ``Experiment`` compiles it into the fused OTA round loop.  This file
builds the Case-II ridge experiment (smooth + strongly convex, so Lemma 2's
linear-convergence bound is computable exactly), prints the Problem-3 /
Algorithm-1 solution it runs on, and compares the measured optimality gap
with the bound.

    PYTHONPATH=src python examples/quickstart.py

The core of it is ~15 lines:

    spec = ExperimentSpec(
        fl=FLConfig(num_devices=20, scheme="normalized", case="II",
                    eta=0.01, channel=ChannelConfig(num_devices=20,
                                                    channel_mean=1e-3),
                    grad_bound=25.0, s_target=0.995),
        data=DataSpec(dataset="ridge", num_train=2000, dim=30),
        eval=EvalSpec(every=50),
    )
    e = Experiment(spec)
    e.run(300)
    print(e.history["gap"])

Scenario axes are one-field changes on the same spec:
``dataclasses.replace(spec, server_opt='adamw')``, ``local_steps=4``, or
``participation=0.5``.
"""
import dataclasses

import jax.numpy as jnp

from repro.core import case2_bound, solve_problem3, solve_problem3_jax
from repro.core.channel import ChannelConfig
from repro.fl import (DataSpec, EvalSpec, Experiment, ExperimentSpec,
                      FLConfig, build_task)

K, DIM = 20, 30


def main() -> None:
    chan = ChannelConfig(num_devices=K, channel_mean=1e-3)
    spec = ExperimentSpec(
        fl=FLConfig(num_devices=K, scheme="normalized", case="II", eta=0.01,
                    channel=chan, grad_bound=25.0, s_target=0.995),
        data=DataSpec(dataset="ridge", num_train=2000, dim=DIM),
        eval=EvalSpec(every=50),
    )
    # the ridge task computes its exact smoothness/strong-convexity
    # constants; fold them into the spec (spec construction already
    # validated scheme/case/amplification against the registries)
    c = build_task(spec.data, spec.model, K).constants
    spec = dataclasses.replace(
        spec, fl=dataclasses.replace(spec.fl, smoothness_L=c["smoothness_L"],
                                     strong_convexity_M=c["strong_convexity_M"]))
    # setup() draws the channel and solves Problem 3 (Algorithm 1)
    e = Experiment(spec).setup()

    # --- the paper's parameter optimization, standalone -------------------
    state = e.state
    sol = solve_problem3(state.h, chan.noise_var, DIM, chan.b_max)
    print(f"Problem 3: Z = {sol.Z:.4f}  (optimal b in "
          f"[{sol.b.min():.3f}, {sol.b.max():.3f}], {sol.iterations} bisection steps)")
    sol_jax = solve_problem3_jax(jnp.asarray(state.h, jnp.float32),
                                 chan.noise_var, DIM, chan.b_max)
    print(f"jax-native Algorithm 1 (runs inside the compiled round loop): "
          f"Z = {float(sol_jax.Z):.4f}, {int(sol_jax.iterations)} bisection steps")
    print(f"receiver gain a*eta = {state.a * state.eta0:.4f}, "
          f"contraction q_max = {spec.fl.s_target}")

    # --- run FL rounds ----------------------------------------------------
    e.run(300)
    hist, cfg = e.history, e.cfg

    print(f"\n{'round':>6s} {'empirical gap':>14s} {'Lemma-2 bound':>14s}")
    for t, gap in zip(hist["eval_round"], hist["gap"]):
        bound = case2_bound(t, state.eta0, state.a, state.h, state.b,
                            c["smoothness_L"], c["strong_convexity_M"],
                            cfg.grad_bound, cfg.theta_th, chan.noise_var, DIM,
                            w1_dist_sq=4.0 * hist["gap"][0])
        print(f"{t:6d} {gap:14.5f} {bound:14.5f}")
    print(f"\nfinal gap {hist['gap'][-1]:.5f} (f* = {c['f_star']:.4f}) — "
          "linear convergence to the epsilon-ball, as Lemma 2 promises.")


if __name__ == "__main__":
    main()
