"""Regenerate the generated tables inside EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src python -m benchmarks.gen_experiments_tables
"""
from __future__ import annotations

import json
import os
import re

RESULTS = "results"
MD = "EXPERIMENTS.md"


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    return data if isinstance(data, list) else [data]


def _mem_gb(rec):
    m = re.search(r"temp_size_in_bytes=(\d+)", rec.get("roofline", {}).get(
        "memory_analysis", "") or "")
    if not m:
        return None
    args = re.search(r"argument_size_in_bytes=(\d+)",
                     rec["roofline"]["memory_analysis"])
    total = int(m.group(1)) + (int(args.group(1)) if args else 0)
    return total / 1e9


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | plan | compiles | per-chip args+temp (GB) | fits 16GB |",
            "|---|---|---|---|---|---|---|"]
    for fname in ("dryrun_singlepod.json", "dryrun_multipod.json",
                  "llama405b_mp_ota.json", "llama405b_mp_mean.json"):
        for r in load(fname):
            plan = r.get("plan", {})
            plan_s = plan.get("scheme", "")
            if plan.get("aggregation_axes"):
                plan_s += f" ota@{'x'.join(plan['aggregation_axes'])}"
            if plan.get("fsdp_axis"):
                fa = plan["fsdp_axis"]
                plan_s += f" fsdp@{fa if isinstance(fa, str) else 'x'.join(fa)}"
            if plan.get("context_parallel"):
                plan_s += " ctx-par"
            if r["status"] == "ok":
                gb = _mem_gb(r)
                gb_s = f"{gb:.1f}" if gb is not None else "?"
                fits = ("yes" if gb is not None and gb <= 16.0 else
                        "**NO**" if gb is not None else "?")
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"{plan_s} | yes ({r.get('lower_compile_s','?')}s) | "
                            f"{gb_s} | {fits} |")
            elif r["status"] == "skip":
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                            f"skip | - | ({r['skip_reason']}) |")
            else:
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"{plan_s} | **ERROR** {r.get('error','')[:60]} | - | - |")
    return "\n".join(rows)


def roofline_table() -> str:
    recs = load("analysis_singlepod.json")
    rows = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
            "bottleneck | 6ND/HLO | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|"]
    advice = {
        ("compute", "train"): "remat policy / MXU-denser attention blocks",
        ("compute", "prefill"): "flash-attention kernel block tuning",
        ("compute", "decode"): "batch growth (decode is latency-bound)",
        ("memory", "train"): "sequence-parallel activations (§Perf)",
        ("memory", "prefill"): "larger fused attention blocks, bf16 stats",
        ("memory", "decode"): "KV-cache sharding/quantization (§Perf)",
        ("collective", "train"): "bf16 OTA psum + seq-parallel RS/AG (§Perf)",
        ("collective", "prefill"): "activation resharding between TP blocks",
        ("collective", "decode"): "seq-sharded cache + select update (§Perf)",
    }
    for r in recs:
        if r["status"] == "ok":
            rf = r["roofline"]
            kind = ("train" if r["shape"].startswith("train") else
                    "prefill" if "prefill" in r["shape"] else "decode")
            rows.append(
                f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.1f} | "
                f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} | "
                f"{rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} | "
                f"{advice.get((rf['bottleneck'], kind), '')} |")
        elif r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                        f"skip: {r['skip_reason']} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                        f"ERROR {r.get('error','')[:60]} |")
    return "\n".join(rows)


def perf_log() -> str:
    recs = load("hillclimb.json")
    if not recs:
        return "(hillclimb results pending)"
    out = ["### Measured variants (unrolled depth-extrapolation; "
           "'fits:' rows use the production scanned lowering)", ""]
    by_pair = {}
    for r in recs:
        by_pair.setdefault((r["arch"], r["shape"]), []).append(r)
    for (arch, shape), variants in by_pair.items():
        out.append(f"#### {arch} × {shape}")
        out.append("| variant | compute (ms) | memory (ms) | collective (ms) "
                   "| bottleneck | vs baseline dominant term |")
        out.append("|---|---|---|---|---|---|")
        base_dom = None
        for v in variants:
            if v["status"] != "ok":
                out.append(f"| {v['variant']} | ERROR {v.get('error','')[:50]} | | | | |")
                continue
            if v["variant"].startswith("fits:"):
                m = re.search(r"temp_size_in_bytes=(\d+)",
                              v.get("roofline", {}).get("memory_analysis", ""))
                gb = f"{int(m.group(1))/1e9:.1f} GB temp/chip" if m else "?"
                out.append(f"| {v['variant']} | | | | | {gb} |")
                continue
            rf = v["roofline"]
            dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            if base_dom is None:
                base_dom = dom
                delta = "1.00x (baseline)"
            else:
                delta = f"{base_dom/dom:.2f}x better" if dom < base_dom else \
                    f"{dom/base_dom:.2f}x WORSE"
            out.append(f"| {v['variant']} | {rf['compute_s']*1e3:.1f} | "
                       f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} | "
                       f"{rf['bottleneck']} | {delta} |")
        out.append("")
    return "\n".join(out)


def main() -> None:
    with open(MD) as f:
        md = f.read()
    md = re.sub(r"<!-- DRYRUN_TABLE -->.*?(?=\n## |\Z)",
                "<!-- DRYRUN_TABLE -->\n" + dryrun_table() + "\n\n",
                md, flags=re.S)
    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
                "<!-- ROOFLINE_TABLE -->\n" + roofline_table() + "\n\n",
                md, flags=re.S)
    md = re.sub(r"<!-- PERF_LOG -->.*?(?=\n## |\Z)",
                "<!-- PERF_LOG -->\n" + perf_log() + "\n",
                md, flags=re.S)
    with open(MD, "w") as f:
        f.write(md)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
