"""Benchmark harness entry point (deliverable (d)): one function per paper
table/figure.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run             # full set
    PYTHONPATH=src python -m benchmarks.run --quick     # CI-speed subset
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced round counts (smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--backend", default=None,
                    choices=("vmap", "kernels", "mesh"),
                    help="aggregation backend for the FL figure benchmarks "
                         "(default: the fused Pallas kernel path)")
    ap.add_argument("--driver", default=None, choices=("scan", "python"),
                    help="FL round-loop driver (default: the compiled "
                         "lax.scan engine)")
    args = ap.parse_args()

    from benchmarks import common, figures
    from benchmarks.roofline_table import roofline_rows

    if args.backend:
        common.DEFAULT_BACKEND = args.backend
    if args.driver:
        common.DEFAULT_DRIVER = args.driver

    r = (lambda full, quick: quick if args.quick else full)
    # figure benchmarks run as batched sweeps with seed-replicate error
    # bands; quick mode trims rounds AND replicates
    s = r(3, 2)
    benches = [
        ("fig1a", lambda: figures.fig1a_opt_benefit(r(300, 60), s)),
        ("fig1b", lambda: figures.fig1b_benchmarks(r(300, 60), s)),
        ("fig2a", lambda: figures.fig2a_opt_benefit_ridge(r(400, 80), s)),
        ("fig2b", lambda: figures.fig2b_benchmarks_ridge(r(400, 80), s)),
        ("fig3a", lambda: figures.fig3a_case1_vs_case2(r(400, 80), s)),
        ("fig3b", lambda: figures.fig3b_tradeoff(r(600, 120), s)),
        ("grad_norm", lambda: figures.grad_norm_fluctuation(r(200, 50), s)),
        ("engine", lambda: figures.engine_rounds_per_sec(r(48, 16))),
        # the vectorized sweep engine: one compiled program for a whole
        # experiment grid vs the same grid dispatched sequentially (quick
        # keeps enough rounds that the per-run host assembly amortizes —
        # the measurement targets the engine, not the stacking)
        ("sweep", lambda: figures.sweep_rounds_per_sec(r(256, 128))),
        # the wireless-environment subsystem: in-scan channel refresh
        # overhead (fixed vs fading vs AR(1) vs AR(1)+imperfect-CSI; the
        # CSI re-solve must stay within 2x of plain fading, asserted) and
        # the CSI-robustness figure (scheme x csi_error x seed bands)
        ("channel", lambda: figures.channel_rounds_per_sec(r(256, 96))),
        # the streaming K-scale engine: a 100,000-device round (k_block
        # lax.scan superposition) vs the dense path's linear peak-RSS growth
        # — subprocess cases, flat-memory + absolute-pin guards asserted
        ("kscale", lambda: figures.kscale_flat_memory(quick=args.quick)),
        ("csi_robustness", lambda: figures.csi_robustness(r(400, 60))),
        # the client-algorithm registry: FedProx / FedDyn / SCAFFOLD vs
        # local SGD on dirichlet splits with H=4 local steps — the
        # correctors' two-slot energy ratio and the non-IID separation
        # (drift-dominated noise regime) are asserted
        ("clients", lambda: figures.client_algorithms(r(200, 60), s)),
        # the declarative spec axes: server optimizer / local steps /
        # partial participation, each one field on the baseline spec
        ("scenarios", lambda: figures.scenario_axes(r(120, 30))),
        ("roofline", roofline_rows),
    ]
    if args.only:
        keep = set(args.only.split(","))
        benches = [b for b in benches if b[0] in keep]

    # harness-level run manifest: which benchmarks ran, with which knobs, on
    # which jax/platform — makes a whole results/ directory self-describing
    # (each bench_*.json additionally embeds its own per-config manifests)
    import json
    import os

    from repro import obs

    os.makedirs(figures.RESULTS_DIR, exist_ok=True)
    with open(os.path.join(figures.RESULTS_DIR, "run_manifest.json"),
              "w") as f:
        json.dump(obs.run_manifest(extra={
            "harness": "benchmarks.run",
            "quick": args.quick,
            "benchmarks": [name for name, _ in benches],
            "backend": common.DEFAULT_BACKEND,
            "driver": common.DEFAULT_DRIVER,
        }), f, indent=2, default=str)

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the harness alive; report the failure
            print(f"{name},0,ERROR={e!r}", flush=True)
            failed.append(name)
            continue
        for row in rows:
            print(",".join(str(c) for c in row), flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        # the CI smoke relies on in-benchmark assertions (e.g. the channel
        # benchmark's 2x CSI-refresh budget) actually failing the job — a
        # swallowed error must not exit 0
        print(f"# FAILED: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
