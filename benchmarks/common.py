"""Shared experiment setup for the paper-figure benchmarks.

Two tasks, exactly as in paper Sec. V:
 * Case I — 10-class classification with the 3-FC-layer ReLU classifier
   (synthetic MNIST-like data; DESIGN.md §7), eta_t = 1/t^0.75, batch 50.
 * Case II — ridge regression (smooth + strongly convex), constant eta = 0.01.

K = 20 devices, b_k^max = sqrt(5), theta_th = pi/3.  The channel keeps the
paper's Rayleigh/noise *model*; the mean is scaled so the post-aggregation
SNR is in the trainable regime the paper's figures imply (EXPERIMENTS.md
§Faithfulness discusses the paper's literal 1e-5 / 1e-7 constants).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.data.datasets import (device_batches, device_batches_many,
                                 ridge_data, split_dirichlet, split_iid,
                                 synthetic_mnist)
from repro.fed.runtime import FLConfig, run, setup
from repro.models.simple import (init_mlp_classifier, init_ridge,
                                 mlp_classifier_accuracy, mlp_classifier_loss,
                                 ridge_constants, ridge_loss, ridge_optimum)

K = 20
CHANNEL_MEAN = 1e-3
SEED = 0

# Execution backend for the benchmark FLConfigs: the fused Pallas kernel
# path by default (the registry refactor made every scheme run on it; on
# non-TPU hosts the wrappers route to their XLA oracles, so CPU timings are
# representative).  Override with `benchmarks.run --backend`.
DEFAULT_BACKEND = "kernels"

# Round-loop driver for the benchmark runs: the compiled lax.scan engine by
# default; `benchmarks.run --driver python` times the host-loop fallback
# (the `engine` benchmark reports both and their ratio).
DEFAULT_DRIVER = "scan"


def channel(num_devices: int = K) -> ChannelConfig:
    return ChannelConfig(num_devices=num_devices, channel_mean=CHANNEL_MEAN)


# ---------------------------------------------------------------------------
# Case I: synthetic-MNIST MLP classification


class CaseIExperiment:
    def __init__(self, num_train: int = 4000, num_test: int = 1000,
                 hidden: int = 64, non_iid_alpha: float = 1.0):
        key = jax.random.PRNGKey(SEED)
        x, y = synthetic_mnist(key, num_train + num_test)
        self.x_tr, self.y_tr = x[:num_train], y[:num_train]
        self.x_te, self.y_te = x[num_train:], y[num_train:]
        self.split = split_dirichlet(jax.random.fold_in(key, 1),
                                     np.asarray(self.y_tr), K, non_iid_alpha)
        self.hidden = hidden
        self.params0 = init_mlp_classifier(jax.random.fold_in(key, 2),
                                           hidden=hidden)
        self.dim = sum(int(np.prod(np.asarray(l).shape))
                       for l in jax.tree_util.tree_leaves(self.params0))
        self._xnp, self._ynp = np.asarray(self.x_tr), np.asarray(self.y_tr)

    def grad_fn(self, params, batch):
        xb, yb = batch
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def provider(self, t, batch_size: int = 50):
        idx = device_batches(jax.random.PRNGKey(3), self.split, batch_size, t)
        return (jnp.asarray(self._xnp[idx]), jnp.asarray(self._ynp[idx]))

    def provider_chunk(self, ts, batch_size: int = 50):
        """[T, K, ...] batches for a whole scan chunk: one gather + transfer."""
        idx = device_batches_many(jax.random.PRNGKey(3), self.split,
                                  batch_size, ts)
        return (jnp.asarray(self._xnp[idx]), jnp.asarray(self._ynp[idx]))

    def eval_fn(self, params) -> Dict[str, float]:
        return {
            "test_acc": float(mlp_classifier_accuracy(params, self.x_te, self.y_te)),
            "train_loss": float(mlp_classifier_loss(params, self.x_tr, self.y_tr)),
        }

    def calibrate_G(self, rounds: int = 30) -> float:
        """Empirical max-norm bound G (the conservative constant Benchmark I
        provisions for): max per-device gradient norm over a noiseless
        mean-aggregation calibration run, x1.2 headroom."""
        if not hasattr(self, "_G"):
            cfg = FLConfig(num_devices=K, scheme="mean", case="I", p=0.75,
                           channel=channel(), seed=SEED, grad_bound=1.0,
                           smoothness_L=5.0, expected_loss_drop=2.0)
            state = setup(cfg, self.params0, self.dim)
            _, hist = run(cfg, state, self.grad_fn, self.provider, rounds)
            self._G = 1.2 * max(hist["grad_norm_max"])
        return self._G

    def config(self, scheme: str = "normalized", amplification: str = "optimal",
               **kw) -> FLConfig:
        base = dict(num_devices=K, scheme=scheme, case="I", p=0.75,
                    channel=channel(), amplification=amplification,
                    grad_bound=self.calibrate_G(), smoothness_L=5.0,
                    expected_loss_drop=2.0, seed=SEED,
                    backend=DEFAULT_BACKEND)
        base.update(kw)
        return FLConfig(**base)

    def run(self, cfg: FLConfig, rounds: int, eval_every: int = 10):
        state = setup(cfg, self.params0, self.dim)
        return run(cfg, state, self.grad_fn, self.provider, rounds,
                   self.eval_fn, eval_every, driver=DEFAULT_DRIVER,
                   chunk_batch_provider=self.provider_chunk)


# ---------------------------------------------------------------------------
# Case II: ridge regression


class CaseIIExperiment:
    def __init__(self, dim: int = 30, num_examples: int = 2000,
                 lam: float = 0.1):
        key = jax.random.PRNGKey(SEED + 10)
        self.x, self.y, _ = ridge_data(key, num_examples, dim)
        self.lam = lam
        self.L, self.M, _ = ridge_constants(self.x, lam)
        w_star = ridge_optimum(self.x, self.y, lam)
        self.f_star = float(ridge_loss({"w": w_star}, self.x, self.y, lam))
        self.split = split_iid(jax.random.fold_in(key, 1), num_examples, K)
        self.params0 = init_ridge(jax.random.fold_in(key, 2), dim)
        self.dim = dim
        self._xnp, self._ynp = np.asarray(self.x), np.asarray(self.y)

    def grad_fn(self, params, batch):
        xb, yb = batch
        return jax.grad(lambda p: ridge_loss(p, xb, yb, self.lam))(params)

    def provider(self, t, batch_size: int = 50):
        idx = device_batches(jax.random.PRNGKey(3), self.split, batch_size, t)
        return (jnp.asarray(self._xnp[idx]), jnp.asarray(self._ynp[idx]))

    def provider_chunk(self, ts, batch_size: int = 50):
        """[T, K, ...] batches for a whole scan chunk: one gather + transfer."""
        idx = device_batches_many(jax.random.PRNGKey(3), self.split,
                                  batch_size, ts)
        return (jnp.asarray(self._xnp[idx]), jnp.asarray(self._ynp[idx]))

    def eval_fn(self, params) -> Dict[str, float]:
        return {"loss": float(ridge_loss(params, self.x, self.y, self.lam)),
                "gap": float(ridge_loss(params, self.x, self.y, self.lam))
                - self.f_star}

    def calibrate_G(self, rounds: int = 30) -> float:
        if not hasattr(self, "_G"):
            cfg = FLConfig(num_devices=K, scheme="mean", case="II", eta=0.01,
                           channel=channel(), seed=SEED, grad_bound=1.0,
                           smoothness_L=self.L, strong_convexity_M=self.M,
                           s_target=0.995)
            state = setup(cfg, self.params0, self.dim)
            _, hist = run(cfg, state, self.grad_fn, self.provider, rounds)
            self._G = 1.2 * max(hist["grad_norm_max"])
        return self._G

    def config(self, scheme: str = "normalized", amplification: str = "optimal",
               s_target: float = 0.995, **kw) -> FLConfig:
        base = dict(num_devices=K, scheme=scheme, case="II", eta=0.01,
                    channel=channel(), amplification=amplification,
                    grad_bound=self.calibrate_G(), smoothness_L=self.L,
                    strong_convexity_M=self.M, s_target=s_target, seed=SEED,
                    backend=DEFAULT_BACKEND)
        base.update(kw)
        return FLConfig(**base)

    def run(self, cfg: FLConfig, rounds: int, eval_every: int = 20):
        state = setup(cfg, self.params0, self.dim)
        return run(cfg, state, self.grad_fn, self.provider, rounds,
                   self.eval_fn, eval_every, driver=DEFAULT_DRIVER,
                   chunk_batch_provider=self.provider_chunk)


def timed_rounds(exp, cfg, rounds: int, eval_every: int = 50):
    """Run and report wall time per round (us_per_call for the CSV)."""
    t0 = time.perf_counter()
    state, hist = exp.run(cfg, rounds, eval_every)
    dt = time.perf_counter() - t0
    return state, hist, dt / rounds * 1e6
