"""Shared experiment setup for the paper-figure benchmarks, on the
declarative ``repro.fl`` facade.

Two tasks, exactly as in paper Sec. V:
 * Case I — 10-class classification with the 3-FC-layer ReLU classifier
   (synthetic MNIST-like data; DESIGN.md §7), eta_t = 1/t^0.75, batch 50.
 * Case II — ridge regression (smooth + strongly convex), constant eta = 0.01.

K = 20 devices, b_k^max = sqrt(5), theta_th = pi/3.  The channel keeps the
paper's Rayleigh/noise *model*; the mean is scaled so the post-aggregation
SNR is in the trainable regime the paper's figures imply (EXPERIMENTS.md
§Faithfulness discusses the paper's literal 1e-5 / 1e-7 constants).

The historical hand-wired plumbing (grad_fn + providers + eval_fn + split
per experiment class) lives in ``repro.fl.tasks`` now; these classes only
build ``FLConfig``s/``ExperimentSpec``s and run them.
"""
from __future__ import annotations

import time
from typing import Mapping, Optional

from repro.core.channel import ChannelConfig
from repro.fed.runtime import FLConfig
from repro.fl import (DataSpec, EvalSpec, Experiment, ExperimentSpec,
                      ModelSpec, SweepSpec, build_task, run_sweep)

K = 20
CHANNEL_MEAN = 1e-3
SEED = 0

# Seed replicates for the figure error bands: every figure benchmark runs
# its grid x SEED_REPLICATES channel/noise seeds as ONE batched sweep and
# dumps mean +- std across the seed axis.
SEED_REPLICATES = 3


def seed_axis(n: int = SEED_REPLICATES):
    return tuple(SEED + i for i in range(n))

# Execution backend for the benchmark FLConfigs: the fused Pallas kernel
# path by default (the registry refactor made every scheme run on it; on
# non-TPU hosts the wrappers route to their XLA oracles, so CPU timings are
# representative).  Override with `benchmarks.run --backend`.
DEFAULT_BACKEND = "kernels"

# Round-loop driver for the benchmark runs: the compiled lax.scan engine by
# default; `benchmarks.run --driver python` times the host-loop fallback
# (the `engine` benchmark reports both and their ratio).
DEFAULT_DRIVER = "scan"

# Flight-recorder overhead budget: the engine benchmark's scan_jsonl lane
# re-times the scan driver with a live JsonlRecorder attached and asserts
# scan/scan_jsonl stays under this ratio.  Telemetry rides the existing
# chunk-boundary device_get, so anything past ~5% means recording leaked
# onto the dispatch path.
OBS_OVERHEAD_BUDGET = 1.05


def channel(num_devices: int = K) -> ChannelConfig:
    return ChannelConfig(num_devices=num_devices, channel_mean=CHANNEL_MEAN)


class _SpecExperiment:
    """Spec-building base: subclasses declare data/model specs and the
    FLConfig defaults; the task (and its compiled executables) is shared
    across every config built here via the ``repro.fl.tasks`` cache."""

    data: DataSpec
    model: ModelSpec

    def __init__(self):
        self._task = build_task(self.data, self.model, K)
        self._G: Optional[float] = None

    # task constants the figures/examples read
    @property
    def params0(self):
        return self._task.params0

    @property
    def dim(self) -> int:
        return self._task.model_dim

    @property
    def constants(self):
        return self._task.constants

    def _base_config(self) -> dict:
        raise NotImplementedError

    def _calibration_config(self) -> FLConfig:
        """The noiseless mean-aggregation config G is calibrated on — the
        same constants as ``_base_config`` so the two can never drift."""
        return FLConfig(**{**self._base_config(),
                           "scheme": "mean", "grad_bound": 1.0})

    def calibrate_G(self, rounds: int = 30) -> float:
        """Empirical max-norm bound G (the conservative constant Benchmark I
        provisions for): max per-device gradient norm over a noiseless
        mean-aggregation calibration run, x1.2 headroom."""
        if self._G is None:
            e = Experiment(self.spec(self._calibration_config(),
                                     evaluate=False))
            hist = e.run(rounds)
            self._G = 1.2 * max(hist["grad_norm_max"])
        return self._G

    def config(self, scheme: str = "normalized",
               amplification: str = "optimal", **kw) -> FLConfig:
        base = self._base_config()
        base.update(scheme=scheme, amplification=amplification,
                    grad_bound=self.calibrate_G(), backend=DEFAULT_BACKEND)
        base.update(kw)
        return FLConfig(**base)

    def spec(self, cfg: FLConfig, eval_every: int = 10,
             evaluate: bool = True) -> ExperimentSpec:
        return ExperimentSpec(fl=cfg, data=self.data, model=self.model,
                              eval=EvalSpec(every=eval_every,
                                            enabled=evaluate),
                              driver=DEFAULT_DRIVER)

    def experiment(self, cfg: FLConfig, eval_every: int = 10) -> Experiment:
        return Experiment(self.spec(cfg, eval_every))

    def sweep(self, axes: Mapping, cfg: Optional[FLConfig] = None,
              eval_every: int = 10, evaluate: bool = True,
              seeds: Optional[int] = SEED_REPLICATES) -> SweepSpec:
        """A ``SweepSpec`` over this experiment's task: the given axes plus
        (by default) a batchable seed-replicate axis for error bands."""
        axes = dict(axes)
        if seeds and "seed" not in axes:
            axes["seed"] = seed_axis(seeds)
        return SweepSpec(self.spec(cfg or self.config(), eval_every,
                                   evaluate), axes)

    def run(self, cfg: FLConfig, rounds: int, eval_every: int = 10):
        e = self.experiment(cfg, eval_every)
        hist = e.run(rounds)
        return e.state, hist


# ---------------------------------------------------------------------------
# Case I: synthetic-MNIST MLP classification


class CaseIExperiment(_SpecExperiment):
    def __init__(self, num_train: int = 4000, num_test: int = 1000,
                 hidden: int = 64, non_iid_alpha: float = 1.0):
        self.data = DataSpec(dataset="synthetic_mnist", split="dirichlet",
                             alpha=non_iid_alpha, batch_size=50,
                             num_train=num_train, num_test=num_test,
                             seed=SEED)
        self.model = ModelSpec(kind="mlp", hidden=hidden)
        super().__init__()

    def _base_config(self) -> dict:
        return dict(num_devices=K, case="I", p=0.75, channel=channel(),
                    smoothness_L=5.0, expected_loss_drop=2.0, seed=SEED)


# ---------------------------------------------------------------------------
# Case II: ridge regression


class CaseIIExperiment(_SpecExperiment):
    def __init__(self, dim: int = 30, num_examples: int = 2000,
                 lam: float = 0.1):
        self.data = DataSpec(dataset="ridge", split="iid", batch_size=50,
                             num_train=num_examples, dim=dim, seed=SEED + 10)
        self.model = ModelSpec(kind="ridge", lam=lam)
        super().__init__()
        c = self.constants
        self.L, self.M = c["smoothness_L"], c["strong_convexity_M"]
        self.f_star = c["f_star"]
        self.lam = lam

    def _base_config(self) -> dict:
        return dict(num_devices=K, case="II", eta=0.01, channel=channel(),
                    smoothness_L=self.L, strong_convexity_M=self.M,
                    s_target=0.995, seed=SEED)

    def config(self, scheme: str = "normalized",
               amplification: str = "optimal", s_target: float = 0.995,
               **kw) -> FLConfig:
        return super().config(scheme=scheme, amplification=amplification,
                              s_target=s_target, **kw)


def timed_rounds(exp, cfg, rounds: int, eval_every: int = 50):
    """Run and report wall time per round (us_per_call for the CSV)."""
    t0 = time.perf_counter()
    state, hist = exp.run(cfg, rounds, eval_every)
    dt = time.perf_counter() - t0
    return state, hist, dt / rounds * 1e6


def timed_sweep(sweep: SweepSpec, rounds: int, **kw):
    """Run a whole sweep and report wall time per (grid point x round) —
    the aggregate us_per_call the figure CSV rows carry."""
    t0 = time.perf_counter()
    res = run_sweep(sweep, rounds, **kw)
    dt = time.perf_counter() - t0
    return res, dt / (sweep.size * rounds) * 1e6
