"""One benchmark per paper figure (Sec. V).  Each returns CSV rows
``name,us_per_call,derived`` where ``derived`` is the figure's headline
quantity; the full trajectories go to results/bench_<name>.json.
"""
from __future__ import annotations

import json
import os
from typing import List, Tuple

import numpy as np

from benchmarks.common import CaseIExperiment, CaseIIExperiment, timed_rounds

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def _dump(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"bench_{name}.json"), "w") as f:
        json.dump(payload, f, indent=2)


def fig1a_opt_benefit(rounds: int = 300) -> List[Tuple[str, float, str]]:
    """Fig. 1(a): Case I test accuracy — optimized (a, b) vs b_k = b_k^max."""
    exp = CaseIExperiment()
    rows, curves = [], {}
    for amp in ("optimal", "bmax"):
        cfg = exp.config(scheme="normalized", amplification=amp)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=max(rounds // 12, 5))
        acc = hist["test_acc"][-1]
        early = hist["test_acc"][1] if len(hist["test_acc"]) > 1 else acc
        curves[amp] = {"round": hist["eval_round"], "acc": hist["test_acc"]}
        rows.append((f"fig1a/{amp}", us,
                     f"early_acc={early:.4f};final_acc={acc:.4f}"))
    _dump("fig1a", curves)
    return rows


def fig1b_benchmarks(rounds: int = 300) -> List[Tuple[str, float, str]]:
    """Fig. 1(b): Case I — proposed vs Benchmark I [7] / II [13] (+ one-bit
    [12] as the extra ablation the intro argues against)."""
    exp = CaseIExperiment()
    rows, curves = [], {}
    for scheme in ("normalized", "benchmark1", "benchmark2", "onebit"):
        cfg = exp.config(scheme=scheme)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=25)
        acc = hist["test_acc"][-1]
        curves[scheme] = {"round": hist["eval_round"], "acc": hist["test_acc"]}
        rows.append((f"fig1b/{scheme}", us, f"final_acc={acc:.4f}"))
    _dump("fig1b", curves)
    return rows


def fig2a_opt_benefit_ridge(rounds: int = 400) -> List[Tuple[str, float, str]]:
    """Fig. 2(a): Case II loss — optimized (a, b) vs b_k = b_k^max."""
    exp = CaseIIExperiment()
    rows, curves = [], {}
    for amp in ("optimal", "bmax"):
        cfg = exp.config(amplification=amp)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=40)
        curves[amp] = {"round": hist["eval_round"], "loss": hist["loss"]}
        rows.append((f"fig2a/{amp}", us, f"final_gap={hist['gap'][-1]:.5f}"))
    _dump("fig2a", curves)
    return rows


def fig2b_benchmarks_ridge(rounds: int = 400) -> List[Tuple[str, float, str]]:
    """Fig. 2(b): Case II — proposed vs Benchmark I / II."""
    exp = CaseIIExperiment()
    rows, curves = [], {}
    for scheme in ("normalized", "benchmark1", "benchmark2"):
        cfg = exp.config(scheme=scheme)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=40)
        curves[scheme] = {"round": hist["eval_round"], "loss": hist["loss"]}
        rows.append((f"fig2b/{scheme}", us, f"final_gap={hist['gap'][-1]:.5f}"))
    _dump("fig2b", curves)
    return rows


def fig3a_case1_vs_case2(rounds: int = 400) -> List[Tuple[str, float, str]]:
    """Fig. 3(a): on the strongly-convex task, Case-II parameters converge
    faster than Case-I parameters (the benefit of exploiting convexity)."""
    exp = CaseIIExperiment()
    rows, curves = [], {}
    for case in ("I", "II"):
        kw = dict(case=case)
        if case == "I":
            kw.update(p=0.75, expected_loss_drop=20.0, s_target=None)
        else:
            kw.update(s_target=0.98)   # paper tunes Case II for speed (Fig. 3a)
        cfg = exp.config(**kw)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=40)
        curves[case] = {"round": hist["eval_round"], "loss": hist["loss"]}
        # rounds to reach 1.1x the better final gap
        rows.append((f"fig3a/case{case}", us, f"final_gap={hist['gap'][-1]:.5f}"))
    _dump("fig3a", curves)
    return rows


def fig3b_tradeoff(rounds: int = 600) -> List[Tuple[str, float, str]]:
    """Fig. 3(b): the q_max <-> epsilon tradeoff — larger s gives a lower
    floor but slower approach."""
    exp = CaseIIExperiment()
    rows, curves = [], {}
    for s in (0.9779, 0.9890, 0.9945):
        cfg = exp.config(s_target=s)
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=60)
        curves[str(s)] = {"round": hist["eval_round"], "loss": hist["loss"]}
        rows.append((f"fig3b/s={s}", us, f"final_gap={hist['gap'][-1]:.5f}"))
    _dump("fig3b", curves)
    return rows


def engine_rounds_per_sec(rounds: int = 64,
                          repeats: int = 3) -> List[Tuple[str, float, str]]:
    """Compiled-engine headline: rounds/sec of the ``lax.scan`` driver vs the
    per-round Python-loop driver (K=20, noiseless channel, ``kernels``
    backend) on both benchmark tasks — the Case-I MLP (compute-bound rounds:
    the engine's win is the removed host round-trips) and the Case-II ridge
    model (driver-overhead-bound rounds: the engine's win is the round rate
    itself).  The facade's task cache keeps one ``grad_fn`` identity per
    experiment, so the runtime's compiled executables persist across the
    ``Experiment`` resets; one warm-up run per driver removes jit compile
    from the timed runs, and the reported rate is the best of ``repeats``
    full runs."""
    import time

    from repro.core.channel import ChannelConfig
    from repro.fl import Experiment
    from benchmarks.common import (CHANNEL_MEAN, CaseIExperiment,
                                   CaseIIExperiment, K)

    rows, dump = [], {}
    for task, exp in (("mlp", CaseIExperiment()), ("ridge", CaseIIExperiment())):
        n = rounds if task == "mlp" else rounds * 8   # tiny model: longer run
        cfg = exp.config(scheme="normalized", backend="kernels",
                         channel=ChannelConfig(num_devices=K,
                                               channel_mean=CHANNEL_MEAN,
                                               noise_var=0.0))
        e = Experiment(exp.spec(cfg, evaluate=False))
        rps = {}
        for driver in ("python", "scan"):
            # compute-bound MLP rounds prefer small chunks (batch-buffer
            # locality); overhead-bound ridge rounds prefer one maximal chunk
            kw = dict(driver=driver, chunk_size=8 if task == "mlp" else n)
            e.reset()
            e.run(n, **kw)                                       # warm-up
            dt = float("inf")
            for _ in range(repeats):
                e.reset()
                t0 = time.perf_counter()
                e.run(n, **kw)
                dt = min(dt, time.perf_counter() - t0)
            rps[driver] = n / dt
            rows.append((f"engine/{task}/{driver}", dt / n * 1e6,
                         f"rounds_per_sec={rps[driver]:.2f}"))
        speedup = rps["scan"] / rps["python"]
        rows.append((f"engine/{task}/speedup", 0.0,
                     f"scan_over_python={speedup:.2f}x"))
        dump[task] = {"rounds_per_sec": rps, "speedup": speedup, "rounds": n}
    _dump("engine", dump)
    return rows


def scenario_axes(rounds: int = 120) -> List[Tuple[str, float, str]]:
    """The new spec axes on the Case-I task, each a one-field change to the
    baseline ``ExperimentSpec`` (the point of the declarative redesign):
    adamw server optimizer, H = 4 local steps, and 50% Bernoulli
    participation — reported as final accuracy plus the measured eq.-8
    transmit-energy total, which partial participation cuts roughly in
    half."""
    import dataclasses
    import time

    import numpy as np

    from repro.fl import Experiment
    from benchmarks.common import CaseIExperiment

    exp = CaseIExperiment()
    base_spec = exp.spec(exp.config(scheme="normalized"),
                         eval_every=max(rounds // 4, 5))
    variants = {
        "baseline": base_spec,
        "adamw": dataclasses.replace(base_spec, server_opt="adamw"),
        "local_steps4": dataclasses.replace(base_spec, local_steps=4,
                                            local_lr=0.05),
        "participation50": dataclasses.replace(base_spec, participation=0.5),
    }
    rows, dump = [], {}
    for name, spec in variants.items():
        e = Experiment(spec)
        t0 = time.perf_counter()
        e.run(rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        acc = e.history["test_acc"][-1]
        energy = float(np.sum(e.history["tx_energy"]))
        parts = float(np.mean(e.history["num_participants"]))
        dump[name] = {"round": e.history["eval_round"],
                      "acc": e.history["test_acc"],
                      "total_tx_energy": energy,
                      "mean_participants": parts}
        rows.append((f"scenario/{name}", us,
                     f"final_acc={acc:.4f};total_tx_energy={energy:.1f}"))
    _dump("scenarios", dump)
    return rows


def grad_norm_fluctuation(rounds: int = 200) -> List[Tuple[str, float, str]]:
    """Sec. I motivating claim: the local gradient norm fluctuates over
    iterations (so provisioning b_k for the max norm G wastes headroom).
    Reported on both experiment tasks; ridge (whose norms collapse as the
    iterate approaches w*) shows the effect most starkly."""
    rows, dump = [], {}
    for name, exp in (("mnist", CaseIExperiment()), ("ridge", CaseIIExperiment())):
        cfg = exp.config(scheme="normalized")
        _, hist, us = timed_rounds(exp, cfg, rounds, eval_every=rounds)
        norms = np.asarray(hist["grad_norm_mean"])
        ratio = float(norms.max() / max(norms.min(), 1e-9))
        dump[name] = {"round": hist["round"], "mean": hist["grad_norm_mean"],
                      "min": hist["grad_norm_min"], "max": hist["grad_norm_max"]}
        rows.append((f"grad_norm_fluctuation/{name}", us,
                     f"max_over_min={ratio:.2f};final_mean={norms[-1]:.4f}"))
    _dump("grad_norm_fluctuation", dump)
    return rows
