"""One benchmark per paper figure (Sec. V), on the vectorized sweep engine:
each figure is ONE batched sweep (its comparison axis x seed replicates),
so every curve in the dumped JSON carries a mean and a std band across
channel/noise seeds.  Rows are CSV ``name,us_per_call,derived`` where
``us_per_call`` is aggregate wall time per (grid point x round) and
``derived`` the figure's headline quantity; full trajectories go to
results/bench_<name>.json.
"""
from __future__ import annotations

import json
import os
from typing import List, Tuple

import numpy as np

from benchmarks.common import (CaseIExperiment, CaseIIExperiment,
                               SEED_REPLICATES, timed_sweep)

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def _dump(name: str, payload, manifest=None) -> None:
    """Write ``results/bench_<name>.json``; ``manifest`` (a
    ``repro.obs.run_manifest`` dict) rides along under the ``"manifest"``
    key so the file is self-describing and ``compare.py --manifest`` can
    cross-check the producing program's structural signature."""
    if manifest is not None:
        payload = dict(payload)
        payload["manifest"] = manifest
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"bench_{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=str)


def _banded_rows(fig: str, res, us: float, axis: str, metric: str,
                 seeds: int, row_metric: str = None, value_prefix: str = "",
                 ) -> Tuple[List[Tuple[str, float, str]], dict]:
    """CSV rows + JSON payload for a (axis x seed) sweep: one curve per axis
    value, mean +- std across the seed replicates (the curve payload is
    ``SweepResult.curves`` — one assembly path for live dumps and tests).
    ``row_metric`` picks the headline quantity of the CSV row when it
    differs from the dumped curve metric (the ridge figures plot ``loss``
    but report ``gap``)."""
    curves = res.curves(axis, metric, over="seed")
    row_metric = row_metric or metric
    rmean, rstd = res.band(row_metric, over="seed")
    rows = []
    for i, value in enumerate(res.sweep.values(axis)):
        rows.append((f"{fig}/{value_prefix}{value}", us,
                     f"final_{row_metric}={rmean[i][-1]:.5f}"
                     f"+-{rstd[i][-1]:.5f}"))
    return rows, curves


def fig1a_opt_benefit(rounds: int = 300,
                      seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 1(a): Case I test accuracy — optimized (a, b) vs b_k = b_k^max.
    One sweep: amplification (structural) x seed (batchable)."""
    exp = CaseIExperiment()
    sweep = exp.sweep({"amplification": ("optimal", "bmax")},
                      eval_every=max(rounds // 12, 5), seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig1a", res, us, "amplification",
                                "test_acc", seeds)
    _dump("fig1a", curves, manifest=res.manifest())
    return rows


def fig1b_benchmarks(rounds: int = 300,
                     seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 1(b): Case I — proposed vs Benchmark I [7] / II [13] (+ one-bit
    [12] as the extra ablation the intro argues against).  One sweep:
    scheme (structural, 4 sub-batches) x seed (batchable)."""
    exp = CaseIExperiment()
    sweep = exp.sweep(
        {"scheme": ("normalized", "benchmark1", "benchmark2", "onebit")},
        eval_every=25, seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig1b", res, us, "scheme", "test_acc", seeds)
    _dump("fig1b", curves, manifest=res.manifest())
    return rows


def fig2a_opt_benefit_ridge(rounds: int = 400,
                            seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 2(a): Case II loss — optimized (a, b) vs b_k = b_k^max."""
    exp = CaseIIExperiment()
    sweep = exp.sweep({"amplification": ("optimal", "bmax")}, eval_every=40,
                      seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig2a", res, us, "amplification", "loss",
                                seeds, row_metric="gap")
    _dump("fig2a", curves, manifest=res.manifest())
    return rows


def fig2b_benchmarks_ridge(rounds: int = 400,
                           seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 2(b): Case II — proposed vs Benchmark I / II."""
    exp = CaseIIExperiment()
    sweep = exp.sweep({"scheme": ("normalized", "benchmark1", "benchmark2")},
                      eval_every=40, seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig2b", res, us, "scheme", "loss", seeds,
                                row_metric="gap")
    _dump("fig2b", curves, manifest=res.manifest())
    return rows


def fig3a_case1_vs_case2(rounds: int = 400,
                         seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 3(a): on the strongly-convex task, Case-II parameters converge
    faster than Case-I parameters (the benefit of exploiting convexity).
    The case axis is a composite (several fields per value) and structural;
    seeds ride along batched within each sub-batch."""
    exp = CaseIIExperiment()
    sweep = exp.sweep(
        {"case_setup": (
            ("caseI", {"case": "I", "p": 0.75, "expected_loss_drop": 20.0,
                       "s_target": None}),
            # paper tunes Case II for speed in Fig. 3(a)
            ("caseII", {"case": "II", "s_target": 0.98}),
        )},
        eval_every=40, seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig3a", res, us, "case_setup", "loss",
                                seeds)
    _dump("fig3a", curves, manifest=res.manifest())
    return rows


def fig3b_tradeoff(rounds: int = 600,
                   seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Fig. 3(b): the q_max <-> epsilon tradeoff — larger s gives a lower
    floor but slower approach.  ``s_target`` only moves the setup-time
    receiver gain, so the WHOLE figure (3 targets x seeds) is one batched
    program."""
    exp = CaseIIExperiment()
    sweep = exp.sweep({"s_target": (0.9779, 0.9890, 0.9945)}, eval_every=60,
                      seeds=seeds)
    res, us = timed_sweep(sweep, rounds)
    rows, curves = _banded_rows("fig3b", res, us, "s_target", "loss", seeds,
                                row_metric="gap", value_prefix="s=")
    _dump("fig3b", curves, manifest=res.manifest())
    return rows


def engine_rounds_per_sec(rounds: int = 64,
                          repeats: int = 3) -> List[Tuple[str, float, str]]:
    """Compiled-engine headline: rounds/sec of the ``lax.scan`` driver vs the
    per-round Python-loop driver (K=20, noiseless channel, ``kernels``
    backend) on both benchmark tasks — the Case-I MLP (compute-bound rounds:
    the engine's win is the removed host round-trips) and the Case-II ridge
    model (driver-overhead-bound rounds: the engine's win is the round rate
    itself).  The facade's task cache keeps one ``grad_fn`` identity per
    experiment, so the runtime's compiled executables persist across the
    ``Experiment`` resets; one warm-up run per driver removes jit compile
    from the timed runs, and the reported rate is the best of ``repeats``
    full runs.

    A third lane re-times the scan driver with a live JSONL flight recorder
    and asserts the telemetry overhead stays within
    ``OBS_OVERHEAD_BUDGET`` (1.05x) — the recorder's whole design (host-side
    chunk-boundary emission, buffered writes) exists to keep this number
    flat, and this guard keeps it kept."""
    import time

    from repro import obs
    from repro.core.channel import ChannelConfig
    from repro.fl import Experiment
    from benchmarks.common import (CHANNEL_MEAN, CaseIExperiment,
                                   CaseIIExperiment, K, OBS_OVERHEAD_BUDGET)

    rows, dump = [], {}
    for task, exp in (("mlp", CaseIExperiment()), ("ridge", CaseIIExperiment())):
        n = rounds if task == "mlp" else rounds * 8   # tiny model: longer run
        cfg = exp.config(scheme="normalized", backend="kernels",
                         channel=ChannelConfig(num_devices=K,
                                               channel_mean=CHANNEL_MEAN,
                                               noise_var=0.0))
        e = Experiment(exp.spec(cfg, evaluate=False))
        rps = {}
        for driver in ("python", "scan"):
            # compute-bound MLP rounds prefer small chunks (batch-buffer
            # locality); overhead-bound ridge rounds prefer one maximal chunk
            kw = dict(driver=driver, chunk_size=8 if task == "mlp" else n)
            e.reset()
            e.run(n, **kw)                                       # warm-up
            dt = float("inf")
            for _ in range(repeats):
                e.reset()
                t0 = time.perf_counter()
                e.run(n, **kw)
                dt = min(dt, time.perf_counter() - t0)
            rps[driver] = n / dt
            rows.append((f"engine/{task}/{driver}", dt / n * 1e6,
                         f"rounds_per_sec={rps[driver]:.2f}"))
        speedup = rps["scan"] / rps["python"]
        if speedup < 1.0:
            # the engine's whole premise: the compiled scan must never lose
            # to the host loop.  The MLP (compute-bound) side regressed once
            # when chunk batches were host-gathered feature rows; the
            # index-batch providers (repro.fl.tasks) fixed it — this guard
            # keeps it fixed
            raise AssertionError(
                f"scan driver is {speedup:.2f}x the python driver on "
                f"{task} (< 1.0x) — the compiled engine regressed")
        rows.append((f"engine/{task}/speedup", 0.0,
                     f"scan_over_python={speedup:.2f}x"))
        # flight-recorder overhead lane: same scan timing, JSONL sink on
        # (fresh file per repeat so every run pays the full write path)
        kw = dict(driver="scan", chunk_size=8 if task == "mlp" else n)
        os.makedirs(RESULTS_DIR, exist_ok=True)
        obs_path = os.path.join(RESULTS_DIR, f"obs_engine_{task}.jsonl")
        dt = float("inf")
        for _ in range(repeats):
            e.reset()
            with obs.make("jsonl", path=obs_path) as rec:
                t0 = time.perf_counter()
                e.run(n, recorder=rec, **kw)
                dt = min(dt, time.perf_counter() - t0)
        rps["scan_jsonl"] = n / dt
        overhead = rps["scan"] / rps["scan_jsonl"]
        if overhead > OBS_OVERHEAD_BUDGET:
            raise AssertionError(
                f"JSONL flight recorder costs {overhead:.3f}x the bare scan "
                f"driver on {task} (> {OBS_OVERHEAD_BUDGET}x budget) — "
                "telemetry leaked onto the dispatch critical path")
        rows.append((f"engine/{task}/scan_jsonl", dt / n * 1e6,
                     f"rounds_per_sec={rps['scan_jsonl']:.2f};"
                     f"obs_overhead={overhead:.3f}x"))
        dump[task] = {"rounds_per_sec": rps, "speedup": speedup, "rounds": n,
                      "obs_overhead": overhead,
                      "obs_overhead_budget": OBS_OVERHEAD_BUDGET,
                      "manifest": e.manifest()}
    _dump("engine", dump)
    return rows


def sweep_rounds_per_sec(rounds: int = 256, grid: int = 8,
                         repeats: int = 2) -> List[Tuple[str, float, str]]:
    """Vectorized-sweep headline: aggregate rounds/sec of ONE batched
    program over a (seed x noise) grid vs the same grid as N sequential
    ``runtime.run`` dispatches (both on the compiled scan engine, both warm).
    The grid point is the Case-II ridge task — the overhead-bound regime
    sweeps live in — and the batched program runs the whole grid per
    dispatch, so the expected win is ~grid-size.  Also asserts the
    compiled-executable caches report ZERO re-traces across the timed
    repeats (the ``cache_info`` satellite)."""
    import dataclasses
    import time

    from repro.fed import runtime
    from repro.fl import SweepSpec
    from benchmarks.common import CaseIIExperiment, run_sweep, seed_axis

    exp = CaseIIExperiment()
    seeds = max(grid // 2, 1)
    base = dataclasses.replace(exp.spec(exp.config(), evaluate=False),
                               chunk_size=rounds)        # one scan per run
    nv = base.fl.channel.noise_var
    sweep = SweepSpec(base, {"noise_var": (nv, 2.0 * nv),
                             "seed": seed_axis(seeds)})
    g = sweep.size

    times = {}
    res_batched = None
    for mode, vectorized in (("batched", True), ("sequential", False)):
        run_sweep(sweep, rounds, vectorized=vectorized)      # warm-up
        traces0 = dict(runtime.TRACE_COUNTS)
        dt = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = run_sweep(sweep, rounds, vectorized=vectorized)
            dt = min(dt, time.perf_counter() - t0)
            if vectorized:
                res_batched = res
        retraces = sum(runtime.TRACE_COUNTS.values()) - sum(traces0.values())
        if mode == "batched" and retraces:
            # the README/ROADMAP contract: a warm batched grid re-traces
            # NOTHING (cache eviction or an unhashable config would show up
            # here long before it shows up as a perf regression)
            raise AssertionError(
                f"warm batched sweep re-traced {retraces} time(s); "
                f"cache_info={runtime.cache_info()}")
        times[mode] = dt
        times[f"{mode}_retraces"] = retraces
    rows = []
    for mode in ("batched", "sequential"):
        dt, retraces = times[mode], times[f"{mode}_retraces"]
        rows.append((f"sweep/{mode}", dt / (g * rounds) * 1e6,
                     f"agg_rounds_per_sec={g * rounds / dt:.1f};grid={g};"
                     f"retraces={retraces}"))
    speedup = times["sequential"] / times["batched"]
    rows.append((f"sweep/speedup", 0.0,
                 f"batched_over_sequential={speedup:.2f}x;grid={g}"))
    _dump("sweep", {
        "grid": g, "rounds": rounds,
        "agg_rounds_per_sec": {m: g * rounds / times[m]
                               for m in ("batched", "sequential")},
        "speedup": speedup,
        "retraces": {m: times[f"{m}_retraces"]
                     for m in ("batched", "sequential")},
        "cache_info": runtime.cache_info(),
    }, manifest=res_batched.manifest())
    return rows


def scenario_axes(rounds: int = 120) -> List[Tuple[str, float, str]]:
    """The new spec axes on the Case-I task, each a one-field change to the
    baseline ``ExperimentSpec`` (the point of the declarative redesign):
    adamw server optimizer, H = 4 local steps, and 50% Bernoulli
    participation — reported as final accuracy plus the measured eq.-8
    transmit-energy total, which partial participation cuts roughly in
    half."""
    import dataclasses
    import time

    import numpy as np

    from repro.fl import Experiment
    from benchmarks.common import CaseIExperiment

    exp = CaseIExperiment()
    base_spec = exp.spec(exp.config(scheme="normalized"),
                         eval_every=max(rounds // 4, 5))
    variants = {
        "baseline": base_spec,
        "adamw": dataclasses.replace(base_spec, server_opt="adamw"),
        "local_steps4": dataclasses.replace(base_spec, local_steps=4,
                                            local_lr=0.05),
        "participation50": dataclasses.replace(base_spec, participation=0.5),
    }
    rows, dump = [], {}
    for name, spec in variants.items():
        e = Experiment(spec)
        t0 = time.perf_counter()
        e.run(rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        acc = e.history["test_acc"][-1]
        energy = float(np.sum(e.history["tx_energy"]))
        parts = float(np.mean(e.history["num_participants"]))
        dump[name] = {"round": e.history["eval_round"],
                      "acc": e.history["test_acc"],
                      "total_tx_energy": energy,
                      "mean_participants": parts,
                      "manifest": e.manifest()}
        rows.append((f"scenario/{name}", us,
                     f"final_acc={acc:.4f};total_tx_energy={energy:.1f}"))
    _dump("scenarios", dump)
    return rows


def channel_rounds_per_sec(rounds: int = 256,
                           repeats: int = 2) -> List[Tuple[str, float, str]]:
    """Wireless-environment engine overhead: scan rounds/sec of the ridge
    task (overhead-bound rounds — the regime where channel work shows)
    across radio environments: fixed channel, i.i.d. block fading, AR(1)
    correlated fading, and AR(1) + imperfect CSI.  Every time-varying
    variant redraws the channel AND re-solves Problem 3 (on ``h_hat``)
    inside the scan, so this measures the in-scan re-solve + estimation
    cost directly; the CSI variant must stay within 2x of plain fading
    (asserted — a regression in the scan-safe refresh shows up here before
    it shows up in a sweep)."""
    import dataclasses
    import time

    from repro.fl import Experiment
    from benchmarks.common import CaseIIExperiment

    exp = CaseIIExperiment()
    base = dataclasses.replace(exp.spec(exp.config(), evaluate=False),
                               chunk_size=rounds)       # one scan per run
    def env(**chkw):
        channel = dataclasses.replace(base.fl.channel, **chkw)
        return dataclasses.replace(
            base, fl=dataclasses.replace(base.fl, channel=channel))

    variants = {
        "fixed": base,
        "iid_fading": env(block_fading=True),
        "ar1": env(model="ar1", rho=0.9),
        "ar1_csi": env(model="ar1", rho=0.9, csi_error=0.2),
    }
    rows, dump = [], {}
    rps, manifests = {}, {}
    for name, spec in variants.items():
        e = Experiment(spec)
        e.run(rounds)                                    # warm-up + compile
        dt = float("inf")
        for _ in range(repeats):
            e.reset()
            t0 = time.perf_counter()
            e.run(rounds)
            dt = min(dt, time.perf_counter() - t0)
        rps[name] = rounds / dt
        manifests[name] = e.manifest()
        rows.append((f"channel/{name}", dt / rounds * 1e6,
                     f"rounds_per_sec={rps[name]:.1f}"))
    overhead = rps["iid_fading"] / rps["ar1_csi"]
    if overhead > 2.0:
        raise AssertionError(
            "in-scan AR(1)+CSI refresh costs "
            f"{overhead:.2f}x plain block fading (> 2x budget)")
    rows.append(("channel/csi_overhead", 0.0,
                 f"fading_over_ar1_csi={overhead:.2f}x"))
    _dump("channel", {"rounds": rounds, "rounds_per_sec": rps,
                      "csi_overhead_vs_fading": overhead,
                      "manifests": manifests})
    return rows


def csi_robustness(rounds: int = 400,
                   seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """CSI-robustness figure: the proposed normalized-gradient scheme vs
    the max-norm Benchmark I across CSI-error levels (block fading, so the
    per-round re-solve runs on every round's noisy estimate).  One sweep:
    scheme (structural, 2 sub-batches) x csi_error (batchable) x seed
    (batchable), dumped with seed-replicate bands via ``SweepResult.band``."""
    import dataclasses

    from benchmarks.common import CaseIIExperiment, seed_axis, timed_sweep
    from repro.fl import SweepSpec

    exp = CaseIIExperiment()
    base = exp.spec(exp.config(), eval_every=max(rounds // 10, 5))
    channel = dataclasses.replace(base.fl.channel, block_fading=True)
    base = dataclasses.replace(
        base, fl=dataclasses.replace(base.fl, channel=channel))
    sweep = SweepSpec(base, {"scheme": ("normalized", "benchmark1"),
                             "csi_error": (0.0, 0.1, 0.3, 0.6),
                             "seed": seed_axis(seeds)})
    res, us = timed_sweep(sweep, rounds)
    mean, std = res.band("gap", over="seed")      # [scheme, csi, evals]
    err_mean, _ = res.band("csi_gain_err", over="seed")
    rows, curves = [], {}
    for i, scheme in enumerate(res.sweep.values("scheme")):
        for j, err in enumerate(res.sweep.values("csi_error")):
            curves[f"{scheme}/csi={err}"] = {
                "round": res.eval_rounds,
                "gap": mean[i, j].tolist(),
                "gap_std": std[i, j].tolist(),
                "mean_abs_csi_gain_err": float(
                    np.abs(err_mean[i, j]).mean()),
                "seeds": seeds,
            }
            rows.append((f"csi_robustness/{scheme}/csi={err}", us,
                         f"final_gap={mean[i, j][-1]:.5f}"
                         f"+-{std[i, j][-1]:.5f}"))
    _dump("csi_robustness", curves, manifest=res.manifest())
    return rows


def client_algorithms(rounds: int = 200,
                      seeds: int = SEED_REPLICATES
                      ) -> List[Tuple[str, float, str]]:
    """Client-algorithm registry deliverable: FedProx, FedDyn, and SCAFFOLD
    through the air vs plain local SGD, on dirichlet splits of the Case-I
    task with H = 4 local steps (the client-drift regime the corrections
    target).  One sweep: algorithm (structural composite — FedDyn's and
    SCAFFOLD's refreshed correction states ride a second OTA slot) x
    dirichlet alpha (structural, new split per value) x participation
    (structural) x seed (batchable), dumped with seed-replicate bands on
    the train loss.

    The sweep runs at noise_var = 1e-10, the drift-dominated operating
    point: the stateful correctors learn their server state from the
    DE-GAINED slot-2 aggregate, which amplifies channel noise by
    ~1/(a sum h b) — at the repo-default 1e-7 that amplified noise swamps
    the variates, the corrections inject it into every local step, and
    plain SGD (which never de-gains) inverts the ranking.  The separation
    below is therefore asserted where client drift, not variate-channel
    noise, is the binding error source.

    Two guards asserted: the two-slot correctors' eq.-8 transmit energy is
    ~2x SGD's under full participation (the second slot pays the same
    unit-norm budget as the first), and on the alpha = 0.1 non-IID split
    both stateful correctors beat plain SGD on final train loss with
    non-overlapping seed bands."""
    import dataclasses

    from benchmarks.common import CaseIExperiment, seed_axis, timed_sweep
    from repro.fl import SweepSpec

    exp = CaseIExperiment()
    cfg = exp.config()
    cfg = dataclasses.replace(
        cfg, channel=dataclasses.replace(cfg.channel, noise_var=1e-10))
    base = exp.spec(cfg, eval_every=max(rounds // 10, 5))
    base = dataclasses.replace(base, local_steps=4, local_lr=0.05)
    algos = (("sgd", {"client.algo": "sgd"}),
             ("fedprox", {"client.algo": "fedprox", "client.mu": 0.1}),
             ("feddyn", {"client.algo": "feddyn", "client.alpha": 0.1}),
             ("scaffold", {"client.algo": "scaffold"}))
    sweep = SweepSpec(base, {"algo": algos,
                             "alpha": (0.1, 100.0),
                             "participation": (1.0, 0.5),
                             "seed": seed_axis(seeds)})
    res, us = timed_sweep(sweep, rounds)
    mean, std = res.band("train_loss", over="seed")  # [algo, alpha, part, E]
    emean, _ = res.band("tx_energy", over="seed")    # [algo, alpha, part, T]
    names = res.sweep.values("algo")
    rows, curves, energy, final = [], {}, {}, {}
    for i, name in enumerate(names):
        for j, al in enumerate(res.sweep.values("alpha")):
            for k, part in enumerate(res.sweep.values("participation")):
                tot_e = float(np.sum(emean[i, j, k]))
                energy[(name, al, part)] = tot_e
                final[(name, al, part)] = (mean[i, j, k][-1],
                                           std[i, j, k][-1])
                curves[f"{name}/alpha={al}/part={part}"] = {
                    "round": res.eval_rounds,
                    "train_loss": mean[i, j, k].tolist(),
                    "train_loss_std": std[i, j, k].tolist(),
                    "total_tx_energy": tot_e,
                    "seeds": seeds,
                }
                rows.append((f"clients/{name}/alpha={al}/part={part}", us,
                             f"final_train_loss={mean[i, j, k][-1]:.4f}"
                             f"+-{std[i, j, k][-1]:.4f};"
                             f"total_tx_energy={tot_e:.1f}"))
    # second OTA slot: the two-slot correctors pay exactly twice the
    # per-round unit-norm energy of the single-slot algorithms under full
    # participation
    a0 = res.sweep.values("alpha")[0]
    for name in ("feddyn", "scaffold"):
        ratio = energy[(name, a0, 1.0)] / energy[("sgd", a0, 1.0)]
        if not 1.95 <= ratio <= 2.05:
            raise AssertionError(
                f"{name}/sgd transmit-energy ratio {ratio:.3f} is not ~2 — "
                "the second OTA slot's eq.-8 accounting drifted")
    # algorithm separation on the non-IID split (full participation):
    # each stateful corrector's final band sits strictly below SGD's
    sm, ss = final[("sgd", 0.1, 1.0)]
    for name in ("feddyn", "scaffold"):
        am, as_ = final[(name, 0.1, 1.0)]
        if am + as_ >= sm - ss:
            raise AssertionError(
                f"{name} final train loss {am:.4f}+-{as_:.4f} does not "
                f"separate from sgd {sm:.4f}+-{ss:.4f} on dirichlet(0.1)")
    rows.append(("clients/energy_ratio", 0.0,
                 f"two_slot_over_sgd_tx_energy={ratio:.3f}"))
    _dump("clients", curves, manifest=res.manifest())
    return rows


def kscale_flat_memory(quick: bool = False) -> List[Tuple[str, float, str]]:
    """Streaming K-scale headline (the PR-6 tentpole deliverable): a
    100,000-device OTA round on the ``k_block`` streaming engine, with peak
    RSS held FLAT against the dense path's linear growth in K.

    Three subprocess cases (``benchmarks.kscale_case``; each owns a process
    because peak RSS is a lifetime high-water mark — measured via the
    exec-fresh ``VmHWM``, not the fork-inherited ``ru_maxrss``): the dense
    engine at
    two small K to fit its MB-per-device slope, then the streaming engine at
    the target K.  Asserted: streaming peak RSS < 0.5x the dense
    extrapolation at the same K (the measured ratio is ~0.04) AND under an
    absolute pin that catches an accidental [K, N] / [K, B, d]
    materialization even if the extrapolation is noisy.  Quick mode shrinks
    every K by 5x for the CI smoke — same shape, same guards.

    The PR-9 sharded entry runs the SAME streamed round under
    ``device_mesh=4`` twice — once on 4 forced host devices (the physical
    ``shard_map`` path) and once without them (the emulated fallback) — and
    asserts the two trajectories are bitwise-identical by params digest:
    sharding is just another blocking, so where it runs is invisible in the
    math.  The >= 2x rounds/sec speedup over the single-device stream is
    asserted only when the host has >= 4 cores (forced host devices on one
    core are concurrency, not parallelism); the measured ratio is always
    recorded so a skipped assertion is visible, never silent."""
    import json as _json
    import os
    import subprocess
    import sys

    # the dense slope is fit from the SAME two K in both modes: smaller
    # points would shave seconds but leave the fit inside RSS noise (tens of
    # MB) — only the streaming K shrinks for the CI smoke
    if quick:
        rounds, dense_ks, stream_k, stream_kb = 2, (1000, 2000), 20_000, 500
    else:
        rounds, dense_ks, stream_k, stream_kb = 4, (1000, 2000), 100_000, 1000
    RSS_PIN_MB = 2048.0

    def case(devices: int, k_block: int, device_mesh: int = 0,
             force_host_devices: int = 0) -> dict:
        env = dict(os.environ)
        if force_host_devices:
            flag = (f"--xla_force_host_platform_device_count="
                    f"{force_host_devices}")
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.kscale_case",
             "--devices", str(devices), "--k-block", str(k_block),
             "--device-mesh", str(device_mesh), "--rounds", str(rounds)],
            capture_output=True, text=True, env=env)
        if out.returncode != 0:
            raise AssertionError(
                f"kscale case K={devices} k_block={k_block} "
                f"device_mesh={device_mesh} failed:\n{out.stderr[-2000:]}")
        return _json.loads(out.stdout.strip().splitlines()[-1])

    rows, dense = [], []
    for kdev in dense_ks:
        r = case(kdev, 0)
        dense.append(r)
        rows.append((f"kscale/dense/K={kdev}", 1e6 / r["rounds_per_sec"],
                     f"peak_rss_mb={r['peak_rss_mb']:.0f};"
                     f"rounds_per_sec={r['rounds_per_sec']:.2f}"))
    stream = case(stream_k, stream_kb)
    rows.append((f"kscale/streaming/K={stream_k}",
                 1e6 / stream["rounds_per_sec"],
                 f"peak_rss_mb={stream['peak_rss_mb']:.0f};"
                 f"rounds_per_sec={stream['rounds_per_sec']:.2f};"
                 f"k_block={stream_kb}"))

    (k1, m1), (k2, m2) = [(r["devices"], r["peak_rss_mb"]) for r in dense]
    slope = (m2 - m1) / (k2 - k1)                   # MB per device, dense
    extrapolated = m2 + slope * (stream_k - k2)
    ratio = stream["peak_rss_mb"] / extrapolated
    if stream["peak_rss_mb"] > 0.5 * extrapolated:
        raise AssertionError(
            f"streaming peak RSS {stream['peak_rss_mb']:.0f} MB at "
            f"K={stream_k} exceeds half the dense extrapolation "
            f"{extrapolated:.0f} MB — the K axis is leaking into memory")
    if stream["peak_rss_mb"] > RSS_PIN_MB:
        raise AssertionError(
            f"streaming peak RSS {stream['peak_rss_mb']:.0f} MB exceeds the "
            f"{RSS_PIN_MB:.0f} MB pin — something materializes O(K)")
    rows.append(("kscale/memory_ratio", 0.0,
                 f"stream_over_dense_extrapolated={ratio:.3f};"
                 f"dense_extrapolated_mb={extrapolated:.0f}"))

    # ---- PR-9 sharded streaming: device_mesh=4, physical vs emulated
    mesh_d = 4
    sharded = case(stream_k, stream_kb, device_mesh=mesh_d,
                   force_host_devices=mesh_d)
    if sharded["local_devices"] < mesh_d:
        raise AssertionError(
            f"forced-host-device case saw {sharded['local_devices']} local "
            f"devices (wanted {mesh_d}) — XLA_FLAGS did not reach the "
            "subprocess")
    sharded_emu = case(stream_k, stream_kb, device_mesh=mesh_d)
    if sharded["params_sha256"] != sharded_emu["params_sha256"]:
        raise AssertionError(
            "sharded streaming trajectory is NOT bitwise-identical across "
            f"physical/emulated execution: {sharded['params_sha256']} vs "
            f"{sharded_emu['params_sha256']} — the device_mesh math spec "
            "leaked an execution-dependent reduction")
    if sharded["peak_rss_mb"] > RSS_PIN_MB:
        raise AssertionError(
            f"sharded streaming peak RSS {sharded['peak_rss_mb']:.0f} MB "
            f"exceeds the {RSS_PIN_MB:.0f} MB pin — the mesh re-materialized "
            "the K axis")
    speedup = sharded["rounds_per_sec"] / stream["rounds_per_sec"]
    cores = os.cpu_count() or 1
    if cores >= mesh_d and speedup < 2.0:
        raise AssertionError(
            f"sharded streaming speedup {speedup:.2f}x < 2x over the "
            f"single-device stream at K={stream_k} on {cores} cores")
    rows.append((f"kscale/sharded/K={stream_k}",
                 1e6 / sharded["rounds_per_sec"],
                 f"peak_rss_mb={sharded['peak_rss_mb']:.0f};"
                 f"rounds_per_sec={sharded['rounds_per_sec']:.2f};"
                 f"device_mesh={mesh_d};speedup={speedup:.2f}x;"
                 f"bitwise_phys_vs_emulated=ok;"
                 + (f"speedup_assert=on"
                    if cores >= mesh_d else
                    f"speedup_assert=SKIPPED(cores={cores})")))

    _dump("kscale", {
        "rounds": rounds,
        "dense": dense,
        "streaming": stream,
        "sharded": sharded,
        "sharded_emulated": sharded_emu,
        "sharded_speedup_over_stream": speedup,
        "sharded_speedup_asserted": cores >= mesh_d,
        "sharded_bitwise_phys_vs_emulated": True,
        "dense_slope_mb_per_device": slope,
        "dense_extrapolated_mb_at_stream_k": extrapolated,
        "stream_over_dense_extrapolated": ratio,
        "rss_pin_mb": RSS_PIN_MB,
    })
    return rows


def grad_norm_fluctuation(rounds: int = 200,
                          seeds: int = SEED_REPLICATES) -> List[Tuple[str, float, str]]:
    """Sec. I motivating claim: the local gradient norm fluctuates over
    iterations (so provisioning b_k for the max norm G wastes headroom).
    Reported on both experiment tasks (one seed-batched sweep each); ridge
    (whose norms collapse as the iterate approaches w*) shows the effect
    most starkly."""
    rows, dump = [], {}
    for name, exp in (("mnist", CaseIExperiment()), ("ridge", CaseIIExperiment())):
        sweep = exp.sweep({}, eval_every=rounds, evaluate=False, seeds=seeds)
        res, us = timed_sweep(sweep, rounds)
        mean = res.history["grad_norm_mean"].mean(axis=0)
        ratio = float(mean.max() / max(mean.min(), 1e-9))
        dump[name] = {
            "round": res.rounds,
            "mean": mean.tolist(),
            "mean_std": res.history["grad_norm_mean"].std(axis=0).tolist(),
            "min": res.history["grad_norm_min"].mean(axis=0).tolist(),
            "max": res.history["grad_norm_max"].mean(axis=0).tolist(),
            "seeds": seeds,
            "manifest": res.manifest(),
        }
        rows.append((f"grad_norm_fluctuation/{name}", us,
                     f"max_over_min={ratio:.2f};final_mean={mean[-1]:.4f}"))
    _dump("grad_norm_fluctuation", dump)
    return rows
