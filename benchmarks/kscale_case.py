"""One (devices, k_block) case of the K-scale streaming benchmark, run as a
SUBPROCESS: peak RSS is a process-lifetime high-water mark, so the
dense-vs-streaming peak-memory comparison is only meaningful when every case
owns a fresh process.

    PYTHONPATH=src python -m benchmarks.kscale_case \
        --devices 100000 --k-block 1000 --rounds 4

Prints one JSON line: peak RSS (MB), rounds/sec, and a final diagnostic.

The task is a shared-pool linear regression: every device's minibatch is B
rows gathered from one [pool, dim] example matrix by a (round, device)-keyed
index draw, so the DATA working set is O(pool * dim) no matter how many
devices exist — the device axis carries only PRNG folds.  That isolates what
this benchmark measures: the engine's own per-device memory (batch gather,
gradient stack, superposition), which the dense path materializes at
O(K * (B + 1) * dim) and the streaming path at O(k_block * (B + 1) * dim).

The radio environment comes from the lazy per-block samplers
(``draw_channel_block`` / ``relative_gains_block``) — the 100k-device path
never holds more than one K-block of geometry or fading draws in flight.
Problem 3's interior-point solve assembles a [K+1, K] system (itself
O(K^2) memory), so at this scale ``b`` rides at ``b_max`` and the receiver
gain normalizes the designed effective gain ``a * sum(h b)`` to 1 — the
paper's Case-I shape with the server optimization held out of the loop.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

# the exec-fresh VmHWM reader this benchmark pioneered, promoted to the
# observability library (see its docstring for why ru_maxrss lies under
# fork and VmHWM does not)
from repro.obs.profiling import peak_rss_mb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, required=True)
    ap.add_argument("--k-block", type=int, default=0,
                    help="streaming K-block size; 0 = the dense path")
    ap.add_argument("--device-mesh", type=int, default=0,
                    help="sharded streaming mesh width (requires --k-block);"
                         " 0 = plain stream.  Launch with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=D for the"
                         " physical path; without the forced devices the"
                         " engine runs its (bitwise-identical) emulated path")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--dim", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--pool", type=int, default=4096)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.channels.geometry import GeometryConfig, relative_gains_block
    from repro.core.channel import ChannelConfig, draw_channel_block
    from repro.fed import runtime

    K, d, B = args.devices, args.dim, args.batch
    kb = args.k_block or None

    key = jax.random.PRNGKey(0)
    X = jax.random.normal(jax.random.fold_in(key, 1), (args.pool, d))
    w_true = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    y = X @ w_true + 0.1 * jax.random.normal(jax.random.fold_in(key, 3),
                                             (args.pool,))

    def grad_fn(params, batch):
        xb, yb = batch
        r = xb @ params["w"] - yb
        return {"w": xb.T @ r / r.shape[0]}

    bk = jax.random.fold_in(key, 4)

    def device_batch(t, dev):
        # B pool rows keyed by (round, device index): the SAME draw whether
        # it is materialized dense or one K-block at a time
        dk = jax.random.fold_in(jax.random.fold_in(bk, t), dev)
        idx = jax.random.randint(dk, (B,), 0, args.pool)
        return X[idx], y[idx]

    def block_batch_provider(t, dev_idx):
        return jax.vmap(lambda i: device_batch(t, i))(dev_idx)

    dense_batch = jax.jit(
        lambda t: jax.vmap(lambda i: device_batch(t, i))(jnp.arange(K)))

    ccfg = ChannelConfig(num_devices=K, channel_mean=1e-3, noise_var=1e-7)
    geo = GeometryConfig(shadowing_std_db=4.0)
    ck = jax.random.PRNGKey(7)
    step = kb or min(K, 10_000)
    blocks = []
    for lo in range(0, K, step):
        devs = jnp.arange(lo, min(lo + step, K))
        scale = ccfg.rayleigh_scale() * relative_gains_block(ck, geo, devs)
        blocks.append(draw_channel_block(ck, ccfg, devs, scale))
    h = np.asarray(jnp.concatenate(blocks), np.float64)
    b = np.full(K, ccfg.b_max)
    a = 1.0 / float(np.sum(h * b))

    cfg = runtime.FLConfig(
        num_devices=K, case="I", p=0.75, channel=ccfg, scheme="normalized",
        backend="kernels", smoothness_L=5.0, expected_loss_drop=2.0,
        grad_bound=10.0, seed=0, k_block=kb,
        device_mesh=args.device_mesh if args.device_mesh > 1 else None)
    params0 = {"w": jnp.zeros((d,), jnp.float32)}
    state = runtime.FLState(params0, h, b, a, eta0=1.0, model_dim=d)

    if kb is None:
        provider, block_provider = dense_batch, None
    else:
        provider, block_provider = None, block_batch_provider

    def go(rounds):
        return runtime.run(cfg, state, grad_fn, provider, rounds,
                           driver="scan", chunk_size=1,
                           block_batch_provider=block_provider)

    go(1)                                          # compile warm-up
    t0 = time.perf_counter()
    _, hist = go(args.rounds)
    dt = time.perf_counter() - t0

    # bitwise trajectory fingerprint: the sharded benchmark compares the
    # physical and emulated runs of the same spec by digest, not tolerance
    params_sha = hashlib.sha256(
        np.ascontiguousarray(np.asarray(state.params["w"],
                                        np.float32)).tobytes()).hexdigest()

    from repro import obs

    json.dump({
        "devices": K, "k_block": args.k_block,
        "device_mesh": args.device_mesh, "dim": d, "batch": B,
        "rounds": args.rounds,
        "rounds_per_sec": args.rounds / dt,
        "peak_rss_mb": peak_rss_mb(),
        "grad_norm_mean_final": float(hist["grad_norm_mean"][-1]),
        "params_sha256": params_sha,
        "local_devices": jax.local_device_count(),
        # self-describing identity block: config hash + structural signature
        # + the digest above (compare.py --manifest cross-checks signatures)
        "manifest": obs.run_manifest(cfg=cfg, params_digest=params_sha),
    }, sys.stdout)
    print()


if __name__ == "__main__":
    main()
