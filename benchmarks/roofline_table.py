"""Roofline benchmark: renders the dry-run analysis JSONs into the
EXPERIMENTS.md table and CSV rows (one per arch x shape)."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def _load(name):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def roofline_rows() -> List[Tuple[str, float, str]]:
    """CSV rows from the analysis sweep (us_per_call = dominant roofline term
    in us — the modeled per-step lower bound on v5e)."""
    recs = _load("analysis_singlepod.json") or _load("dryrun_singlepod.json")
    rows = []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append((name, 0.0, f"status={r['status']}"))
            continue
        rf = r["roofline"]
        dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append((name, dom_s * 1e6,
                     f"bottleneck={rf['bottleneck']},useful={rf['useful_flops_ratio']:.2f}"))
    return rows


def markdown_table(recs) -> str:
    lines = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
             "bottleneck | 6ND/HLO | note |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "ok":
            rf = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.2f} | "
                f"{rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.2f} | "
                f"{rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} | |")
        else:
            reason = r.get("skip_reason") or r.get("error", "")[:40]
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                         f"{r['status']}: {reason} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for row in roofline_rows():
        print(",".join(str(c) for c in row))
