"""§Perf hillclimb driver (run AFTER the analysis sweep; single process).

Three targeted pairs (EXPERIMENTS.md §Perf):
  * qwen2-7b x train_4k      — most representative of the paper's technique
                               (OTA gradient collective in the train step)
  * jamba-v0.1-52b x train_4k — worst roofline fraction (hybrid + MoE)
  * pixtral-12b x decode_32k  — most collective-bound (KV-cache all-gathers)

Each variant is measured with the same unrolled depth-extrapolation
methodology as the baseline table.  Usage:

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --out results/hillclimb.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

TARGETS = [
    # (arch, shape, variant-name, overrides, perf)
    ("qwen2-7b", "train_4k", "baseline-paper-faithful", {}, {}),
    ("qwen2-7b", "train_4k", "seq-parallel-activations",
     {"seq_shard_activations": "model"}, {}),
    # NOTE: "seqpar+bf16-ota-psum" aborts XLA-CPU's AllReducePromotion pass
    # ("Invalid binary instruction opcode copy") — recorded in EXPERIMENTS.md
    # §Perf as blocked-by-tooling; the lever stays available for real TPU.
    ("qwen2-7b", "train_4k", "seqpar+remat-dots",
     {"seq_shard_activations": "model", "remat_policy": "dots"}, {}),

    ("jamba-v0.1-52b", "train_4k", "baseline-paper-faithful", {}, {}),
    ("jamba-v0.1-52b", "train_4k", "mamba-channel-shard",
     {"mamba_shard_channels": "model"}, {}),
    ("jamba-v0.1-52b", "train_4k", "mamba-chunk-1024",
     {"mamba_shard_channels": "model", "mamba_chunk": 1024}, {}),

    ("pixtral-12b", "decode_32k", "baseline", {}, {}),
    ("pixtral-12b", "decode_32k", "seq-sharded-cache+select-update",
     {"decode_cache_update": "select"}, {"shard_cache_seq": True}),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--only", default=None, help="substring filter on variant")
    args = ap.parse_args()

    from repro.launch.dryrun import analyze_one

    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    done = {(r["arch"], r["shape"], r["variant"]) for r in existing}

    records = existing
    for arch, shape, variant, ov, perf in TARGETS:
        if (arch, shape, variant) in done:
            continue
        if args.only and args.only not in variant:
            continue
        try:
            rec = analyze_one(arch, shape, overrides=ov or None,
                              perf=perf or None)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": repr(e)}
        rec["variant"] = variant
        rec["overrides"] = ov
        rec["perf"] = perf
        records.append(rec)
        if rec["status"] == "ok":
            rf = rec["roofline"]
            print(f"{arch} x {shape} [{variant}]: "
                  f"compute={rf['compute_s']*1e3:.1f}ms "
                  f"mem={rf['memory_s']*1e3:.1f}ms "
                  f"coll={rf['collective_s']*1e3:.1f}ms "
                  f"-> {rf['bottleneck']}", flush=True)
        else:
            print(f"{arch} x {shape} [{variant}]: {rec['status']} "
                  f"{rec.get('error','')[:200]}", flush=True)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
