"""Benchmark regression guard: diff fresh ``results/bench_*.json`` against
committed baselines and exit nonzero on a regression, so a perf cliff fails
CI instead of silently rewriting the numbers.

    # CI smoke: rerun the quick benchmarks, then diff against the committed
    # quick-mode baselines (results/ itself is gitignored — the blessed
    # numbers live in benchmarks/baselines/)
    PYTHONPATH=src python -m benchmarks.run --quick --only sweep,kscale
    PYTHONPATH=src python -m benchmarks.compare --fresh results

Three rule families, matched by leaf key name anywhere in the JSON tree:

* throughput (``rounds_per_sec`` scalars, and every lane of the
  ``rounds_per_sec`` / ``agg_rounds_per_sec`` dicts): the fresh number must
  be at least ``(1 - tolerance)`` of the baseline.  The default tolerance is
  generous (50%) because shared CI boxes are noisy — the guard exists to
  catch cliffs (a 2x regression from an accidental retrace or
  materialization), not single-digit drift.
* memory (``peak_rss_mb``): the fresh peak must stay under the sibling
  ``rss_pin_mb`` when the file carries one (the kscale flat-memory pin),
  and under ``(1 + rss_tolerance)`` of the baseline either way.
* retraces (any leaf under a ``retraces`` dict): must be 0 in the fresh run,
  unconditionally — retraces are deterministic, so there is no noise to
  tolerate.

With ``--manifest`` a fourth family activates: every baseline leaf named
``structural_signature`` (run-manifest identity: the hash of the
structurally-significant FLConfig fields, PR 10) must be present and EQUAL
in the fresh run.  A signature mismatch means the fresh benchmark compiled
a structurally different program than the one the baseline numbers were
blessed on — a workload swap masquerading as a perf result — and is a
regression, not a skip.  Baselines predating manifests simply contribute no
signature leaves.

Entries whose scale knobs disagree between the two files (``rounds``,
``grid``, ``devices`` — e.g. a quick-mode fresh run against a full-mode
baseline) are SKIPPED with a visible note rather than mis-compared; a
baseline file with no fresh counterpart is likewise reported.  Exit status:
0 = no regressions (skips allowed), 1 = at least one regression.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any, Dict, Iterator, List, Tuple

# scale knobs: when any of these differ at the SAME path in baseline/fresh,
# the surrounding entry is incomparable (different workload, not a
# regression)
_SCALE_KEYS = ("rounds", "grid", "devices", "k_block", "dim", "batch")


def _walk(tree: Any, path: Tuple[str, ...] = ()) -> Iterator[
        Tuple[Tuple[str, ...], Any]]:
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (str(k),))
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (str(i),))
    else:
        yield path, tree


def _fmt(path: Tuple[str, ...]) -> str:
    return "/".join(path)


def compare_file(name: str, base: Dict, fresh: Dict, *, tolerance: float,
                 rss_tolerance: float,
                 manifest: bool = False) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) for one benchmark JSON pair."""
    regressions: List[str] = []
    notes: List[str] = []
    bleaves = dict(_walk(base))
    fleaves = dict(_walk(fresh))

    if manifest:
        # signatures only occur inside run manifests, so the leaf name alone
        # identifies them wherever the benchmark nested its manifest(s)
        sig_paths = [p for p in bleaves
                     if p and p[-1] == "structural_signature"]
        if not sig_paths:
            notes.append(f"{name}: NOTE baseline carries no run manifest — "
                         "signature check skipped")
        for path in sig_paths:
            fval = fleaves.get(path)
            if fval is None:
                regressions.append(
                    f"{name}: {_fmt(path)} missing from fresh run — "
                    "benchmark no longer writes its manifest")
            elif fval != bleaves[path]:
                regressions.append(
                    f"{name}: {_fmt(path)} changed "
                    f"{bleaves[path][:12]}... -> {str(fval)[:12]}... — "
                    "fresh run compiled a structurally different program")

    # scale mismatch -> mark every entry sharing that prefix incomparable
    skipped_prefixes: List[Tuple[str, ...]] = []
    for path, bval in bleaves.items():
        if path and path[-1] in _SCALE_KEYS and path in fleaves:
            if fleaves[path] != bval:
                skipped_prefixes.append(path[:-1])
                notes.append(
                    f"{name}: SKIP {_fmt(path[:-1]) or '<root>'} — "
                    f"{path[-1]} changed {bval} -> {fleaves[path]} "
                    "(different workload, not compared)")

    def skipped(path: Tuple[str, ...]) -> bool:
        return any(path[:len(p)] == p for p in skipped_prefixes)

    for path, bval in bleaves.items():
        if skipped(path) or not isinstance(bval, (int, float)) \
                or isinstance(bval, bool):
            continue
        leaf = path[-1]
        in_dict = len(path) >= 2
        fval = fleaves.get(path)
        if fval is None:
            notes.append(f"{name}: SKIP {_fmt(path)} — missing in fresh run")
            continue
        if leaf == "rounds_per_sec" or (
                in_dict and path[-2] in ("rounds_per_sec",
                                         "agg_rounds_per_sec")):
            floor = bval * (1.0 - tolerance)
            if fval < floor:
                regressions.append(
                    f"{name}: {_fmt(path)} regressed {bval:.3f} -> "
                    f"{fval:.3f} rounds/sec (floor {floor:.3f}, "
                    f"tolerance {tolerance:.0%})")
        elif leaf == "peak_rss_mb":
            pin = fresh.get("rss_pin_mb") or base.get("rss_pin_mb")
            if pin is not None and fval > pin:
                regressions.append(
                    f"{name}: {_fmt(path)} = {fval:.0f} MB exceeds the "
                    f"{pin:.0f} MB pin")
            cap = bval * (1.0 + rss_tolerance)
            if fval > cap:
                regressions.append(
                    f"{name}: {_fmt(path)} grew {bval:.0f} -> {fval:.0f} MB "
                    f"(cap {cap:.0f}, tolerance {rss_tolerance:.0%})")
        elif in_dict and path[-2] == "retraces":
            if fval != 0:
                regressions.append(
                    f"{name}: {_fmt(path)} = {fval} — fresh run retraced "
                    "(must be 0)")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory of committed bench_*.json baselines")
    ap.add_argument("--fresh", default="results",
                    help="directory of freshly produced bench_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional rounds/sec drop (default 0.5)")
    ap.add_argument("--rss-tolerance", type=float, default=0.3,
                    help="allowed fractional peak-RSS growth (default 0.3)")
    ap.add_argument("--manifest", action="store_true",
                    help="also cross-check run-manifest structural "
                         "signatures (fresh must match baseline exactly)")
    args = ap.parse_args()

    bdir, fdir = pathlib.Path(args.baseline), pathlib.Path(args.fresh)
    base_files = sorted(bdir.glob("bench_*.json"))
    if not base_files:
        print(f"compare: no bench_*.json baselines under {bdir}",
              file=sys.stderr)
        sys.exit(1)

    all_regressions: List[str] = []
    compared = 0
    for bpath in base_files:
        fpath = fdir / bpath.name
        if not fpath.exists():
            print(f"{bpath.name}: SKIP — no fresh counterpart under {fdir}")
            continue
        with open(bpath) as f:
            base = json.load(f)
        with open(fpath) as f:
            fresh = json.load(f)
        regs, notes = compare_file(bpath.name, base, fresh,
                                   tolerance=args.tolerance,
                                   rss_tolerance=args.rss_tolerance,
                                   manifest=args.manifest)
        compared += 1
        for line in notes:
            print(line)
        for line in regs:
            print(f"REGRESSION  {line}")
        if not regs:
            print(f"{bpath.name}: ok")
        all_regressions.extend(regs)

    if all_regressions:
        print(f"\ncompare: {len(all_regressions)} regression(s) across "
              f"{compared} file(s)", file=sys.stderr)
        sys.exit(1)
    print(f"\ncompare: no regressions across {compared} file(s)")


if __name__ == "__main__":
    main()
