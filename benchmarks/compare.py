"""Benchmark regression guard: diff fresh ``results/bench_*.json`` against
committed baselines and exit nonzero on a regression, so a perf cliff fails
CI instead of silently rewriting the numbers.

    # CI smoke: rerun the quick benchmarks, then diff against the committed
    # quick-mode baselines (results/ itself is gitignored — the blessed
    # numbers live in benchmarks/baselines/)
    PYTHONPATH=src python -m benchmarks.run --quick --only sweep,kscale
    PYTHONPATH=src python -m benchmarks.compare --fresh results

Three rule families, matched by leaf key name anywhere in the JSON tree:

* throughput (``rounds_per_sec`` scalars, and every lane of the
  ``rounds_per_sec`` / ``agg_rounds_per_sec`` dicts): the fresh number must
  be at least ``(1 - tolerance)`` of the baseline.  The default tolerance is
  generous (50%) because shared CI boxes are noisy — the guard exists to
  catch cliffs (a 2x regression from an accidental retrace or
  materialization), not single-digit drift.
* memory (``peak_rss_mb``): the fresh peak must stay under the sibling
  ``rss_pin_mb`` when the file carries one (the kscale flat-memory pin),
  and under ``(1 + rss_tolerance)`` of the baseline either way.
* retraces (any leaf under a ``retraces`` dict): must be 0 in the fresh run,
  unconditionally — retraces are deterministic, so there is no noise to
  tolerate.

Entries whose scale knobs disagree between the two files (``rounds``,
``grid``, ``devices`` — e.g. a quick-mode fresh run against a full-mode
baseline) are SKIPPED with a visible note rather than mis-compared; a
baseline file with no fresh counterpart is likewise reported.  Exit status:
0 = no regressions (skips allowed), 1 = at least one regression.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any, Dict, Iterator, List, Tuple

# scale knobs: when any of these differ at the SAME path in baseline/fresh,
# the surrounding entry is incomparable (different workload, not a
# regression)
_SCALE_KEYS = ("rounds", "grid", "devices", "k_block", "dim", "batch")


def _walk(tree: Any, path: Tuple[str, ...] = ()) -> Iterator[
        Tuple[Tuple[str, ...], Any]]:
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (str(k),))
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (str(i),))
    else:
        yield path, tree


def _fmt(path: Tuple[str, ...]) -> str:
    return "/".join(path)


def compare_file(name: str, base: Dict, fresh: Dict, *, tolerance: float,
                 rss_tolerance: float) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) for one benchmark JSON pair."""
    regressions: List[str] = []
    notes: List[str] = []
    bleaves = dict(_walk(base))
    fleaves = dict(_walk(fresh))

    # scale mismatch -> mark every entry sharing that prefix incomparable
    skipped_prefixes: List[Tuple[str, ...]] = []
    for path, bval in bleaves.items():
        if path and path[-1] in _SCALE_KEYS and path in fleaves:
            if fleaves[path] != bval:
                skipped_prefixes.append(path[:-1])
                notes.append(
                    f"{name}: SKIP {_fmt(path[:-1]) or '<root>'} — "
                    f"{path[-1]} changed {bval} -> {fleaves[path]} "
                    "(different workload, not compared)")

    def skipped(path: Tuple[str, ...]) -> bool:
        return any(path[:len(p)] == p for p in skipped_prefixes)

    for path, bval in bleaves.items():
        if skipped(path) or not isinstance(bval, (int, float)) \
                or isinstance(bval, bool):
            continue
        leaf = path[-1]
        in_dict = len(path) >= 2
        fval = fleaves.get(path)
        if fval is None:
            notes.append(f"{name}: SKIP {_fmt(path)} — missing in fresh run")
            continue
        if leaf == "rounds_per_sec" or (
                in_dict and path[-2] in ("rounds_per_sec",
                                         "agg_rounds_per_sec")):
            floor = bval * (1.0 - tolerance)
            if fval < floor:
                regressions.append(
                    f"{name}: {_fmt(path)} regressed {bval:.3f} -> "
                    f"{fval:.3f} rounds/sec (floor {floor:.3f}, "
                    f"tolerance {tolerance:.0%})")
        elif leaf == "peak_rss_mb":
            pin = fresh.get("rss_pin_mb") or base.get("rss_pin_mb")
            if pin is not None and fval > pin:
                regressions.append(
                    f"{name}: {_fmt(path)} = {fval:.0f} MB exceeds the "
                    f"{pin:.0f} MB pin")
            cap = bval * (1.0 + rss_tolerance)
            if fval > cap:
                regressions.append(
                    f"{name}: {_fmt(path)} grew {bval:.0f} -> {fval:.0f} MB "
                    f"(cap {cap:.0f}, tolerance {rss_tolerance:.0%})")
        elif in_dict and path[-2] == "retraces":
            if fval != 0:
                regressions.append(
                    f"{name}: {_fmt(path)} = {fval} — fresh run retraced "
                    "(must be 0)")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory of committed bench_*.json baselines")
    ap.add_argument("--fresh", default="results",
                    help="directory of freshly produced bench_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional rounds/sec drop (default 0.5)")
    ap.add_argument("--rss-tolerance", type=float, default=0.3,
                    help="allowed fractional peak-RSS growth (default 0.3)")
    args = ap.parse_args()

    bdir, fdir = pathlib.Path(args.baseline), pathlib.Path(args.fresh)
    base_files = sorted(bdir.glob("bench_*.json"))
    if not base_files:
        print(f"compare: no bench_*.json baselines under {bdir}",
              file=sys.stderr)
        sys.exit(1)

    all_regressions: List[str] = []
    compared = 0
    for bpath in base_files:
        fpath = fdir / bpath.name
        if not fpath.exists():
            print(f"{bpath.name}: SKIP — no fresh counterpart under {fdir}")
            continue
        with open(bpath) as f:
            base = json.load(f)
        with open(fpath) as f:
            fresh = json.load(f)
        regs, notes = compare_file(bpath.name, base, fresh,
                                   tolerance=args.tolerance,
                                   rss_tolerance=args.rss_tolerance)
        compared += 1
        for line in notes:
            print(line)
        for line in regs:
            print(f"REGRESSION  {line}")
        if not regs:
            print(f"{bpath.name}: ok")
        all_regressions.extend(regs)

    if all_regressions:
        print(f"\ncompare: {len(all_regressions)} regression(s) across "
              f"{compared} file(s)", file=sys.stderr)
        sys.exit(1)
    print(f"\ncompare: no regressions across {compared} file(s)")


if __name__ == "__main__":
    main()
