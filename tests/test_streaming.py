"""Streaming K-block engine tests (the K-scale path): blocked superposition
must reproduce the dense engine — bitwise where the reduction order is
unchanged (driver parity, participation accounting, active-set gather),
within documented ulp drift where it is not (blocked fp32 accumulation
re-associates the K-way sums, so trajectories diverge at the last bit per
round; ``STREAM_TOL`` bounds the compounding over a multi-round run).  Plus
the lazy per-block samplers (channel, geometry, participation, batches)
whose device-indexed key schedules must be invariant to how ``[0, K)`` is
blocked, and the (K-block, N-block) streaming kernels against their dense
counterparts.
"""
import dataclasses
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channels.geometry import GeometryConfig, relative_gains_block
from repro.core import ota
from repro.core.channel import (ChannelConfig, draw_channel_block,
                                draw_fading_state_block)
from repro.data.datasets import device_batches, split_dirichlet, synthetic_mnist
from repro.fed.runtime import FLConfig, run, setup
from repro.kernels import ops
from repro.models.simple import init_mlp_classifier, mlp_classifier_loss

K = 12
ROUNDS = 6

# Streaming-vs-dense trajectory tolerance: the blocked K-reduction is exact
# in VALUE terms but associates differently, so params pick up ~1 ulp per
# round and the gap compounds through the nonlinear round map.  Over the
# 6-round runs here the observed drift is < 1e-5 relative; 3e-4 leaves
# headroom without masking a real (order-of-magnitude) defect.
STREAM_TOL = dict(rtol=3e-4, atol=1e-6)


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_mnist(key, 600)
    split = split_dirichlet(jax.random.fold_in(key, 1), np.asarray(y), K, 1.0)
    params0 = init_mlp_classifier(jax.random.fold_in(key, 2), hidden=8)
    dim = sum(int(np.prod(np.asarray(l).shape))
              for l in jax.tree_util.tree_leaves(params0))
    xnp, ynp = np.asarray(x), np.asarray(y)

    def grad_fn(params, batch):
        xb, yb = batch
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def provider(t):
        idx = device_batches(jax.random.PRNGKey(3), split, 16, t)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    return dict(params0=params0, dim=dim, grad_fn=grad_fn, provider=provider,
                split=split, x=jnp.asarray(xnp), y=jnp.asarray(ynp))


def _cfg(backend="vmap", scheme="normalized", chan=None, **kw):
    channel = ChannelConfig(num_devices=K, channel_mean=1e-3, **(chan or {}))
    base = dict(num_devices=K, scheme=scheme, case="I", p=0.75,
                channel=channel, grad_bound=10.0, smoothness_L=5.0,
                expected_loss_drop=2.0, seed=0, backend=backend)
    base.update(kw)
    return FLConfig(**base)


def _go(task, cfg, rounds=ROUNDS, driver="scan", **kw):
    state = setup(cfg, task["params0"], task["dim"])
    return run(cfg, state, task["grad_fn"], kw.pop("provider",
                                                   task["provider"]),
               rounds, driver=driver, chunk_size=3, **kw)


def _leaves(state):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(state.params)]


class TestBlockSamplers:
    """The lazy samplers' device-indexed key schedules: any blocking of
    [0, K) must concatenate to the same draw, and a gathered subset must
    equal the full draw's gather — bitwise, that is the whole contract."""

    def test_fading_state_blocking_invariant(self):
        key = jax.random.PRNGKey(7)
        full = draw_fading_state_block(key, jnp.arange(64))
        for step in (8, 16, 32):
            parts = jnp.concatenate(
                [draw_fading_state_block(key, jnp.arange(lo, lo + step))  # tracelint: disable=TL002 blocking invariance: every block derives from ONE key via per-device fold_in
                 for lo in range(0, 64, step)])
            np.testing.assert_array_equal(np.asarray(parts), np.asarray(full))

    def test_channel_blocking_invariant_and_subset(self):
        key = jax.random.PRNGKey(7)
        cfg = ChannelConfig(num_devices=64)
        full = draw_channel_block(key, cfg, jnp.arange(64))
        parts = jnp.concatenate(
            [draw_channel_block(key, cfg, jnp.arange(lo, lo + 8))  # tracelint: disable=TL002 blocking invariance: every block derives from ONE key via per-device fold_in
             for lo in range(0, 64, 8)])
        np.testing.assert_array_equal(np.asarray(parts), np.asarray(full))
        idx = jnp.array([3, 17, 42])
        np.testing.assert_array_equal(
            np.asarray(draw_channel_block(key, cfg, idx)),  # tracelint: disable=TL002 subset gather reuses the key so full[idx] matches bitwise
            np.asarray(full[idx]))
        assert np.all(np.asarray(full) > 0.0)

    def test_geometry_gains_blocking_invariant(self):
        key = jax.random.PRNGKey(11)
        geo = GeometryConfig(shadowing_std_db=4.0)
        full = relative_gains_block(key, geo, jnp.arange(48))
        parts = jnp.concatenate(
            [relative_gains_block(key, geo, jnp.arange(lo, lo + 16))  # tracelint: disable=TL002 blocking invariance: every block derives from ONE key via per-device fold_in
             for lo in range(0, 48, 16)])
        np.testing.assert_array_equal(np.asarray(parts), np.asarray(full))
        assert np.all(np.isfinite(np.asarray(full)))
        assert np.all(np.asarray(full) > 0.0)


class TestStreamingKernels:
    """(K-block, N-block) streaming kernel launches vs the dense kernels on
    the same inputs — the XLA oracles on CPU, the Pallas interpreter pinned
    explicitly.  The streaming accumulators re-associate the K-way sum, so
    comparisons are allclose at fp32 resolution, not bitwise."""

    def setup_method(self, _):
        key = jax.random.PRNGKey(5)
        self.g = jax.random.normal(key, (8, 192), jnp.float32)
        self.scale = jax.random.uniform(jax.random.fold_in(key, 1), (8,))
        self.noise = jax.random.normal(jax.random.fold_in(key, 2), (192,))

    @pytest.mark.parametrize("kb", [2, 4, 8])
    def test_moments_match_dense(self, kb):
        d_sq, d_sum = ops.batched_moments(self.g)
        s_sq, s_sum = ops.batched_moments(self.g, k_block=kb)
        np.testing.assert_allclose(np.asarray(s_sq), np.asarray(d_sq),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s_sum), np.asarray(d_sum),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("pre", ["identity", "sign"])
    @pytest.mark.parametrize("kb", [2, 4])
    def test_superpose_matches_dense(self, pre, kb):
        dense = ops.ota_superpose(self.g, self.scale, self.noise, 0.5,
                                  pre=pre)
        stream = ops.ota_superpose(self.g, self.scale, self.noise, 0.5,
                                   pre=pre, k_block=kb)
        np.testing.assert_allclose(np.asarray(stream), np.asarray(dense),
                                   rtol=1e-5, atol=1e-6)

    def test_streaming_interpreter_matches_oracle(self):
        """The Pallas streaming kernels themselves (interpret=True) against
        the lax.scan oracles the CPU path runs."""
        o_sq, o_sum = ops.batched_moments(self.g, k_block=4)
        i_sq, i_sum = ops.batched_moments(self.g, k_block=4, interpret=True)
        np.testing.assert_allclose(np.asarray(i_sq), np.asarray(o_sq),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(i_sum), np.asarray(o_sum),
                                   rtol=1e-5, atol=1e-5)
        o_y = ops.ota_superpose(self.g, self.scale, self.noise, 0.5,
                                k_block=4)
        i_y = ops.ota_superpose(self.g, self.scale, self.noise, 0.5,
                                k_block=4, interpret=True)
        np.testing.assert_allclose(np.asarray(i_y), np.asarray(o_y),
                                   rtol=1e-5, atol=1e-6)

    def test_bad_k_block_raises(self):
        with pytest.raises(ValueError, match="divide"):
            ops.batched_moments(self.g, k_block=3)
        with pytest.raises(ValueError, match="divide"):
            ops.ota_superpose(self.g, self.scale, self.noise, 0.5, k_block=5)


class TestStreamingAggregate:
    """``core.ota.aggregate`` with ``OTAConfig.k_block`` vs the dense path,
    per scheme x backend, shared noise key."""

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("scheme", ["normalized", "normalized_per_tensor",
                                        "raw", "benchmark1", "benchmark2",
                                        "onebit", "mean", "clipped"])
    def test_matches_dense(self, backend, scheme):
        key = jax.random.PRNGKey(3)
        stacked = {
            "w": jax.random.normal(key, (8, 4, 5), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 7),
                                   jnp.float32),
        }
        h = jax.random.uniform(jax.random.fold_in(key, 2), (8,)) * 1e-3
        b = jnp.full((8,), 2.0)
        nkey = jax.random.fold_in(key, 3)
        mk = lambda kb: ota.OTAConfig(scheme=scheme, a=10.0, noise_var=1e-7,
                                      grad_bound=5.0, backend=backend,
                                      k_block=kb)
        dense = ota.aggregate(mk(None), stacked, h, b, nkey)
        stream = ota.aggregate(mk(4), stacked, h, b, nkey)  # tracelint: disable=TL002 streamed-vs-dense parity shares the noise key bitwise
        for d, s in zip(jax.tree_util.tree_leaves(dense),
                        jax.tree_util.tree_leaves(stream)):
            np.testing.assert_allclose(np.asarray(s), np.asarray(d),
                                       rtol=2e-5, atol=1e-6)

    def test_mesh_backend_rejected(self):
        with pytest.raises(ValueError, match="mesh"):
            ota.OTAConfig(scheme="normalized", a=1.0, backend="mesh",
                          k_block=4)


class TestStreamingRounds:
    """The streaming round (``FLConfig.k_block``) vs the dense round through
    the full engine: schemes x backends on the paper's fixed channel, then
    the wireless-environment axes (i.i.d. block fading, AR(1), imperfect
    CSI) — each env re-checks that the per-round channel refresh and the
    blocked superposition compose."""

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("scheme", ["normalized", "benchmark2", "onebit",
                                        "mean", "normalized_per_tensor"])
    def test_schemes_match_dense(self, task, backend, scheme):
        sd, hd = _go(task, _cfg(backend, scheme))
        ss, hs = _go(task, _cfg(backend, scheme, k_block=4))
        for d, s in zip(_leaves(sd), _leaves(ss)):
            np.testing.assert_allclose(s, d, **STREAM_TOL)
        np.testing.assert_array_equal(hd["num_participants"],
                                      hs["num_participants"])
        for k in ("grad_norm_min", "grad_norm_max", "grad_norm_mean",
                  "tx_energy"):
            np.testing.assert_allclose(hs[k], hd[k], rtol=1e-5, err_msg=k)

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("env", [
        {"block_fading": True},
        {"model": "ar1", "rho": 0.9},
        {"block_fading": True, "csi_error": 0.2},
    ], ids=["iid_fading", "ar1", "imperfect_csi"])
    def test_environments_match_dense(self, task, backend, env):
        sd, hd = _go(task, _cfg(backend, chan=env))
        ss, hs = _go(task, _cfg(backend, chan=env, k_block=4))
        for d, s in zip(_leaves(sd), _leaves(ss)):
            np.testing.assert_allclose(s, d, **STREAM_TOL)
        np.testing.assert_allclose(hs["csi_gain_err"], hd["csi_gain_err"],
                                   rtol=1e-4, atol=1e-7)

    def test_driver_parity_bitwise(self, task):
        """python and scan drivers trace the SAME streaming round: bitwise."""
        cfg = _cfg("vmap", k_block=3)
        sp, hp = _go(task, cfg, driver="python")
        ss, hs = _go(task, cfg, driver="scan")
        for p, s in zip(_leaves(sp), _leaves(ss)):
            np.testing.assert_array_equal(s, p)
        np.testing.assert_array_equal(hp["tx_energy"], hs["tx_energy"])

    @pytest.mark.parametrize("scheme", ["normalized", "mean"])
    def test_bernoulli_participation_matches_dense(self, task, scheme):
        """k_block + bernoulli masks: the lazy per-block mask draw must
        reproduce the dense [K] draw's accounting exactly (same key fold per
        device), with params at streaming tolerance."""
        sd, hd = _go(task, _cfg("vmap", scheme, participation=0.6))
        ss, hs = _go(task, _cfg("vmap", scheme, participation=0.6,
                                k_block=4))
        np.testing.assert_array_equal(hd["num_participants"],
                                      hs["num_participants"])
        np.testing.assert_allclose(hs["tx_energy"], hd["tx_energy"],
                                   rtol=1e-5)
        for d, s in zip(_leaves(sd), _leaves(ss)):
            np.testing.assert_allclose(s, d, **STREAM_TOL)

    def test_streaming_with_active_gather(self, task):
        """k_block composed with the fixed-mode active-set gather."""
        dense = _cfg("vmap", participation=0.5, participation_mode="fixed")
        sd, hd = _go(task, dense)
        sg, hg = _go(task, dataclasses.replace(dense, active_gather=True,
                                               k_block=3))
        for d, g in zip(_leaves(sd), _leaves(sg)):
            np.testing.assert_allclose(g, d, **STREAM_TOL)
        np.testing.assert_array_equal(hd["num_participants"],
                                      hg["num_participants"])

    def test_block_batch_provider_matches_dense_batches(self, task):
        """The lazy batch hook: gathering each K-block's batch in-trace from
        device indices is bitwise the pre-stacked dense batch."""
        cfg = _cfg("vmap", k_block=4)
        idx_stack = jnp.asarray(np.stack(
            [device_batches(jax.random.PRNGKey(3), task["split"], 16, t)
             for t in range(1, ROUNDS + 1)]))
        xj, yj = task["x"], task["y"]

        def block_provider(t, dev):
            rows = idx_stack[t - 1][dev]
            return (xj[rows], yj[rows])

        s1, _ = _go(task, cfg)
        state = setup(cfg, task["params0"], task["dim"])
        s2, _ = run(cfg, state, task["grad_fn"], None, ROUNDS, driver="scan",
                    chunk_size=3, block_batch_provider=block_provider)
        for a, b in zip(_leaves(s1), _leaves(s2)):
            np.testing.assert_array_equal(b, a)

    def test_k_block_validation(self, task):
        with pytest.raises(ValueError, match="divide"):
            _cfg("vmap", k_block=5)          # 5 does not divide K=12
        with pytest.raises(ValueError, match="mesh"):
            _cfg("mesh", k_block=4)
        with pytest.raises(ValueError, match="block_batch_provider"):
            run(_cfg("vmap"), setup(_cfg("vmap"), task["params0"],
                                    task["dim"]),
                task["grad_fn"], None, 1,
                block_batch_provider=lambda t, d: None)


@pytest.mark.slow
class TestKScaleSmoke:
    """The 100k-device no-OOM smoke: one streaming round at K = 100,000 in a
    fresh process (``benchmarks.kscale_case``), peak RSS asserted under the
    same absolute pin the benchmark guards — a dense [K, N] or [K, B, d]
    materialization anywhere in the streaming path blows straight past it."""

    def test_100k_round_flat_memory(self):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.kscale_case",
             "--devices", "100000", "--k-block", "1000", "--rounds", "1"],
            capture_output=True, text=True, timeout=540)
        assert out.returncode == 0, out.stderr[-2000:]
        payload = json.loads(out.stdout.strip().splitlines()[-1])
        assert payload["devices"] == 100_000
        assert np.isfinite(payload["grad_norm_mean_final"])
        assert payload["peak_rss_mb"] < 2048.0, payload
