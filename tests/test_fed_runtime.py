"""FL-runtime integration tests: the paper's Case-I task end to end, the
per-tensor-normalized beyond-paper variant, and block-fading operation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.data.datasets import device_batches, split_dirichlet, synthetic_mnist
from repro.fed.runtime import DIAG_KEYS, FLConfig, run, setup
from repro.models.simple import (init_mlp_classifier, mlp_classifier_accuracy,
                                 mlp_classifier_loss)

K = 10


@pytest.fixture(scope="module")
def mnist_task():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_mnist(key, 1500)
    x_tr, y_tr, x_te, y_te = x[:1200], y[:1200], x[1200:], y[1200:]
    split = split_dirichlet(jax.random.fold_in(key, 1), np.asarray(y_tr), K, 1.0)
    params0 = init_mlp_classifier(jax.random.fold_in(key, 2), hidden=32)
    dim = sum(int(np.prod(np.asarray(l).shape))
              for l in jax.tree_util.tree_leaves(params0))
    xnp, ynp = np.asarray(x_tr), np.asarray(y_tr)

    def grad_fn(params, batch):
        xb, yb = batch
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def provider(t):
        idx = device_batches(jax.random.PRNGKey(3), split, 32, t)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    def ev(params):
        return {"acc": float(mlp_classifier_accuracy(params, x_te, y_te))}

    return dict(params0=params0, dim=dim, grad_fn=grad_fn, provider=provider,
                ev=ev)


def _cfg(scheme="normalized", **kw):
    base = dict(num_devices=K, scheme=scheme, case="I", p=0.75,
                channel=ChannelConfig(num_devices=K, channel_mean=1e-3),
                grad_bound=10.0, smoothness_L=5.0, expected_loss_drop=2.0,
                seed=0)
    base.update(kw)
    return FLConfig(**base)


def _run(task, cfg, rounds=80):
    state = setup(cfg, task["params0"], task["dim"])
    return run(cfg, state, task["grad_fn"], task["provider"], rounds,
               task["ev"], eval_every=rounds)


class TestCaseIEndToEnd:
    def test_accuracy_improves_over_chance(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized"))
        assert hist["acc"][-1] > 0.5      # 10-class chance = 0.1

    def test_per_tensor_variant_trains(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized_per_tensor"))
        assert hist["acc"][-1] > 0.5

    def test_block_fading_reoptimizes_and_trains(self, mnist_task):
        chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                             block_fading=True)
        _, hist = _run(mnist_task, _cfg("normalized", channel=chan))
        assert hist["acc"][-1] > 0.5

    def test_eta_schedule_is_paper_case1(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized"), rounds=20)
        for t, e in zip(hist["round"], hist["eta"]):
            assert abs(e - t ** -0.75) < 1e-5

    def test_all_schemes_train(self, mnist_task):
        """Every aggregation scheme learns on the Case-I task.  (Relative
        orderings are horizon- and task-dependent; they are *reported* by the
        fig1b/fig2b benchmarks rather than asserted here — see EXPERIMENTS.md
        §Faithfulness.)"""
        for scheme in ("onebit", "benchmark2"):
            _, hist = _run(mnist_task, _cfg(scheme), rounds=60)
            assert hist["acc"][-1] > 0.3, scheme


class TestConfigValidation:
    """Satellite: FLConfig.__post_init__ used to validate only `backend` —
    a typo'd scheme surfaced as a deep KeyError mid-trace.  Every enum-ish
    field now fails at construction with a message naming the options."""

    def test_unknown_scheme_names_registry(self):
        with pytest.raises(ValueError, match="unknown scheme 'normalised'"):
            _cfg("normalised")

    def test_unknown_backend(self):
        with pytest.raises(ValueError, match="unknown backend"):
            _cfg(backend="tpu")

    def test_unknown_case(self):
        with pytest.raises(ValueError, match=r"unknown case 'III'.*'I', 'II'"):
            _cfg(case="III")

    def test_unknown_amplification(self):
        with pytest.raises(ValueError,
                           match="unknown amplification 'bmin'"):
            _cfg(amplification="bmin")

    def test_unknown_server_opt(self):
        with pytest.raises(ValueError, match="unknown server_opt 'lion'"):
            _cfg(server_opt="lion")

    def test_bad_local_steps(self):
        with pytest.raises(ValueError, match="local_steps"):
            _cfg(local_steps=0)

    def test_bad_participation(self):
        for p in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError, match="participation"):
                _cfg(participation=p)
        with pytest.raises(ValueError, match="participation_mode"):
            _cfg(participation_mode="roundrobin")

    def test_valid_config_still_builds(self):
        cfg = _cfg("clipped", server_opt="adamw", local_steps=4,
                   participation=0.25, participation_mode="fixed")
        assert cfg.scheme == "clipped"


class TestEvalHistoryAlignment:
    """Satellite: record_eval's setdefault-append silently misaligned a
    metric list with hist['eval_round'] when eval_fn returned a key only on
    some rounds.  The key set locks on the first eval; divergence raises."""

    @pytest.mark.parametrize("driver", ["python", "scan"])
    def test_ragged_eval_keys_raise(self, mnist_task, driver):
        calls = {"n": 0}

        def ragged_ev(params):
            calls["n"] += 1
            if calls["n"] == 1:
                return {"acc": 0.5}
            return {"acc": 0.5, "extra": 1.0}    # new key mid-run

        cfg = _cfg("normalized")
        state = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        with pytest.raises(ValueError, match="locked"):
            run(cfg, state, mnist_task["grad_fn"], mnist_task["provider"],
                8, ragged_ev, eval_every=4, driver=driver)

    @pytest.mark.parametrize("driver", ["python", "scan"])
    def test_aligned_eval_keys_stay_aligned(self, mnist_task, driver):
        cfg = _cfg("normalized")
        state = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        _, hist = run(cfg, state, mnist_task["grad_fn"],
                      mnist_task["provider"], 8, mnist_task["ev"],
                      eval_every=4, driver=driver)
        assert len(hist["acc"]) == len(hist["eval_round"]) == 3  # t=1,4,8


class TestHistoryAccounting:
    """Satellite: update_norm and tx_energy were computed every round but
    never recorded — every per-round history key must grow by num_rounds,
    on both drivers."""

    @pytest.mark.parametrize("driver", ["python", "scan"])
    def test_every_key_grows_by_num_rounds(self, mnist_task, driver):
        rounds = 7
        cfg = _cfg("normalized")
        state = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        _, hist = run(cfg, state, mnist_task["grad_fn"],
                      mnist_task["provider"], rounds, driver=driver)
        assert "update_norm" in DIAG_KEYS and "tx_energy" in DIAG_KEYS
        for key in ("round",) + DIAG_KEYS:
            assert len(hist[key]) == rounds, key
        # normalized scheme: ||x_k|| = 1, so tx energy is sum_k b_k^2 exactly
        want = float(np.sum(np.square(state.b)))
        np.testing.assert_allclose(hist["tx_energy"], want, rtol=1e-4)
        assert all(v > 0 for v in hist["update_norm"])


class TestBlockFadingStatePersistence:
    """Satellite: run() used to mutate local h/b/a and drop them — a second
    run resumed from the stale round-0 channel.  The final values must be
    written back to FLState and resume must continue the trajectory."""

    def _fading_cfg(self):
        chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                             block_fading=True)
        return _cfg("normalized", channel=chan)

    @pytest.mark.parametrize("driver", ["python", "scan"])
    def test_final_channel_persisted(self, mnist_task, driver):
        cfg = self._fading_cfg()
        state = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        h0, b0, a0 = state.h.copy(), state.b.copy(), state.a
        state, _ = run(cfg, state, mnist_task["grad_fn"],
                       mnist_task["provider"], 5, driver=driver)
        assert state.round == 5
        assert not np.allclose(state.h, h0)   # round-5 draw, not round-0
        assert state.a != a0
        # the optimized effective gain a*sum(h b) is preserved by re-solving
        np.testing.assert_allclose(state.a * np.sum(state.h * state.b),
                                   a0 * np.sum(h0 * b0), rtol=1e-5)

    @pytest.mark.parametrize("driver", ["python", "scan"])
    def test_resume_matches_single_run(self, mnist_task, driver):
        cfg = self._fading_cfg()
        one = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        one, hist_one = run(cfg, one, mnist_task["grad_fn"],
                            mnist_task["provider"], 10, driver=driver)
        two = setup(cfg, mnist_task["params0"], mnist_task["dim"])
        two, _ = run(cfg, two, mnist_task["grad_fn"],
                     mnist_task["provider"], 5, driver=driver)
        two, hist_two = run(cfg, two, mnist_task["grad_fn"],
                            mnist_task["provider"], 5, driver=driver)
        assert two.round == 10
        assert hist_two["round"] == list(range(6, 11))
        for a, b in zip(jax.tree_util.tree_leaves(one.params),
                        jax.tree_util.tree_leaves(two.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(hist_one["grad_norm_mean"][5:],
                                   hist_two["grad_norm_mean"], rtol=1e-4)
