"""FL-runtime integration tests: the paper's Case-I task end to end, the
per-tensor-normalized beyond-paper variant, and block-fading operation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.data.datasets import device_batches, split_dirichlet, synthetic_mnist
from repro.fed.runtime import FLConfig, run, setup
from repro.models.simple import (init_mlp_classifier, mlp_classifier_accuracy,
                                 mlp_classifier_loss)

K = 10


@pytest.fixture(scope="module")
def mnist_task():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_mnist(key, 1500)
    x_tr, y_tr, x_te, y_te = x[:1200], y[:1200], x[1200:], y[1200:]
    split = split_dirichlet(jax.random.fold_in(key, 1), np.asarray(y_tr), K, 1.0)
    params0 = init_mlp_classifier(jax.random.fold_in(key, 2), hidden=32)
    dim = sum(int(np.prod(np.asarray(l).shape))
              for l in jax.tree_util.tree_leaves(params0))
    xnp, ynp = np.asarray(x_tr), np.asarray(y_tr)

    def grad_fn(params, batch):
        xb, yb = batch
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def provider(t):
        idx = device_batches(jax.random.PRNGKey(3), split, 32, t)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    def ev(params):
        return {"acc": float(mlp_classifier_accuracy(params, x_te, y_te))}

    return dict(params0=params0, dim=dim, grad_fn=grad_fn, provider=provider,
                ev=ev)


def _cfg(scheme="normalized", **kw):
    base = dict(num_devices=K, scheme=scheme, case="I", p=0.75,
                channel=ChannelConfig(num_devices=K, channel_mean=1e-3),
                grad_bound=10.0, smoothness_L=5.0, expected_loss_drop=2.0,
                seed=0)
    base.update(kw)
    return FLConfig(**base)


def _run(task, cfg, rounds=80):
    state = setup(cfg, task["params0"], task["dim"])
    return run(cfg, state, task["grad_fn"], task["provider"], rounds,
               task["ev"], eval_every=rounds)


class TestCaseIEndToEnd:
    def test_accuracy_improves_over_chance(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized"))
        assert hist["acc"][-1] > 0.5      # 10-class chance = 0.1

    def test_per_tensor_variant_trains(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized_per_tensor"))
        assert hist["acc"][-1] > 0.5

    def test_block_fading_reoptimizes_and_trains(self, mnist_task):
        chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                             block_fading=True)
        _, hist = _run(mnist_task, _cfg("normalized", channel=chan))
        assert hist["acc"][-1] > 0.5

    def test_eta_schedule_is_paper_case1(self, mnist_task):
        _, hist = _run(mnist_task, _cfg("normalized"), rounds=20)
        for t, e in zip(hist["round"], hist["eta"]):
            assert abs(e - t ** -0.75) < 1e-5

    def test_all_schemes_train(self, mnist_task):
        """Every aggregation scheme learns on the Case-I task.  (Relative
        orderings are horizon- and task-dependent; they are *reported* by the
        fig1b/fig2b benchmarks rather than asserted here — see EXPERIMENTS.md
        §Faithfulness.)"""
        for scheme in ("onebit", "benchmark2"):
            _, hist = _run(mnist_task, _cfg(scheme), rounds=60)
            assert hist["acc"][-1] > 0.3, scheme
