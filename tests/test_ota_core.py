"""Unit + property tests for the paper's aggregation schemes (core/ota.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional: the compat module skips only @given tests
# when it is missing instead of failing collection for the whole file
from hypothesis_compat import given, settings, st

from repro.core import (OTAConfig, aggregate, apply_update, device_transform,
                        per_device_norm, per_device_mean_std, superpose,
                        transmit_norms, tree_num_elements)

KEY = jax.random.PRNGKey(0)


def stacked_grads(key, k=5, shapes=((8, 4), (16,), (3, 2, 2))):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(ki, (k,) + s)
            for i, (ki, s) in enumerate(zip(keys, shapes))}


class TestDeviceTransforms:
    def test_normalized_has_unit_norm_always(self):
        """The paper's core claim about eq. (12): ||x_k|| == 1 for every
        device at every round, no matter the gradient scale."""
        for scale in (1e-6, 1.0, 1e6):
            g = jax.tree_util.tree_map(lambda l: l * scale, stacked_grads(KEY))
            norms = transmit_norms("normalized", g)
            np.testing.assert_allclose(np.asarray(norms), 1.0, rtol=1e-4)

    def test_normalized_elementwise_bounded_by_one(self):
        g = stacked_grads(KEY)
        x, _ = device_transform("normalized", g)
        for leaf in jax.tree_util.tree_leaves(x):
            assert float(jnp.max(jnp.abs(leaf))) <= 1.0 + 1e-6

    def test_benchmark1_wastes_headroom(self):
        """Under the conservative max-norm assumption, the transmit norm is
        ||g||/G << 1 when gradients shrink — the motivation of the paper."""
        g = stacked_grads(KEY)
        big_G = 100.0
        norms = transmit_norms("benchmark1", g, big_G)
        true = per_device_norm(g)
        np.testing.assert_allclose(np.asarray(norms), np.asarray(true) / big_G,
                                   rtol=1e-5)
        assert float(jnp.max(norms)) < 0.2

    def test_benchmark2_energy_fair_unit_norm(self):
        """The raw standardization of [13] gives ||x|| = sqrt(N) (the paper's
        unboundedness critique); our energy-fair implementation rescales to
        unit norm so all schemes share the same transmit budget
        (EXPERIMENTS.md §Faithfulness)."""
        g = stacked_grads(KEY)
        norms = transmit_norms("benchmark2", g)
        np.testing.assert_allclose(np.asarray(norms), 1.0, rtol=1e-3)

    def test_onebit_unit_norm(self):
        g = stacked_grads(KEY)
        norms = transmit_norms("onebit", g)
        np.testing.assert_allclose(np.asarray(norms), 1.0, rtol=1e-5)

    def test_mean_std_match_numpy(self):
        g = stacked_grads(KEY, k=3)
        mean, std = per_device_mean_std(g)
        flat = np.concatenate([np.asarray(l).reshape(3, -1)
                               for l in jax.tree_util.tree_leaves(g)], axis=1)
        np.testing.assert_allclose(np.asarray(mean), flat.mean(1), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(std), flat.std(1), rtol=1e-4)


class TestSuperposition:
    def test_noiseless_superposition_is_weighted_sum(self):
        g = stacked_grads(KEY, k=4)
        h = jnp.asarray([1.0, 2.0, 3.0, 4.0])
        b = jnp.asarray([1.0, 0.5, 1.0, 0.25])
        y = superpose(g, h, b, a=2.0, key=None, noise_var=0.0)
        want = jax.tree_util.tree_map(
            lambda l: 2.0 * jnp.tensordot(h * b, l, axes=(0, 0)), g)
        for got, exp in zip(jax.tree_util.tree_leaves(y),
                            jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5)

    def test_noise_statistics(self):
        """Received noise is a * z with per-coordinate variance a^2 sigma^2."""
        g = {"p": jnp.zeros((1, 20000))}
        h = jnp.ones((1,))
        b = jnp.zeros((1,))          # kill the signal; only noise remains
        a, noise_var = 3.0, 0.25
        y = superpose(g, h, b, a=a, key=KEY, noise_var=noise_var)["p"]
        emp_var = float(jnp.var(y))
        assert abs(emp_var - a * a * noise_var) / (a * a * noise_var) < 0.05

    def test_mean_scheme_is_plain_average(self):
        g = stacked_grads(KEY, k=4)
        cfg = OTAConfig(scheme="mean")
        y = aggregate(cfg, g, jnp.ones(4), jnp.ones(4))
        want = jax.tree_util.tree_map(lambda l: jnp.mean(l, 0), g)
        for got, exp in zip(jax.tree_util.tree_leaves(y),
                            jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6)

    def test_onebit_output_is_sign(self):
        g = stacked_grads(KEY, k=4)
        cfg = OTAConfig(scheme="onebit", a=1.0, noiseless=True)
        y = aggregate(cfg, g, jnp.ones(4), jnp.ones(4), KEY)
        for leaf in jax.tree_util.tree_leaves(y):
            vals = np.unique(np.asarray(leaf))
            assert set(vals.tolist()) <= {-1.0, 0.0, 1.0}

    def test_benchmark2_exact_when_stats_equal(self):
        """With identical per-device mean/std the de-standardization is exact:
        aggregate == weighted mean of gradients (a = 1/sum hb)."""
        base = stacked_grads(KEY, k=1)
        g = jax.tree_util.tree_map(lambda l: jnp.repeat(l, 4, 0), base)
        h = jnp.asarray([1.0, 2.0, 0.5, 1.5])
        b = jnp.ones((4,))
        a = 1.0 / float(jnp.sum(h * b))
        cfg = OTAConfig(scheme="benchmark2", a=a, noiseless=True)
        y = aggregate(cfg, g, h, b, KEY)
        want = jax.tree_util.tree_map(lambda l: l[0], g)
        for got, exp in zip(jax.tree_util.tree_leaves(y),
                            jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                       rtol=1e-4, atol=1e-5)


class TestUpdateRule:
    def test_apply_update_matches_eq11(self):
        params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
        y = {"w": jnp.full((4,), 2.0), "b": jnp.ones((2,))}
        new = apply_update(params, y, 0.5)
        np.testing.assert_allclose(np.asarray(new["w"]), 0.0)
        np.testing.assert_allclose(np.asarray(new["b"]), -0.5)


class TestParticipationFold:
    def test_masked_devices_get_zero_weight_and_energy(self):
        from repro.core import participation_fold, transmit_energy
        h = jnp.asarray([1.0, 2.0, 3.0, 4.0])
        b = jnp.asarray([2.0, 2.0, 2.0, 2.0])
        mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        b_eff, a_eff = participation_fold(h, b, 1.0, mask)
        np.testing.assert_allclose(np.asarray(b_eff), [2.0, 0.0, 2.0, 0.0])
        g = stacked_grads(KEY, k=4)
        e = transmit_energy("normalized", g, b_eff, mask=mask)
        np.testing.assert_allclose(np.asarray(e), [4.0, 0.0, 4.0, 0.0],
                                   rtol=1e-5)

    def test_effective_gain_is_preserved(self):
        """The server rescales a so a*sum(h b) over participants equals the
        full-cohort design value (what the convergence bounds see)."""
        from repro.core import participation_fold
        h = jnp.asarray([1.0, 2.0, 3.0])
        b = jnp.asarray([0.5, 1.0, 1.5])
        mask = jnp.asarray([0.0, 1.0, 1.0])
        b_eff, a_eff = participation_fold(h, b, 0.25, mask)
        np.testing.assert_allclose(float(a_eff * jnp.sum(h * b_eff)),
                                   0.25 * float(jnp.sum(h * b)), rtol=1e-6)

    def test_empty_round_zeroes_the_gain(self):
        from repro.core import participation_fold
        h = jnp.asarray([1.0, 2.0])
        b = jnp.asarray([1.0, 1.0])
        _, a_eff = participation_fold(h, b, 5.0, jnp.zeros(2))
        assert float(a_eff) == 0.0


@settings(max_examples=25, deadline=None)
@given(k=st.integers(2, 8), scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
def test_property_normalization_scale_invariant(k, scale, seed):
    """Hypothesis invariant: the normalized transmit signal is invariant to
    uniform gradient rescaling (what frees b_k from the worst-case G)."""
    g = stacked_grads(jax.random.PRNGKey(seed), k=k)
    g_scaled = jax.tree_util.tree_map(lambda l: l * scale, g)
    x1, _ = device_transform("normalized", g)
    x2, _ = device_transform("normalized", g_scaled)
    for a_, b_ in zip(jax.tree_util.tree_leaves(x1), jax.tree_util.tree_leaves(x2)):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-3, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.integers(2, 6))
def test_property_superposition_linearity(seed, k):
    """psum-style superposition is linear in each device's signal."""
    key = jax.random.PRNGKey(seed)
    g = stacked_grads(key, k=k)
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (k,))) + 0.1
    b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (k,))) + 0.1
    y1 = superpose(g, h, b, 1.0, None, 0.0)
    g2 = jax.tree_util.tree_map(lambda l: 2.0 * l, g)
    y2 = superpose(g2, h, b, 1.0, None, 0.0)
    for a_, b_ in zip(jax.tree_util.tree_leaves(y1), jax.tree_util.tree_leaves(y2)):
        np.testing.assert_allclose(2 * np.asarray(a_), np.asarray(b_), rtol=1e-4)
