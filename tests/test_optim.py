"""Optimizer-substrate tests (satellite: the module is the FL server's
pluggable optimizer now — load-bearing): adamw against a hand-rolled
reference with explicit bias correction, schedule values, sgd+momentum
trajectories, and the per-call ``lr`` override the FL runtime drives the
paper's eta_t schedules through."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (adamw, constant_schedule, cosine_schedule,
                                    inverse_power_schedule, sgd)


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


class TestAdamWReference:
    """adamw vs a float64 numpy reference implementing the textbook update
    m_t = b1 m + (1-b1) g;  v_t = b2 v + (1-b2) g^2;
    w -= lr * ( (m_t / (1-b1^t)) / (sqrt(v_t / (1-b2^t)) + eps) + wd * w )."""

    B1, B2, EPS, WD, LR = 0.9, 0.95, 1e-8, 0.01, 3e-3

    def _reference(self, w0, grads_seq):
        w = np.asarray(w0, np.float64).copy()
        m = np.zeros_like(w)
        v = np.zeros_like(w)
        for t, g in enumerate(grads_seq, start=1):
            g = np.asarray(g, np.float64)
            m = self.B1 * m + (1 - self.B1) * g
            v = self.B2 * v + (1 - self.B2) * g * g
            mhat = m / (1 - self.B1 ** t)
            vhat = v / (1 - self.B2 ** t)
            w = w - self.LR * (mhat / (np.sqrt(vhat) + self.EPS)
                               + self.WD * w)
        return w

    def test_matches_handrolled_reference(self):
        rng = np.random.default_rng(0)
        w0 = rng.normal(size=(7,)).astype(np.float32)
        grads_seq = [rng.normal(size=(7,)).astype(np.float32)
                     for _ in range(12)]
        opt = adamw(self.LR, b1=self.B1, b2=self.B2, eps=self.EPS,
                    weight_decay=self.WD)
        p = {"w": jnp.asarray(w0)}
        s = opt.init(p)
        for g in grads_seq:
            p, s = opt.update({"w": jnp.asarray(g)}, s, p)
        np.testing.assert_allclose(np.asarray(p["w"]),
                                   self._reference(w0, grads_seq),
                                   rtol=2e-5, atol=1e-7)
        assert int(s.step) == len(grads_seq)

    def test_bias_correction_first_step(self):
        """At t=1 the corrected moments equal g and g^2 exactly, so the step
        is -lr * g / (|g| + eps) regardless of b1/b2 (the whole point of
        bias correction; an uncorrected implementation would take a step
        (1-b1)/sqrt(1-b2) ~ 0.45x too small here)."""
        opt = adamw(self.LR, b1=self.B1, b2=self.B2, eps=self.EPS)
        g = np.asarray([0.5, -2.0, 1e-3], np.float32)
        p = {"w": jnp.zeros((3,))}
        p2, _ = opt.update({"w": jnp.asarray(g)}, opt.init(p), p)
        want = -self.LR * g / (np.abs(g) + self.EPS)
        np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)

    def test_lr_override_wins(self):
        opt = adamw(123.0)   # constructor lr is ignored when lr= is passed
        p = {"w": jnp.zeros((2,))}
        g = {"w": jnp.ones((2,))}
        p2, _ = opt.update(g, opt.init(p), p, lr=self.LR)
        np.testing.assert_allclose(np.asarray(p2["w"]), -self.LR, rtol=1e-5)


class TestSGD:
    def test_momentum_trajectory(self):
        """Heavy-ball: m_t = mu m_{t-1} + g, w -= lr m_t, checked over 4
        steps against the closed-form partial sums."""
        mu, lr = 0.8, 0.1
        opt = sgd(lr, momentum=mu)
        p = {"w": jnp.zeros(())}
        s = opt.init(p)
        m_ref, w_ref = 0.0, 0.0
        for _ in range(4):
            p, s = opt.update({"w": jnp.ones(())}, s, p)
            m_ref = mu * m_ref + 1.0
            w_ref -= lr * m_ref
            np.testing.assert_allclose(float(p["w"]), w_ref, rtol=1e-6)

    def test_lr_override_matches_legacy_eq11(self):
        """sgd(momentum=0) with an explicit per-call lr IS the paper's
        eq. 11, w <- w - eta y — bitwise, which the FL runtime's legacy
        parity relies on."""
        from repro.core.ota import apply_update
        rng = np.random.default_rng(1)
        p = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
        y = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
        eta = jnp.asarray(0.037, jnp.float32)
        opt = sgd(999.0)
        got, _ = opt.update(y, opt.init(p), p, lr=eta)
        want = apply_update(p, y, eta)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(want["w"]))


class TestSchedules:
    def test_inverse_power_values(self):
        sched = inverse_power_schedule(0.75, eta0=2.0)
        for t in (1, 3, 17, 400):
            np.testing.assert_allclose(float(sched(jnp.asarray(t))),
                                       2.0 * t ** -0.75, rtol=1e-6)
        # step 0 clamps to t=1 (schedules are 1-indexed like the paper)
        np.testing.assert_allclose(float(sched(jnp.asarray(0))), 2.0,
                                   rtol=1e-6)

    def test_inverse_power_rejects_bad_p(self):
        for p in (0.5, 1.0, 0.2):
            with pytest.raises(ValueError):
                inverse_power_schedule(p)

    def test_constant(self):
        sched = constant_schedule(0.01)
        for t in (0, 1, 1000):
            assert float(sched(jnp.asarray(t))) == pytest.approx(0.01)

    def test_cosine_values(self):
        peak, warmup, total, floor = 1.0, 10, 110, 0.1
        sched = cosine_schedule(peak, warmup, total, floor)
        # linear warmup
        np.testing.assert_allclose(float(sched(jnp.asarray(5))), 0.5,
                                   rtol=1e-6)
        # midpoint of the cosine leg: (peak + floor) / 2
        np.testing.assert_allclose(float(sched(jnp.asarray(60))),
                                   (peak + floor) / 2, rtol=1e-5)
        # quarter point: floor + (peak-floor) * (1 + cos(pi/4)) / 2
        want = floor + (peak - floor) * (1 + math.cos(math.pi / 4)) / 2
        np.testing.assert_allclose(float(sched(jnp.asarray(35))), want,
                                   rtol=1e-5)
        # past total: clamped at the floor
        np.testing.assert_allclose(float(sched(jnp.asarray(500))), floor,
                                   rtol=1e-5)
