"""Optimality tests for the paper's system-parameter optimization (Sec. IV)."""
import math

import numpy as np
import pytest
# hypothesis is optional: the compat module skips only @given tests
# when it is missing instead of failing collection for the whole file
from hypothesis_compat import given, settings, st

from repro.core import (case1_receiver_gain, optimal_S, optimize_case1,
                        optimize_case2, problem3_objective, solve_problem3,
                        solve_problem6)


def rayleigh(seed, k, mean=1e-3):
    rng = np.random.default_rng(seed)
    return rng.rayleigh(mean / math.sqrt(math.pi / 2), k)


class TestProblem3:
    def test_beats_brute_force(self):
        """Bisection+convex (Algorithm 1) must match 20k-point random search."""
        h = rayleigh(0, 20)
        b_max = math.sqrt(5)
        sol = solve_problem3(h, 1e-7, 1000, b_max)
        rng = np.random.default_rng(1)
        best = problem3_objective(np.full(20, b_max), h, 1e-7, 1000)
        for _ in range(20000):
            b = rng.uniform(0, b_max, 20)
            best = min(best, problem3_objective(b, h, 1e-7, 1000))
        assert sol.Z <= best * (1 + 1e-6)

    def test_noise_free_interior_structure(self):
        """With c -> 0 the optimum equalizes h_k b_k (waterfilling-like):
        b_k ~ 1/h_k capped at b_max."""
        h = np.array([1.0, 2.0, 4.0])
        sol = solve_problem3(h, 1e-12, 1, b_max=10.0)
        hb = h * sol.b
        assert np.std(hb) / np.mean(hb) < 0.05

    def test_noise_dominated_corner(self):
        """When the noise term dominates, every b_k sits at its cap (Sec. V
        regime: maximize received signal power)."""
        h = rayleigh(2, 10, mean=1e-5)
        sol = solve_problem3(h, 1e-3, 100000, b_max=2.0)
        np.testing.assert_allclose(sol.b, 2.0, rtol=1e-3)

    def test_noiseless_channel_well_posed(self):
        """sigma^2 = 0 (the benchmark's noiseless configs): the vanishing
        noise floor keeps the bisection away from the degenerate b = 0 point
        and the solution keeps the noise-free equalizing structure."""
        h = rayleigh(10, 6)
        sol = solve_problem3(h, 0.0, 1000, 2.0)
        assert np.isfinite(sol.Z) and sol.Z > 0
        hb = h * sol.b
        assert np.std(hb) / np.mean(hb) < 0.05

    def test_z_positive_and_consistent(self):
        h = rayleigh(3, 8)
        sol = solve_problem3(h, 1e-7, 500, 2.0)
        assert sol.Z > 0
        np.testing.assert_allclose(
            sol.Z, problem3_objective(sol.b, h, 1e-7, 500), rtol=1e-9)
        np.testing.assert_allclose(sol.Z, sol.r_star ** 2, rtol=1e-9)

    def test_problem6_feasibility_crosscheck(self):
        """Literal Problem 6 (SLSQP) agrees with the value-form feasibility
        test at r slightly above/below r*."""
        h = rayleigh(4, 6)
        b_max = np.full(6, 1.5)
        sol = solve_problem3(h, 1e-7, 200, b_max)
        v_hi, _ = solve_problem6(sol.r_star * 1.05, h, 1e-7, 200, b_max)
        v_lo, _ = solve_problem6(sol.r_star * 0.8, h, 1e-7, 200, b_max)
        assert v_hi <= 1e-6          # feasible above r*
        assert v_lo > 0.0            # infeasible below r*


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 12),
       log_noise=st.floats(-9, -4))
def test_property_problem3_optimality(seed, k, log_noise):
    """Hypothesis: solver never loses to 2000 random feasible points."""
    h = rayleigh(seed, k)
    noise = 10.0 ** log_noise
    b_max = 2.0
    sol = solve_problem3(h, noise, 100, b_max)
    rng = np.random.default_rng(seed + 1)
    for _ in range(2000):
        b = rng.uniform(0, b_max, k)
        if (h * b).sum() <= 0:
            continue
        assert sol.Z <= problem3_objective(b, h, noise, 100) * (1 + 1e-6)


class TestCaseParameters:
    def test_optimal_S_formula(self):
        S = optimal_S(Z=3.0, L=2.0, p=0.75, expected_loss_drop=4.0)
        want = math.sqrt(2.0 * 4.0 * 0.75 / (0.5 * 4.0))
        assert abs(S - want) < 1e-12

    def test_case1_gain_inverse(self):
        h = rayleigh(5, 4)
        sol = solve_problem3(h, 1e-7, 50, 1.0)
        a = case1_receiver_gain(2.0, h, sol.b)
        assert abs(a * 2.0 * (h * sol.b).sum() - 1.0) < 1e-9

    def test_case2_epsilon_to_s_roundtrip(self):
        h = rayleigh(6, 8)
        p = optimize_case2(h, 1e-7, 100, 1.5, L=2.0, M=0.5, G=10.0,
                           theta_th=math.pi / 3, epsilon=0.05)
        assert 0.0 < p.s < 1.0
        assert abs(p.bias_bound - 0.05) < 1e-6

    def test_case2_tradeoff_monotone(self):
        """Remark 2: larger s (slower contraction) => lower bias floor."""
        h = rayleigh(7, 8)
        common = dict(L=2.0, M=0.5, G=10.0, theta_th=math.pi / 3)
        floors = [optimize_case2(h, 1e-7, 100, 1.5, s=s, **common).bias_bound
                  for s in (0.9, 0.99, 0.999)]
        assert floors[0] > floors[1] > floors[2]

    def test_case1_full_pipeline(self):
        h = rayleigh(8, 10)
        p = optimize_case1(h, 1e-7, 1000, math.sqrt(5), L=1.0, p=0.75,
                           expected_loss_drop=2.0)
        assert p.a > 0 and p.S > 0 and p.Z > 0
        assert np.all(p.b >= 0) and np.all(p.b <= math.sqrt(5) + 1e-9)

    def test_bad_inputs_raise(self):
        with pytest.raises(ValueError):
            optimal_S(1.0, 1.0, p=0.4, expected_loss_drop=1.0)
        with pytest.raises(ValueError):
            optimize_case2(rayleigh(9, 4), 1e-7, 10, 1.0, L=1, M=1, G=1,
                           theta_th=1.0)  # neither s nor epsilon
