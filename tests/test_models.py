"""Per-architecture smoke tests (deliverable (f)) + model correctness:
prefill-vs-decode agreement, SWA masking, MoE dispatch exactness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import (ARCH_IDS, get_config, make_dummy_inputs,
                                    reduce_config)
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
SMOKE_TRAIN = InputShape("smoke_train", 64, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        """Reduced variant (<=4 layers, d_model<=512, <=4 experts): one
        forward + one SGD step on CPU; asserts shapes and no NaNs."""
        cfg = reduce_config(get_config(arch))
        assert cfg.d_model <= 512 and cfg.num_layers <= 4
        if cfg.is_moe:
            assert cfg.num_experts <= 4
        params = T.init_params(cfg, KEY)
        batch = make_dummy_inputs(cfg, SMOKE_TRAIN)
        if "labels" not in batch:
            batch["labels"] = batch["tokens"]

        loss, metrics = jax.jit(
            lambda p, b: T.forward_loss(p, cfg, b))(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))

        grads = jax.grad(lambda p: T.forward_loss(p, cfg, batch)[0])(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                          for l in jax.tree_util.tree_leaves(grads)))
        assert bool(jnp.isfinite(gn)) and float(gn) > 0
        new = jax.tree_util.tree_map(
            lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
        loss2, _ = jax.jit(lambda p, b: T.forward_loss(p, cfg, b))(new, batch)
        assert bool(jnp.isfinite(loss2))

    def test_decode_step_shapes(self, arch):
        cfg = reduce_config(get_config(arch))
        params = T.init_params(cfg, KEY)
        b = 2
        cache = T.init_cache(cfg, b, 32)
        enc_out = None
        if cfg.is_encoder_decoder:
            src = jnp.zeros((b, 16, cfg.modal_embed_dim), jnp.float32)
            enc_out = T.encode_for_decode(params, cfg, {"src_embeds": src})
        tok = jnp.zeros((b, 1), jnp.int32)
        logits, cache2 = T.decode_step(params, cfg, cache, tok,
                                       jnp.asarray(0), enc_out=enc_out)
        assert logits.shape == (b, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert jax.tree_util.tree_structure(cache) == \
            jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "qwen2-7b",
                                  "jamba-v0.1-52b", "xlstm-1.3b",
                                  "olmoe-1b-7b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced full-sequence logits must match step-by-step decode —
    the strongest cache-correctness check (covers SWA rotation, mamba/xlstm
    state recurrences, MoE routing determinism)."""
    cfg = reduce_config(get_config(arch))
    # capacity_factor high so the prefill path drops no tokens: capacity
    # drops are legitimate train/prefill behaviour but decode (T=B tokens)
    # never drops, so exact agreement needs drop-free routing.
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0,
                                cfg.vocab_size)
    # full-forward logits
    x = T.forward_hidden(params, cfg, {"tokens": tokens})
    w_un = L.unembed_matrix(params["emb"], cfg)
    full_logits = (x @ w_un).astype(jnp.float32)          # [b, s, V]
    # stepwise decode
    cache = T.init_cache(cfg, b, s)
    step = jax.jit(lambda c, t, pos: T.decode_step(params, cfg, c, t, pos))
    errs = []
    for pos in range(s):
        logits, cache = step(cache, tokens[:, pos:pos + 1], jnp.asarray(pos))
        errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, pos, :]))))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    assert max(errs) / scale < 2e-3, (arch, errs)


@pytest.mark.parametrize("arch", ["qwen2-7b", "h2o-danube-1.8b",
                                  "jamba-v0.1-52b", "xlstm-1.3b"])
def test_prefill_cache_handoff(arch):
    """prefill_with_cache + decode continuation == decode-from-scratch — the
    serving handoff is exact for every mixer (KV incl. SWA rotation, mamba
    ssm/conv state, mLSTM matrix memory, sLSTM state)."""
    cfg = dataclasses.replace(reduce_config(get_config(arch)), dtype="float32",
                              capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    b, s, maxlen = 2, 12, 24
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s + 4), 0,
                              cfg.vocab_size)
    _, cache = T.prefill_with_cache(params, cfg, {"tokens": toks[:, :s]}, maxlen)
    c2 = T.init_cache(cfg, b, maxlen)
    for pos in range(s):
        _, c2 = T.decode_step(params, cfg, c2, toks[:, pos:pos + 1],
                              jnp.asarray(pos))
    scale = None
    for pos in range(s, s + 4):
        la, cache = T.decode_step(params, cfg, cache, toks[:, pos:pos + 1],
                                  jnp.asarray(pos))
        lb, c2 = T.decode_step(params, cfg, c2, toks[:, pos:pos + 1],
                               jnp.asarray(pos))
        scale = scale or float(jnp.max(jnp.abs(lb))) + 1e-9
        assert float(jnp.max(jnp.abs(la - lb))) / scale < 1e-4


def test_sliding_window_masks_old_tokens():
    """With window W, logits at position t must not depend on tokens older
    than t - W + 1."""
    cfg = reduce_config(get_config("h2o-danube-1.8b"))
    cfg = dataclasses.replace(cfg, dtype="float32", sliding_window=4,
                              num_layers=2)
    params = T.init_params(cfg, KEY)
    b, s = 1, 12
    t1 = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)   # perturb an old token
    h1 = T.forward_hidden(params, cfg, {"tokens": t1})
    h2 = T.forward_hidden(params, cfg, {"tokens": t2})
    # position >= window: old token is out of every layer's window reach only
    # for 1-layer receptive fields; with 2 layers reach is 2W-1 = 7
    reach = 2 * 4 - 1
    diff = jnp.max(jnp.abs(h1 - h2), axis=(0, 2))
    assert float(jnp.max(diff[reach + 1:])) < 1e-5
    assert float(diff[0]) > 1e-4     # sanity: it does affect early positions


class TestMoE:
    def _cfg(self, e=4, k=2, cf=8.0):
        return dataclasses.replace(
            reduce_config(get_config("olmoe-1b-7b")),
            num_experts=e, experts_per_token=k, capacity_factor=cf,
            dtype="float32")

    def test_topk_equals_dense_mix_when_k_equals_e(self):
        """With k = E and ample capacity, MoE output must equal the dense
        prob-weighted mixture of all experts — dispatch/combine exactness."""
        cfg = self._cfg(e=4, k=4, cf=8.0)
        p = MOE.init_moe(jax.random.fold_in(KEY, 3), cfg)
        x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, cfg.d_model))
        y, aux = MOE.moe_mlp(p, cfg, x)

        probs, _ = MOE.router_probs(p, x.reshape(-1, cfg.d_model))
        act = jax.nn.silu
        outs = []
        for e in range(4):
            h = act(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
            outs.append(h @ p["w_down"][e])
        dense = sum(probs.reshape(2, 8, 4)[..., e:e + 1] * outs[e]
                    for e in range(4))
        np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                                   rtol=2e-3, atol=2e-4)

    def test_capacity_drops_dont_crash_and_bound_output(self):
        cfg = self._cfg(e=4, k=2, cf=0.1)   # absurdly tight capacity
        p = MOE.init_moe(jax.random.fold_in(KEY, 5), cfg)
        x = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 32, cfg.d_model))
        y, aux = MOE.moe_mlp(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_load_balance_loss_uniform_router(self):
        """A perfectly uniform router gives the theoretical minimum lb loss 1."""
        cfg = self._cfg(e=4, k=2)
        p = MOE.init_moe(jax.random.fold_in(KEY, 7), cfg)
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 64, cfg.d_model))
        _, aux = MOE.moe_mlp(p, cfg, x)
        assert abs(float(aux["load_balance_loss"]) - 1.0) < 0.05


def test_chunked_xent_matches_dense():
    b, s, d, v = 2, 16, 8, 32
    x = jax.random.normal(KEY, (b, s, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, v))
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0, v)
    got = L.chunked_softmax_xent(x, w, labels, chunk=4)
    logits = x @ w
    lse = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_param_count_estimate_close():
    """ModelConfig.param_count() (used for 6ND rooflines) within 10% of the
    true initialized parameter count."""
    for arch in ("qwen2-7b", "olmoe-1b-7b", "jamba-v0.1-52b"):
        cfg = reduce_config(get_config(arch))
        params = jax.eval_shape(lambda c=cfg: T.init_params(c, KEY))
        true = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert abs(est - true) / true < 0.10, (arch, est, true)
