"""Import hypothesis when installed; degrade @given tests to skips otherwise.

A bare ``from hypothesis import ...`` fails *collection* for a whole test
module when the package is absent, taking every non-property test in the file
down with it (that was the seed's tier-1 failure mode).  Importing the same
names from here keeps the property tests fully functional wherever
``pip install hypothesis`` has happened (see requirements.txt) and turns only
them into explicit skips where it hasn't.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: every strategy builder exists
        and returns None (never drawn from — the test body is skipped)."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*a, **k):
                pass  # pragma: no cover
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
