"""Wireless-environment subsystem tests: the channel-model registry
(Rayleigh / Rician / AR(1)), geometry-derived heterogeneous means, the
imperfect-CSI h vs h_hat split, ChannelConfig validation, and the bitwise
default contract (golden trajectories recorded from the pre-subsystem
seed, both drivers)."""
import dataclasses
import hashlib
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import channels as chl
from repro.channels import GeometryConfig
from repro.core import amplification as amp
from repro.core.channel import ChannelConfig, channel_for_round, draw_channel
from repro.fed import runtime as rt
from repro.fl import (DataSpec, EvalSpec, Experiment, ExperimentSpec,
                      ModelSpec)

KEY = jax.random.PRNGKey(0)
K = 4


def ridge_spec(driver="scan", seed=0, **chkw):
    fl = rt.FLConfig(
        num_devices=K, scheme="normalized", case="II", eta=0.01,
        channel=ChannelConfig(num_devices=K, channel_mean=1e-3,
                              noise_var=1e-7, **chkw),
        grad_bound=25.0, s_target=0.995, smoothness_L=2.0,
        strong_convexity_M=0.5, seed=seed)
    return ExperimentSpec(
        fl=fl,
        data=DataSpec(dataset="ridge", split="iid", num_train=200, dim=8,
                      batch_size=16, seed=3),
        model=ModelSpec(kind="ridge"), eval=EvalSpec(every=4),
        driver=driver, chunk_size=3)


class TestChannelConfigValidation:
    """Satellite: constructor-time validation matching the FLConfig
    pattern, with error messages naming the offending field."""

    @pytest.mark.parametrize("kw,match", [
        (dict(channel_mean=0.0), "channel_mean must be positive"),
        (dict(channel_mean=-1e-5), "channel_mean must be positive"),
        (dict(noise_var=-1e-7), "noise_var must be >= 0"),
        (dict(b_max=0.0), "b_max must be positive"),
        (dict(b_max=-2.0), "b_max must be positive"),
        (dict(num_devices=0), "num_devices must be >= 1"),
        (dict(rician_k=-1.0), "rician_k must be >= 0"),
        (dict(rho=1.0), r"rho must lie in \[0, 1\)"),
        (dict(rho=-0.1), r"rho must lie in \[0, 1\)"),
        (dict(csi_error=-0.5), "csi_error must be >= 0"),
        (dict(model="nope"), "unknown channel model 'nope'"),
        (dict(csi_error_model="nope"), "unknown csi_error_model 'nope'"),
    ])
    def test_rejects(self, kw, match):
        base = dict(num_devices=K)
        base.update(kw)
        with pytest.raises(ValueError, match=match):
            ChannelConfig(**base)

    def test_geometry_validation(self):
        with pytest.raises(ValueError, match="min_distance"):
            GeometryConfig(min_distance=0.0)
        with pytest.raises(ValueError, match="min_distance"):
            GeometryConfig(min_distance=600.0, cell_radius=500.0)
        with pytest.raises(ValueError, match="path_loss_exp"):
            GeometryConfig(path_loss_exp=-1.0)
        with pytest.raises(ValueError, match="shadowing_std_db"):
            GeometryConfig(shadowing_std_db=-2.0)

    def test_defaults_valid(self):
        cfg = ChannelConfig(num_devices=K)
        assert cfg.model == "rayleigh" and cfg.csi_error == 0.0
        assert not cfg.time_varying()
        assert dataclasses.replace(cfg, block_fading=True).time_varying()
        assert dataclasses.replace(cfg, model="ar1").time_varying()


class TestDrawChannelScale:
    """Satellite: ``draw_channel`` accepts per-device [K] scale arrays;
    scalar behavior stays bitwise."""

    def test_scalar_explicit_matches_default_bitwise(self):
        cfg = ChannelConfig(num_devices=8, channel_mean=1e-3)
        np.testing.assert_array_equal(
            np.asarray(draw_channel(KEY, cfg)),
            np.asarray(draw_channel(KEY, cfg, scale=cfg.rayleigh_scale())))

    def test_per_device_scale_vector(self):
        cfg = ChannelConfig(num_devices=6, channel_mean=1e-3)
        scales = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0, 6.0]) * 1e-4
        h = draw_channel(KEY, cfg, scale=scales)
        assert h.shape == (6,)
        # each coordinate is the scalar draw rescaled: h_k = scale_k * r_k
        base = draw_channel(KEY, cfg, scale=1.0)
        np.testing.assert_allclose(np.asarray(h),
                                   np.asarray(scales * base), rtol=1e-6)

    def test_wrong_length_scale_raises(self):
        cfg = ChannelConfig(num_devices=6)
        with pytest.raises(ValueError, match="per-device scale"):
            draw_channel(KEY, cfg, scale=jnp.ones((4,)))

    def test_block_fading_respects_vector_scale(self):
        cfg = ChannelConfig(num_devices=3, block_fading=True)
        s = jnp.asarray([1e-4, 2e-4, 3e-4])
        h1 = channel_for_round(KEY, cfg, 1, scale=s)
        h2 = channel_for_round(KEY, cfg, 2, scale=s)
        assert not np.allclose(np.asarray(h1), np.asarray(h2))


class TestRegistry:
    def test_names_and_get(self):
        assert {"rayleigh", "rician", "ar1"} <= set(chl.names())
        assert chl.get("ar1").has_state and chl.get("ar1").time_varying
        assert not chl.get("rayleigh").has_state
        with pytest.raises(ValueError, match="unknown channel model"):
            chl.get("missing")

    def test_register_custom_model_runs_end_to_end(self):
        """The one-module extension contract (mirroring the scheme
        registry's ``clipped`` proof): a model registered here — constant
        unit-envelope "channel" — immediately validates in ChannelConfig
        and runs through the compiled engine."""
        name = "_test_const"
        if name not in chl.names():
            chl.register(chl.ChannelModel(
                name=name,
                init=lambda cfg, scale, key: (
                    scale * jnp.ones((cfg.num_devices,)), None),
                step=lambda cfg, scale, key_t, state, rho: (
                    scale * jnp.ones((cfg.num_devices,)), None),
            ))
        e = Experiment(ridge_spec(model=name))
        e.run(2)
        np.testing.assert_allclose(
            e.state.h, np.full(K, ChannelConfig(
                num_devices=K, channel_mean=1e-3).amplitude_scale()),
            rtol=1e-6)


class TestChannelStatistics:
    """Satellite: empirical means of the registered models match the
    configured ``channel_mean``, and AR(1)'s stationary marginal is the
    i.i.d. Rayleigh."""

    def test_rayleigh_mean(self):
        cfg = ChannelConfig(num_devices=200_000, channel_mean=1e-3)
        h, _ = chl.get("rayleigh").init(cfg, cfg.amplitude_scale(), KEY)
        assert abs(float(jnp.mean(h)) - 1e-3) / 1e-3 < 0.02

    @pytest.mark.parametrize("k_factor", [0.0, 1.0, 5.0, 20.0])
    def test_rician_mean_calibrated(self, k_factor):
        cfg = ChannelConfig(num_devices=200_000, channel_mean=1e-3,
                            model="rician", rician_k=k_factor)
        h, _ = chl.get("rician").init(cfg, cfg.amplitude_scale(), KEY)
        assert abs(float(jnp.mean(h)) - 1e-3) / 1e-3 < 0.02
        assert float(jnp.min(h)) >= 0.0

    def test_rician_k0_is_rayleigh_bitwise(self):
        cfg = ChannelConfig(num_devices=64, channel_mean=1e-3,
                            model="rician", rician_k=0.0)
        h_ric, _ = chl.get("rician").init(cfg, cfg.amplitude_scale(), KEY)
        h_ray, _ = chl.get("rayleigh").init(cfg, cfg.amplitude_scale(), KEY)
        np.testing.assert_array_equal(np.asarray(h_ric), np.asarray(h_ray))

    def test_rician_concentrates_with_k(self):
        """Larger K-factor -> more LOS -> smaller relative spread at the
        same mean."""
        stds = []
        for k_factor in (0.0, 10.0):
            cfg = ChannelConfig(num_devices=100_000, channel_mean=1e-3,
                                model="rician", rician_k=k_factor)
            h, _ = chl.get("rician").init(cfg, cfg.amplitude_scale(), KEY)
            stds.append(float(jnp.std(h)))
        assert stds[1] < 0.5 * stds[0]

    @pytest.mark.parametrize("rho", [0.3, 0.9])
    def test_ar1_stationary_matches_iid_marginal(self, rho):
        """Run the Gauss-Markov recursion from its stationary init for many
        steps: mean AND variance of h_t must match the i.i.d. Rayleigh of
        the same scale at every lag."""
        cfg = ChannelConfig(num_devices=20_000, channel_mean=1e-3,
                            model="ar1", rho=rho)
        model = chl.get("ar1")
        scale = cfg.amplitude_scale()
        h, state = model.init(cfg, scale, KEY)
        means, stds = [float(jnp.mean(h))], [float(jnp.std(h))]
        for t in range(1, 6):
            h, state = model.step(cfg, scale, jax.random.fold_in(KEY, t),
                                  state, rho)
            means.append(float(jnp.mean(h)))
            stds.append(float(jnp.std(h)))
        # Rayleigh(sigma): mean sigma sqrt(pi/2), var sigma^2 (2 - pi/2)
        want_mean = 1e-3
        want_std = scale * math.sqrt(2.0 - math.pi / 2.0)
        for m, s in zip(means, stds):
            assert abs(m - want_mean) / want_mean < 0.03
            assert abs(s - want_std) / want_std < 0.03

    def test_ar1_correlates_rounds(self):
        """rho close to 1 keeps consecutive draws close; rho = 0 does not."""
        cfg = ChannelConfig(num_devices=5_000, channel_mean=1e-3,
                            model="ar1")
        model = chl.get("ar1")
        scale = cfg.amplitude_scale()
        h0, state = model.init(cfg, scale, KEY)
        k1 = jax.random.fold_in(KEY, 1)
        h_corr, _ = model.step(cfg, scale, k1, state, 0.99)
        h_iid, _ = model.step(cfg, scale, k1, state, 0.0)
        corr_rel = float(jnp.mean(jnp.abs(h_corr - h0))) / 1e-3
        iid_rel = float(jnp.mean(jnp.abs(h_iid - h0))) / 1e-3
        assert corr_rel < 0.2 < iid_rel

    def test_ar1_rho0_is_block_fading_bitwise(self):
        """rho = 0 degenerates the AR(1) step to exactly the i.i.d. block-
        fading redraw (same innovation key stream)."""
        cfg = ChannelConfig(num_devices=16, channel_mean=1e-3, model="ar1")
        fading = ChannelConfig(num_devices=16, channel_mean=1e-3,
                               block_fading=True)
        model = chl.get("ar1")
        scale = cfg.amplitude_scale()
        _, state = model.init(cfg, scale, KEY)
        for t in (1, 2, 7):
            h_ar, state = model.step(cfg, scale, jax.random.fold_in(KEY, t),
                                     state, 0.0)
            h_bf = channel_for_round(KEY, fading, t, scale=scale)
            np.testing.assert_array_equal(np.asarray(h_ar), np.asarray(h_bf))


class TestGeometry:
    def test_distances_in_annulus_and_deterministic(self):
        geo = GeometryConfig(cell_radius=400.0, min_distance=80.0)
        d = chl.draw_distances(KEY, geo, 1000)
        assert (d >= 80.0).all() and (d <= 400.0).all()
        np.testing.assert_array_equal(d, chl.draw_distances(KEY, geo, 1000))

    def test_path_loss_formula(self):
        """No shadowing: the relative gain is exactly the distance power
        law (checked against the drawn distances)."""
        geo = GeometryConfig(path_loss_exp=3.0, shadowing_std_db=0.0)
        d = chl.draw_distances(KEY, geo, 50)
        g = chl.relative_gains(KEY, geo, 50)
        np.testing.assert_allclose(
            g, (d / geo.ref_distance) ** (-1.5), rtol=1e-12)

    def test_shadowing_spreads_gains(self):
        geo0 = GeometryConfig(shadowing_std_db=0.0)
        geo8 = GeometryConfig(shadowing_std_db=8.0)
        g0 = chl.relative_gains(KEY, geo0, 2000)
        g8 = chl.relative_gains(KEY, geo8, 2000)
        assert np.std(np.log(g8)) > np.std(np.log(g0))

    def test_setup_produces_heterogeneous_means(self):
        spec = ridge_spec(geometry=GeometryConfig(shadowing_std_db=4.0))
        e = Experiment(spec)
        e.setup()
        assert e.state.scale is not None and e.state.scale.shape == (K,)
        assert np.std(e.state.scale) > 0
        # distinct seeds draw distinct geometries
        e2 = Experiment(ridge_spec(seed=1,
                                   geometry=GeometryConfig(
                                       shadowing_std_db=4.0)))
        e2.setup()
        assert not np.allclose(e.state.scale, e2.state.scale)

    def test_geometry_mean_scales_with_channel_mean(self):
        """channel_mean stays the single batchable knob: doubling it doubles
        every per-device mean."""
        geo = GeometryConfig()
        e1 = Experiment(ridge_spec(geometry=geo))
        e1.setup()
        spec2 = ridge_spec(geometry=geo)
        spec2 = dataclasses.replace(
            spec2, fl=dataclasses.replace(
                spec2.fl, channel=dataclasses.replace(
                    spec2.fl.channel, channel_mean=2e-3)))
        e2 = Experiment(spec2)
        e2.setup()
        np.testing.assert_allclose(e2.state.scale, 2.0 * e1.state.scale,
                                   rtol=1e-12)


class TestImperfectCSI:
    def test_perfect_csi_is_h_bitwise(self):
        """Satellite: h_hat == h bitwise when csi_error = 0, for both error
        models, including a traced zero (the batched sweep's mixed lanes)."""
        h = draw_channel(KEY, ChannelConfig(num_devices=32,
                                            channel_mean=1e-3))
        for model in chl.CSI_ERROR_MODELS:
            np.testing.assert_array_equal(
                np.asarray(chl.estimate(h, KEY, 0.0, 1e-3, model)),
                np.asarray(h))
            traced = jax.jit(lambda hh, e: chl.estimate(hh, KEY, e, 1e-3,
                                                        model))
            np.testing.assert_array_equal(
                np.asarray(traced(h, jnp.asarray(0.0))), np.asarray(h))

    def test_estimate_nonnegative_and_scaled(self):
        cfg = ChannelConfig(num_devices=50_000, channel_mean=1e-3)
        h = draw_channel(KEY, cfg)
        for model in chl.CSI_ERROR_MODELS:
            for err in (0.1, 0.5):
                hh = chl.estimate(h, jax.random.fold_in(KEY, 1), err,
                                  cfg.amplitude_scale(), model)
                assert float(jnp.min(hh)) >= 0.0
                spread = float(jnp.std(hh - h))
                assert spread > 0
            # larger csi_error -> larger deviation
            d1 = float(jnp.std(chl.estimate(h, KEY, 0.1,
                                            cfg.amplitude_scale(), model)
                               - h))
            d2 = float(jnp.std(chl.estimate(h, KEY, 0.5,
                                            cfg.amplitude_scale(), model)
                               - h))
            assert d2 > 3.0 * d1

    def test_additive_error_std_matches(self):
        cfg = ChannelConfig(num_devices=100_000, channel_mean=1e-3)
        h = draw_channel(KEY, cfg)
        err = 0.25
        hh = chl.estimate(h, jax.random.fold_in(KEY, 2), err,
                          cfg.amplitude_scale(), "additive")
        # |h + e| folds a negligible mass at this SNR: std(hh - h) ~ err*scale
        want = err * cfg.amplitude_scale()
        assert abs(float(jnp.std(hh - h)) - want) / want < 0.05

    def test_unknown_model_raises(self):
        with pytest.raises(ValueError, match="unknown csi_error_model"):
            chl.estimate(jnp.ones((3,)), KEY, 0.1, 1.0, "nope")

    def test_setup_solves_on_h_hat(self):
        """Algorithm 1 runs on the server's estimate: the optimized b of an
        imperfect-CSI setup equals the reference solve on h_hat (and NOT
        the solve on the true h)."""
        e = Experiment(ridge_spec(csi_error=0.4))
        e.setup()
        st = e.state
        assert st.h_hat is not None
        assert not np.allclose(st.h_hat, st.h)
        n = e.task.model_dim
        ref_hat = amp.solve_problem3(st.h_hat, 1e-7, n, math.sqrt(5.0))
        np.testing.assert_allclose(st.b, ref_hat.b, rtol=1e-6, atol=1e-9)
        ref_true = amp.solve_problem3(st.h, 1e-7, n, math.sqrt(5.0))
        assert not np.allclose(st.b, ref_true.b)

    def test_csi_gain_err_diagnostic(self):
        """Perfect CSI: the misalignment diagnostic is a hard 0 every
        round; imperfect CSI moves it, and a time-varying channel re-rolls
        it round to round."""
        e0 = Experiment(ridge_spec())
        e0.run(4)
        assert e0.history["csi_gain_err"] == [0.0] * 4
        e1 = Experiment(ridge_spec(csi_error=0.3))
        e1.run(4)
        assert all(v != 0.0 for v in e1.history["csi_gain_err"])
        # fixed channel, fixed estimate: constant misalignment
        assert len(set(e1.history["csi_gain_err"])) == 1
        e2 = Experiment(ridge_spec(csi_error=0.3, block_fading=True))
        e2.run(4)
        assert len(set(e2.history["csi_gain_err"])) == 4


class TestEngineIntegration:
    """Scan-vs-python driver parity on the new environment axes, resume
    semantics of the AR(1) state, and checkpoint round-trips."""

    AXES = [
        dict(model="ar1", rho=0.9),
        dict(model="ar1", rho=0.9, csi_error=0.3),
        dict(model="rician", rician_k=3.0, block_fading=True),
        dict(block_fading=True, csi_error=0.2),
        dict(csi_error=0.2),
        dict(geometry=GeometryConfig(shadowing_std_db=4.0)),
        dict(geometry=GeometryConfig(), block_fading=True, csi_error=0.1),
    ]

    @pytest.mark.parametrize("chkw", AXES,
                             ids=lambda a: ",".join(f"{k}={getattr(v, 'cell_radius', v)}"
                                                    for k, v in a.items()))
    def test_driver_parity(self, chkw):
        hists = {}
        for driver in ("python", "scan"):
            e = Experiment(ridge_spec(driver, **chkw))
            e.run(7)
            hists[driver] = e.history
        assert set(hists["python"]) == set(hists["scan"])
        for k in hists["python"]:
            np.testing.assert_allclose(hists["scan"][k], hists["python"][k],
                                       rtol=2e-6, atol=1e-9, err_msg=k)

    @pytest.mark.parametrize("driver", ["scan", "python"])
    def test_ar1_resume_continues_process(self, driver):
        """run(3); run(3) == run(6): the Gauss-Markov state persists in
        FLState so the correlated channel continues, not restarts."""
        spec = ridge_spec(driver, model="ar1", rho=0.8, csi_error=0.1)
        e_once = Experiment(spec)
        e_once.run(6)
        e_twice = Experiment(spec)
        e_twice.run(3)
        e_twice.run(3)
        np.testing.assert_allclose(e_twice.state.h, e_once.state.h,
                                   rtol=1e-6)
        np.testing.assert_allclose(e_twice.state.fad_state,
                                   e_once.state.fad_state, rtol=1e-6)
        for k in rt.DIAG_KEYS:
            np.testing.assert_allclose(e_twice.history[k],
                                       e_once.history[k], rtol=2e-6,
                                       atol=1e-9, err_msg=k)

    def test_checkpoint_roundtrip_ar1_csi_geometry(self, tmp_path):
        """save at round 3, load into a fresh Experiment, run 3 more —
        equals an unbroken 6-round run, with the full environment state
        (h_hat, fading state, geometry scales) restored."""
        spec = ridge_spec(model="ar1", rho=0.8, csi_error=0.2,
                          geometry=GeometryConfig(shadowing_std_db=3.0))
        e_once = Experiment(spec)
        e_once.run(6)
        e = Experiment(spec)
        e.run(3)
        path = str(tmp_path / "ck.msgpack")
        e.save(path)
        e2 = Experiment(spec)
        e2.load(path)
        np.testing.assert_array_equal(e2.state.fad_state, e.state.fad_state)
        np.testing.assert_array_equal(e2.state.h_hat, e.state.h_hat)
        np.testing.assert_array_equal(e2.state.scale, e.state.scale)
        e2.run(3)
        np.testing.assert_allclose(e2.state.h, e_once.state.h, rtol=1e-6)
        for g, w in zip(jax.tree_util.tree_leaves(e2.state.params),
                        jax.tree_util.tree_leaves(e_once.state.params)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-6, atol=1e-8)

    def test_load_pre_subsystem_checkpoint(self, tmp_path):
        """Non-strict restore: a checkpoint missing the new channel leaves
        (written before this subsystem) still loads, keeping setup()'s
        values for them."""
        from repro.checkpoint import store
        spec = ridge_spec()
        e = Experiment(spec)
        e.run(2)
        path = str(tmp_path / "old.msgpack")
        e.save(path)
        # strip the new leaf as an old writer would have
        import msgpack
        with open(path, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)
        assert any("h_hat" in k for k in payload["leaves"])
        payload["leaves"] = {k: v for k, v in payload["leaves"].items()
                             if "h_hat" not in k}
        with open(path, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        e2 = Experiment(spec)
        e2.load(path)
        assert e2.round == 2
        np.testing.assert_allclose(e2.state.h, e.state.h)
        # strict restore still refuses
        with pytest.raises(KeyError, match="h_hat"):
            store.restore(path, e2._ckpt_tree())

    def test_ar1_rho0_matches_block_fading_trajectory(self):
        """The whole-engine version of the rho = 0 degeneracy: an 'ar1'
        run at rho = 0 produces the block-fading run's exact history."""
        e_ar = Experiment(ridge_spec(model="ar1", rho=0.0))
        e_bf = Experiment(ridge_spec(block_fading=True))
        e_ar.run(5)
        e_bf.run(5)
        assert e_ar.history == e_bf.history

    def test_setup_requires_fad_state_for_ar1(self):
        spec = ridge_spec(model="ar1", rho=0.5)
        e = Experiment(spec)
        e.setup()
        e.state.fad_state = None
        with pytest.raises(ValueError, match="fading state"):
            e.run(1)


class TestDefaultBitwiseGolden:
    """Acceptance: the default environment (model='rayleigh', csi_error=0,
    fixed or block-fading) reproduces the PRE-subsystem trajectories
    bitwise on CPU — golden data recorded at the pre-PR seed by
    tests/golden/generate.py."""

    GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden", "channel_defaults.json")

    @pytest.fixture(scope="class")
    def golden(self):
        with open(self.GOLDEN) as f:
            return json.load(f)

    @pytest.fixture(scope="class")
    def cases(self):
        import sys
        sys.path.insert(0, os.path.dirname(self.GOLDEN))
        try:
            import generate
        finally:
            sys.path.pop(0)
        return generate

    def test_all_cases_bitwise(self, golden, cases):
        specs = cases.cases()
        assert set(specs) == set(golden)
        for name, spec in specs.items():
            got = cases.run_case(spec)
            want = golden[name]
            assert got["params_sha256"] == want["params_sha256"], name
            assert got["h"] == want["h"], name
            assert got["b"] == want["b"], name
            assert got["a"] == want["a"], name
            for key, vals in want["history"].items():
                assert got["history"][key] == vals, (name, key)
