"""tracelint gate: rules fire on the corpus, suppressions suppress, the
self-test catches a silenced rule, --fix round-trips, and the repo is clean.

This suite IS the mechanism that keeps future PRs honest about the engine's
trace-purity / PRNG / classification contracts: `test_repo_lints_clean`
fails the tier-1 run the moment an unsuppressed finding lands in src/,
tests/, or benchmarks/.
"""
import json
import pathlib
import shutil
import subprocess
import sys

import pytest

from repro import lint
from repro.lint import engine

ROOT = pathlib.Path(__file__).resolve().parent.parent
CORPUS = ROOT / "tests" / "lint_corpus"
RULE_IDS = ("TL001", "TL002", "TL003", "TL004", "TL005", "TL006", "TL007",
            "TL008", "TL009")


def lint_file(path, only=None):
    _, active, suppressed = engine.lint(
        [str(path)], root=ROOT, include_corpus=True,
        only=set(only) if only else None)
    return active, suppressed


class TestRegistry:
    def test_at_least_nine_rules(self):
        assert len(lint.names()) >= 9

    def test_ids_and_lookup(self):
        for rid in RULE_IDS:
            assert lint.get(rid).id == rid
        with pytest.raises(KeyError):
            lint.get("TL999")

    def test_duplicate_registration_rejected(self):
        rule = lint.get("TL001")
        with pytest.raises(ValueError):
            lint.register(rule)


class TestCorpus:
    @pytest.mark.parametrize("rid", ("TL000",) + RULE_IDS)
    def test_rule_fires_on_bad_fixture(self, rid):
        active, _ = lint_file(CORPUS / f"{rid.lower()}_bad.py")
        assert any(f.rule_id == rid for f in active), \
            f"{rid} silent on its known-bad fixture"

    @pytest.mark.parametrize("rid", ("TL000",) + RULE_IDS)
    def test_rule_quiet_on_good_fixture(self, rid):
        active, _ = lint_file(CORPUS / f"{rid.lower()}_ok.py")
        noise = [f for f in active if f.rule_id == rid]
        assert not noise, f"{rid} false positive: {noise[0].message}"

    def test_suppressions_suppress(self):
        active, suppressed = lint_file(CORPUS / "suppressed_ok.py")
        assert not active, [f.message for f in active]
        assert len(suppressed) == 3

    def test_reasonless_suppression_is_tl000(self):
        active, _ = lint_file(CORPUS / "tl000_bad.py")
        assert [f.rule_id for f in active] == ["TL000"]
        assert active[0].fix is not None


class TestSelfTest:
    def test_self_test_passes(self):
        ok, report = engine.self_test(CORPUS, ROOT)
        assert ok, report

    def test_self_test_fails_when_rule_misses(self, tmp_path):
        # a corpus whose tl001_bad.py contains no violation: the self-test
        # must exit nonzero rather than certify a silenced rule
        broken = tmp_path / "lint_corpus"
        shutil.copytree(CORPUS, broken)
        (broken / "tl001_bad.py").write_text("x = 1\n")
        ok, report = engine.self_test(broken, tmp_path)
        assert not ok
        assert "FAIL TL001" in report

    def test_cli_self_test_exit_codes(self, tmp_path):
        r = subprocess.run(
            [sys.executable, "-m", "repro.lint", "--self-test"],
            capture_output=True, text=True, cwd=ROOT,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
        assert r.returncode == 0, r.stdout + r.stderr
        broken = tmp_path / "repo"
        (broken / "tests").mkdir(parents=True)
        shutil.copytree(CORPUS, broken / "tests" / "lint_corpus")
        (broken / "tests" / "lint_corpus" / "tl003_bad.py").write_text("")
        r = subprocess.run(
            [sys.executable, "-m", "repro.lint", "--self-test",
             "--root", str(broken)],
            capture_output=True, text=True, cwd=ROOT,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
        assert r.returncode == 1, r.stdout + r.stderr


class TestFix:
    def test_fix_roundtrip(self, tmp_path):
        target = tmp_path / "fix_roundtrip.py"
        shutil.copy(CORPUS / "fix_roundtrip.py", target)
        project, active, _ = engine.lint([str(target)], root=tmp_path,
                                         include_corpus=True)
        touched = engine.apply_fixes(project, active)
        assert touched
        want = (CORPUS / "fix_roundtrip_fixed.py").read_text()
        assert target.read_text() == want

    def test_fix_skips_stale_lines(self, tmp_path):
        target = tmp_path / "fix_roundtrip.py"
        shutil.copy(CORPUS / "fix_roundtrip.py", target)
        project, active, _ = engine.lint([str(target)], root=tmp_path,
                                         include_corpus=True)
        # file changes between lint and fix: every recorded original line is
        # stale, so nothing may be rewritten
        target.write_text("# rewritten\n" + (CORPUS / "fix_roundtrip.py"
                                             ).read_text())
        engine.apply_fixes(project, active)
        assert target.read_text().startswith("# rewritten\n")


class TestContracts:
    """The acceptance-criteria mutations: classification drift must fail."""

    def _mutated(self, tmp_path, old, new):
        src = tmp_path / "src"
        shutil.copytree(ROOT / "src", src)
        rt = src / "repro" / "fed" / "runtime.py"
        text = rt.read_text()
        assert old in text
        rt.write_text(text.replace(old, new))
        _, active, _ = engine.lint([str(src)], root=tmp_path)
        return [f for f in active if f.rule_id == "TL005"]

    def test_removing_batched_field_fails(self, tmp_path):
        hits = self._mutated(
            tmp_path,
            'BATCHED_FL_FIELDS = ("seed", "eta",',
            'BATCHED_FL_FIELDS = ("seed",')
        assert any("eta" in f.message for f in hits), hits

    def test_unclassified_field_fails(self, tmp_path):
        hits = self._mutated(
            tmp_path,
            "    active_gather: bool = False\n",
            "    active_gather: bool = False\n    new_knob: float = 1.0\n")
        assert any("new_knob" in f.message for f in hits), hits


class TestRepoClean:
    def test_repo_lints_clean(self):
        _, active, _ = engine.lint(["src", "tests", "benchmarks"], root=ROOT)
        assert not active, "\n".join(
            f"{f.path}:{f.line}: {f.rule_id} {f.message}" for f in active)

    def test_json_report_shape(self):
        project, active, suppressed = engine.lint(["src"], root=ROOT)
        payload = json.loads(engine.render_json(active, suppressed,
                                                len(project.modules)))
        assert len(payload["rules"]) >= 9
        assert payload["findings"] == []
        assert {"id", "name", "summary", "contract", "fixable"} <= set(
            payload["rules"][0])


class TestConfigValidation:
    """Satellite: construction-time k_block / backend / noise validation
    with exact error messages (previously surfaced deep in ota.aggregate)."""

    def test_flconfig_rejects_mesh_k_block(self):
        from repro.fed.runtime import FLConfig
        with pytest.raises(ValueError, match="mesh backend's device axis"):
            FLConfig(num_devices=8, backend="mesh", k_block=4)

    def test_flconfig_rejects_non_dividing_k_block(self):
        from repro.fed.runtime import FLConfig
        with pytest.raises(ValueError, match="must divide the streamed"):
            FLConfig(num_devices=10, k_block=3)

    def test_flconfig_rejects_nonpositive_k_block(self):
        from repro.fed.runtime import FLConfig
        with pytest.raises(ValueError, match="k_block must be >= 1"):
            FLConfig(num_devices=8, k_block=0)

    def test_otaconfig_rejects_mesh_k_block(self):
        from repro.core.ota import OTAConfig
        with pytest.raises(ValueError, match="mesh backend's device axis"):
            OTAConfig(backend="mesh", k_block=2)

    def test_otaconfig_rejects_negative_noise_var(self):
        from repro.core.ota import OTAConfig
        with pytest.raises(ValueError, match="noise_var must be >= 0"):
            OTAConfig(noise_var=-1e-3)

    def test_channelconfig_rejects_bad_devices(self):
        from repro.core.channel import ChannelConfig
        with pytest.raises(ValueError, match="num_devices must be >= 1"):
            ChannelConfig(num_devices=0)
