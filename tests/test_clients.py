"""Client-algorithm registry tests (``repro.fl.clients``): FedProx, FedDyn,
and SCAFFOLD through the air.

Contracts pinned here:
 * the registry itself (names, validation, per-client state shapes);
 * ``client.algo='sgd'`` is the pre-registry round BITWISE — the default
   ``FLConfig`` and an explicit sgd ``ClientConfig`` produce identical
   trajectories on both drivers and both CPU backends (the channel golden in
   ``tests/golden/channel_defaults.json`` pins the same thing against
   recorded pre-PR data);
 * scan and python drivers trace the SAME corrected round per algorithm
   (bitwise), and the vmap/kernels backends agree at fp32 resolution;
 * the stateful correctors' refreshed states (FedDyn's h_k, SCAFFOLD's c_k)
   ride a genuine second OTA slot: the eq.-8 transmit energy is exactly the
   unit-norm budget summed over BOTH slots, and the slot-2 noise key is
   independent of slot 1's;
 * the streaming ``k_block`` engine and the fixed-participation
   ``active_gather`` path thread per-client state identically to the dense
   round (streaming tolerance — the blocked K-reduction re-associates);
 * checkpoints round-trip client state, and pre-registry checkpoints
   (no ``['client']`` subtree — and pre-environment ones missing
   ``['channel']['h_hat']``) still load, keeping ``setup()``'s zero state;
 * the sweep engine classifies ``client.algo`` structural and
   ``client.mu``/``client.alpha`` batchable, and a mixed-algorithm grid
   matches per-point sequential dispatches;
 * on a dirichlet(0.1) non-IID split with H = 4 local steps, in the
   drift-dominated noise regime, the stateful correctors (FedDyn, SCAFFOLD)
   beat plain SGD on final train loss with non-overlapping seed bands — the
   paper-level deliverable.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.channel import ChannelConfig
from repro.fed import runtime
from repro.fed.runtime import FLConfig, run, setup
from repro.fl import (DataSpec, EvalSpec, Experiment, ExperimentSpec,
                      ModelSpec, SweepSpec, run_sweep)
from repro.fl import clients
from repro.fl.sweep import BATCHABLE, STRUCTURAL, classify_field

K = 8
ROUNDS = 6

# streaming-vs-dense tolerance: blocked fp32 K-reductions re-associate (see
# tests/test_streaming.py); the corrected rounds compound the same ~ulp/round
STREAM_TOL = dict(rtol=3e-4, atol=1e-6)
# vmap-vs-kernels backend tolerance (fp32 kernel accumulators)
BACKEND_TOL = dict(rtol=2e-4, atol=1e-6)

ALGOS = ("sgd", "fedprox", "feddyn", "scaffold")


def _client(**kw):
    return clients.ClientConfig(**kw)


def _fl(**kw):
    base = dict(num_devices=K, scheme="normalized", case="I", p=0.75,
                channel=ChannelConfig(num_devices=K, channel_mean=1e-3),
                grad_bound=10.0, smoothness_L=5.0, expected_loss_drop=2.0,
                seed=0)
    base.update(kw)
    return FLConfig(**base)


def _spec(fl=None, alpha=1.0, **kw):
    base = dict(fl=fl or _fl(),
                data=DataSpec(dataset="synthetic_mnist", split="dirichlet",
                              alpha=alpha, num_train=320, num_test=64,
                              batch_size=16, seed=0),
                model=ModelSpec(kind="mlp", hidden=8),
                eval=EvalSpec(every=5), chunk_size=3)
    base.update(kw)
    return ExperimentSpec(**base)


def _leaves(params):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(params)]


def _run_spec(spec, rounds=ROUNDS, **kw):
    e = Experiment(spec)
    hist = e.run(rounds, **kw)
    return e, hist


# ---------------------------------------------------------------------------
# registry


class TestRegistry:
    def test_builtin_names(self):
        assert set(ALGOS) <= set(clients.names())

    def test_get_unknown(self):
        with pytest.raises(ValueError, match="unknown client algorithm"):
            clients.get("fedavgm")

    def test_config_validation(self):
        with pytest.raises(ValueError):
            _client(algo="nope")
        with pytest.raises(ValueError):
            _client(mu=-0.1)
        with pytest.raises(ValueError):
            _client(algo="feddyn", alpha=-0.5)

    def test_scaffold_rejects_baseline_variate_scheme(self):
        # the second slot must go through the air; a channel-bypassing
        # baseline scheme there would silently skip the OTA superposition
        with pytest.raises(ValueError, match="variate"):
            _fl(client=_client(algo="scaffold", variate_scheme="mean"))

    def test_algorithm_flags(self):
        sgd, prox = clients.get("sgd"), clients.get("fedprox")
        dyn, sca = clients.get("feddyn"), clients.get("scaffold")
        assert not sgd.stateful and sgd.num_slots == 1
        assert not prox.stateful and prox.uses_mu
        assert dyn.stateful and dyn.uses_alpha
        assert dyn.has_server_state and dyn.num_slots == 2
        assert sca.stateful and sca.has_server_state and sca.num_slots == 2

    def test_init_state_shapes(self):
        params0 = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
        assert clients.init_state(_client(), params0, K) is None
        assert clients.init_state(_client(algo="fedprox", mu=0.1),
                                  params0, K) is None
        st = clients.init_state(_client(algo="feddyn"), params0, K)
        assert st["srv"]["w"].shape == (3, 2)     # hbar rides slot 2
        assert st["dev"]["w"].shape == (K, 3, 2)
        assert st["dev"]["w"].dtype == np.float32
        st = clients.init_state(_client(algo="scaffold"), params0, K)
        assert st["dev"]["b"].shape == (K, 2)
        assert st["srv"]["w"].shape == (3, 2)
        assert not np.any(st["dev"]["w"]) and not np.any(st["srv"]["w"])

    def test_resolve_params_overrides(self):
        cfg = _client(algo="fedprox", mu=0.3)
        cp = clients.resolve_params(cfg, None, None)
        assert float(cp.mu) == pytest.approx(0.3)
        cp = clients.resolve_params(cfg, jnp.float32(0.7), None)
        assert float(cp.mu) == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# sgd bitwise (the no-regression pin)


class TestSgdBitwise:
    """The default config (no ClientConfig given) and an explicit
    ``algo='sgd'`` must be the SAME program — bitwise, both drivers, both
    CPU backends, H = 1 and H > 1."""

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("driver", ["scan", "python"])
    def test_default_equals_explicit_sgd(self, backend, driver):
        base = _spec(_fl(backend=backend))
        explicit = _spec(_fl(backend=backend, client=_client(algo="sgd")))
        e1, h1 = _run_spec(base, driver=driver)
        e2, h2 = _run_spec(explicit, driver=driver)
        for a, b in zip(_leaves(e1.params), _leaves(e2.params)):
            np.testing.assert_array_equal(b, a)
        np.testing.assert_array_equal(h1["tx_energy"], h2["tx_energy"])

    def test_default_equals_explicit_sgd_local_steps(self):
        base = _spec(_fl(), local_steps=3, local_lr=0.05)
        explicit = _spec(_fl(client=_client(algo="sgd")),
                         local_steps=3, local_lr=0.05)
        e1, _ = _run_spec(base)
        e2, _ = _run_spec(explicit)
        for a, b in zip(_leaves(e1.params), _leaves(e2.params)):
            np.testing.assert_array_equal(b, a)

    def test_sgd_state_is_none(self):
        e, _ = _run_spec(_spec(_fl(client=_client(algo="sgd"))))
        assert e.state.client_state is None


# ---------------------------------------------------------------------------
# per-algorithm driver/backend parity


class TestAlgorithmParity:
    @staticmethod
    def _algo_fl(algo, backend="vmap", **kw):
        return _fl(backend=backend,
                   client=_client(algo=algo, mu=0.1, alpha=0.05), **kw)

    @pytest.mark.parametrize("algo", ALGOS)
    def test_scan_python_bitwise(self, algo):
        spec = _spec(self._algo_fl(algo), local_steps=2, local_lr=0.05)
        es, hs = _run_spec(spec, driver="scan")
        ep, hp = _run_spec(spec, driver="python")
        for s, p in zip(_leaves(es.params), _leaves(ep.params)):
            np.testing.assert_array_equal(p, s)
        np.testing.assert_array_equal(hs["tx_energy"], hp["tx_energy"])
        if es.state.client_state is not None:
            for s, p in zip(_leaves(es.state.client_state),
                            _leaves(ep.state.client_state)):
                np.testing.assert_array_equal(p, s)

    @pytest.mark.parametrize("algo", ["feddyn", "scaffold"])
    def test_vmap_kernels_parity(self, algo):
        ev, _ = _run_spec(_spec(self._algo_fl(algo, "vmap")))
        ek, _ = _run_spec(_spec(self._algo_fl(algo, "kernels")))
        for v, k in zip(_leaves(ev.params), _leaves(ek.params)):
            np.testing.assert_allclose(k, v, **BACKEND_TOL)
        for v, k in zip(_leaves(ev.state.client_state),
                        _leaves(ek.state.client_state)):
            np.testing.assert_allclose(k, v, **BACKEND_TOL)

    def test_algorithms_actually_differ(self):
        """The corrections are live: on a non-IID split with local steps,
        each algorithm produces a distinct trajectory (guards against a
        registry wiring that silently ignores the correction)."""
        finals = {}
        for algo in ALGOS:
            e, _ = _run_spec(_spec(self._algo_fl(algo), alpha=0.1,
                                   local_steps=3, local_lr=0.05))
            finals[algo] = np.concatenate(
                [l.ravel() for l in _leaves(e.params)])
        for i, a in enumerate(ALGOS):
            for b in ALGOS[i + 1:]:
                assert not np.array_equal(finals[a], finals[b]), (a, b)


# ---------------------------------------------------------------------------
# the second OTA slot


class TestTwoSlotEnergy:
    @pytest.mark.parametrize("algo", ["feddyn", "scaffold"])
    def test_two_slot_energy_is_two_unit_norm_budgets(self, algo):
        """Full participation, unit-norm schemes on both slots: the eq.-8
        total is exactly Sum b_k^2 per slot, so the two-slot correctors pay
        exactly 2x the single-slot budget every round."""
        sgd_e, sgd_h = _run_spec(_spec(_fl(client=_client(algo="sgd"))))
        two_e, two_h = _run_spec(_spec(_fl(client=_client(algo=algo))))
        np.testing.assert_array_equal(sgd_e.state.b, two_e.state.b)
        budget = float(np.sum(np.asarray(sgd_e.state.b) ** 2))
        np.testing.assert_allclose(sgd_h["tx_energy"], budget, rtol=1e-5)
        np.testing.assert_allclose(two_h["tx_energy"], 2.0 * budget,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(two_h["tx_energy"]),
                                   2.0 * np.asarray(sgd_h["tx_energy"]),
                                   rtol=1e-6)

    def test_slot2_noise_key_independent(self):
        """The slot-2 aggregation draws its own noise: two scaffold runs
        differing ONLY in noise_var produce different server variates but
        identical slot-1 budgets (same b/a solve)."""
        lo = _spec(_fl(client=_client(algo="scaffold")))
        hi_chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                                noise_var=1e-3)
        hi = _spec(_fl(channel=hi_chan, client=_client(algo="scaffold")))
        el, _ = _run_spec(lo)
        eh, _ = _run_spec(hi)
        srv_lo = np.concatenate(
            [np.asarray(l).ravel()
             for l in jax.tree_util.tree_leaves(el.state.client_state["srv"])])
        srv_hi = np.concatenate(
            [np.asarray(l).ravel()
             for l in jax.tree_util.tree_leaves(eh.state.client_state["srv"])])
        assert not np.array_equal(srv_lo, srv_hi)
        assert np.all(np.isfinite(srv_lo)) and np.all(np.isfinite(srv_hi))

    def test_scaffold_partial_participation_energy(self):
        """Bernoulli masks fold into BOTH slots: per-round energy is twice
        the active subset's Sum b_k^2, never the full-K budget."""
        spec = _spec(_fl(client=_client(algo="scaffold")),
                     participation=0.5)
        e, h = _run_spec(spec)
        budget = float(np.sum(np.asarray(e.state.b) ** 2))
        frac = np.asarray(h["num_participants"]) / K
        assert np.all(np.asarray(h["tx_energy"])
                      <= 2.0 * budget * np.maximum(frac, 1e-9) + 1e-5)


# ---------------------------------------------------------------------------
# streaming + active-gather


class TestStreamingClients:
    @pytest.mark.parametrize("algo", ["fedprox", "feddyn", "scaffold"])
    def test_k_block_matches_dense(self, algo):
        fl = _fl(client=_client(algo=algo, mu=0.1, alpha=0.05))
        ed, hd = _run_spec(_spec(fl))
        es, hs = _run_spec(_spec(dataclasses.replace(fl, k_block=4)))
        for d, s in zip(_leaves(ed.params), _leaves(es.params)):
            np.testing.assert_allclose(s, d, **STREAM_TOL)
        np.testing.assert_allclose(hs["tx_energy"], hd["tx_energy"],
                                   rtol=1e-4)
        if ed.state.client_state is not None:
            for d, s in zip(_leaves(ed.state.client_state),
                            _leaves(es.state.client_state)):
                np.testing.assert_allclose(s, d, **STREAM_TOL)

    @pytest.mark.parametrize("algo", ["feddyn", "scaffold"])
    def test_active_gather_matches_dense_mask(self, algo):
        """Fixed-mode participation: the gathered active-set round must
        reproduce the dense masked round INCLUDING the scatter-back of the
        active clients' state (idle clients keep theirs untouched)."""
        fl = _fl(client=_client(algo=algo, alpha=0.05))
        dense = _spec(fl, participation=0.5, participation_mode="fixed")
        gathered = dataclasses.replace(dense, active_gather=True)
        ed, hd = _run_spec(dense)
        eg, hg = _run_spec(gathered)
        np.testing.assert_array_equal(hd["num_participants"],
                                      hg["num_participants"])
        for d, g in zip(_leaves(ed.params), _leaves(eg.params)):
            np.testing.assert_allclose(g, d, **STREAM_TOL)
        for d, g in zip(_leaves(ed.state.client_state),
                        _leaves(eg.state.client_state)):
            np.testing.assert_allclose(g, d, **STREAM_TOL)

    def test_spec_level_k_block_and_active_gather(self):
        """Satellite: the streaming knobs are spec/sweep axes, not only
        FLConfig fields — the override folds into fl_config()."""
        spec = _spec(k_block=4, active_gather=False)
        assert spec.fl_config().k_block == 4
        assert spec.fl.k_block is None           # base config untouched
        spec = _spec(participation=0.5, participation_mode="fixed",
                     active_gather=True)
        assert spec.fl_config().active_gather is True
        e, _ = _run_spec(spec, rounds=2)
        assert e.round == 2


# ---------------------------------------------------------------------------
# checkpoints


class TestClientCheckpoints:
    @pytest.mark.parametrize("algo", ["feddyn", "scaffold"])
    def test_resume_matches_continuous(self, tmp_path, algo):
        spec = _spec(_fl(client=_client(algo=algo, alpha=0.05)),
                     local_steps=2, local_lr=0.05)
        path = str(tmp_path / "ck.msgpack")
        cont, _ = _run_spec(spec, rounds=8)
        first, _ = _run_spec(spec, rounds=4)
        first.save(path)
        resumed = Experiment(spec).load(path)
        assert resumed.round == 4
        resumed.run(4)
        for g, w in zip(_leaves(resumed.params), _leaves(cont.params)):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-7)
        for g, w in zip(_leaves(resumed.state.client_state),
                        _leaves(cont.state.client_state)):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-7)

    def test_pre_registry_checkpoint_loads(self, tmp_path):
        """Forward compat: a checkpoint written before the client-algorithm
        registry has no ``['client']`` subtree — load() keeps setup()'s
        zero state and resumes."""
        spec = _spec(_fl(client=_client(algo="scaffold")))
        path = str(tmp_path / "old.msgpack")
        e, _ = _run_spec(spec, rounds=3)
        tree = e._ckpt_tree()
        del tree["client"]                       # simulate the old layout
        store.save(path, tree, {"round": e.state.round,
                                "model_dim": e.state.model_dim,
                                "scheme": e.cfg.scheme,
                                "server_opt": e.cfg.server_opt})
        e2 = Experiment(spec).load(path)
        assert e2.round == 3
        for l in _leaves(e2.state.client_state):
            assert not np.any(l)                 # zeros, as setup() made them
        e2.run(2)
        assert e2.round == 5

    def test_pre_environment_checkpoint_loads(self, tmp_path):
        """Regression for the PR-5 prefix: a checkpoint missing the
        ``['channel']`` estimate leaves (h_hat) still loads, keeping the
        setup() value — while a missing core channel leaf fails loudly."""
        spec = _spec()
        path = str(tmp_path / "pre_env.msgpack")
        e, _ = _run_spec(spec, rounds=2)
        tree = e._ckpt_tree()
        del tree["channel"]["h_hat"]
        store.save(path, tree, {"round": 2, "model_dim": e.state.model_dim,
                                "scheme": e.cfg.scheme,
                                "server_opt": e.cfg.server_opt})
        e2 = Experiment(spec).load(path)
        np.testing.assert_array_equal(e2.state.h_hat, e2.state.h)

        bad = str(tmp_path / "bad.msgpack")
        tree2 = e._ckpt_tree()
        del tree2["channel"]["h"]
        store.save(bad, tree2, {"round": 2, "model_dim": e.state.model_dim,
                                "scheme": e.cfg.scheme,
                                "server_opt": e.cfg.server_opt})
        with pytest.raises((KeyError, ValueError)):
            Experiment(spec).load(bad)


# ---------------------------------------------------------------------------
# sweep integration


class TestClientSweeps:
    def test_classification(self):
        assert classify_field("client.algo") == STRUCTURAL
        assert classify_field("client.mu") == BATCHABLE
        assert classify_field("client.alpha") == BATCHABLE
        assert classify_field("client.variate_scheme") == STRUCTURAL
        # bare names: "algo" is unambiguous; bare "alpha" stays the DATA
        # field (dirichlet concentration) — the client lane needs the scope
        assert classify_field("algo") == STRUCTURAL
        from repro.fl.spec import resolve_axis
        assert resolve_axis("alpha") == ("data", "alpha")
        assert resolve_axis("client.alpha") == ("client", "alpha")

    def test_mu_axis_batches_one_program(self):
        sweep = SweepSpec(_spec(_fl(client=_client(algo="fedprox"))),
                          {"client.mu": (0.0, 0.1, 0.5)})
        assert sweep.classification() == {"client.mu": BATCHABLE}
        res = run_sweep(sweep, 4)
        assert np.asarray(res.history["tx_energy"]).shape[0] == 3

    def test_mixed_algo_grid_batched_vs_sequential(self):
        axes = {"algo": (("sgd", {"client.algo": "sgd"}),
                         ("fedprox", {"client.algo": "fedprox",
                                      "client.mu": 0.1}),
                         ("scaffold", {"client.algo": "scaffold"})),
                "seed": (0, 1)}
        sweep = SweepSpec(_spec(), axes)
        assert sweep.classification()["algo"] == STRUCTURAL
        res_b = run_sweep(sweep, ROUNDS)
        res_s = run_sweep(sweep, ROUNDS, vectorized=False)
        for key in res_b.history:
            np.testing.assert_allclose(res_b.history[key],
                                       res_s.history[key],
                                       rtol=2e-5, atol=1e-7, err_msg=key)

    def test_mu_zero_lane_matches_sgd(self):
        """FedProx with mu = 0 is plain local SGD — the batched mu lane at
        zero must reproduce the sgd trajectory (same program family)."""
        sweep = SweepSpec(_spec(_fl(client=_client(algo="fedprox"))),
                          {"client.mu": (0.0, 0.3)})
        res = run_sweep(sweep, ROUNDS)
        e, h = _run_spec(_spec(_fl(client=_client(algo="sgd"))),
                         rounds=ROUNDS)
        np.testing.assert_allclose(
            np.asarray(res.history["update_norm"])[0],
            np.asarray(h["update_norm"]), rtol=2e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# the deliverable: separation on non-IID splits


@pytest.mark.slow
class TestNonIIDSeparation:
    """Dirichlet(0.1) split, H = 4 local steps, drift-dominated noise
    (the correctors learn their server state from the DE-GAINED slot-2
    aggregate, which amplifies channel noise by ~1/(a sum h b); at the
    repo-default noise_var that amplified noise swamps the variates and
    plain SGD wins instead): the stateful correctors (FedDyn, SCAFFOLD)
    must beat plain local SGD on final train loss with non-overlapping
    seed bands — the paper-level claim the registry exists to demonstrate
    (full-scale version: ``benchmarks.figures.client_algorithms``)."""

    def test_stateful_correctors_beat_sgd(self):
        axes = {"algo": (("sgd", {"client.algo": "sgd"}),
                         ("feddyn", {"client.algo": "feddyn",
                                     "client.alpha": 0.1}),
                         ("scaffold", {"client.algo": "scaffold"})),
                "seed": (0, 1, 2)}
        chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                             noise_var=1e-10)
        base = _spec(_fl(channel=chan), alpha=0.1, local_steps=4,
                     local_lr=0.05, eval=EvalSpec(every=20))
        res = run_sweep(SweepSpec(base, axes), 120)
        mean, std = res.band("train_loss", over="seed")   # [algo, evals]
        names = res.sweep.values("algo")
        final = {n: (mean[i][-1], std[i][-1]) for i, n in enumerate(names)}
        sm, ss = final["sgd"]
        for name in ("feddyn", "scaffold"):
            am, as_ = final[name]
            assert am + as_ < sm - ss, (
                f"{name} {am:.4f}+-{as_:.4f} vs sgd {sm:.4f}+-{ss:.4f}")
