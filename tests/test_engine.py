"""Compiled FL-engine tests: the ``scan`` driver must reproduce the
``python`` host-loop driver exactly (params AND history) on every backend,
fixed-channel and block-fading, and the jax-native Problem-3 solver must
match the float64 SciPy reference."""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import amplification as amp
from repro.core.channel import ChannelConfig
from repro.data.datasets import (device_batches, device_batches_many,
                                 split_dirichlet, synthetic_mnist)
from repro.fed import runtime as rt
from repro.fed.runtime import FLConfig, run, setup
from repro.models.simple import init_mlp_classifier, mlp_classifier_loss

K = 6
ROUNDS = 10


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_mnist(key, 600)
    split = split_dirichlet(jax.random.fold_in(key, 1), np.asarray(y), K, 1.0)
    params0 = init_mlp_classifier(jax.random.fold_in(key, 2), hidden=16)
    dim = sum(int(np.prod(np.asarray(l).shape))
              for l in jax.tree_util.tree_leaves(params0))
    xnp, ynp = np.asarray(x), np.asarray(y)

    def grad_fn(params, batch):
        xb, yb = batch
        return jax.grad(lambda p: mlp_classifier_loss(p, xb, yb))(params)

    def provider(t):
        idx = device_batches(jax.random.PRNGKey(3), split, 16, t)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    def provider_chunk(ts):
        idx = device_batches_many(jax.random.PRNGKey(3), split, 16, ts)
        return (jnp.asarray(xnp[idx]), jnp.asarray(ynp[idx]))

    return dict(params0=params0, dim=dim, grad_fn=grad_fn, provider=provider,
                provider_chunk=provider_chunk, split=split, x=xnp, y=ynp)


def _cfg(task, backend="vmap", fading=False, **kw):
    chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                         block_fading=fading)
    base = dict(num_devices=K, scheme="normalized", case="I", p=0.75,
                channel=chan, grad_bound=10.0, smoothness_L=5.0,
                expected_loss_drop=2.0, seed=0, backend=backend)
    base.update(kw)
    return FLConfig(**base)


def _run_driver(task, cfg, driver, rounds=ROUNDS, **kw):
    state = setup(cfg, task["params0"], task["dim"])
    return run(cfg, state, task["grad_fn"], task["provider"], rounds,
               driver=driver, chunk_size=4, **kw)


def assert_params_equal(got, want, **tol):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), **tol)


class TestDriverParity:
    """scan vs python: identical params and history under a shared seed, for
    every backend x {fixed, block-fading}.  (fp32 tolerance per the
    acceptance criteria; on CPU the two drivers are in fact bitwise equal.)"""

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("fading", [False, True])
    def test_params_and_history(self, task, backend, fading):
        cfg = _cfg(task, backend=backend, fading=fading)
        s_py, h_py = _run_driver(task, cfg, "python")
        s_sc, h_sc = _run_driver(task, cfg, "scan")
        assert_params_equal(s_sc.params, s_py.params, rtol=2e-6, atol=1e-7)
        assert h_sc["round"] == h_py["round"] == list(range(1, ROUNDS + 1))
        for k in rt.DIAG_KEYS:
            np.testing.assert_allclose(h_sc[k], h_py[k], rtol=2e-6,
                                       atol=1e-9, err_msg=k)
        if fading:
            # persisted channel state agrees between drivers too
            np.testing.assert_allclose(s_sc.h, s_py.h, rtol=2e-6)
            np.testing.assert_allclose(s_sc.b, s_py.b, rtol=2e-6)

    def test_eval_rounds_align(self, task):
        """The scan driver chunks so eval lands at exactly the python
        driver's rounds (t == 1 and every eval_every-th)."""
        cfg = _cfg(task)

        def ev(params):
            return {"probe": float(sum(jnp.sum(l) for l in
                                       jax.tree_util.tree_leaves(params)))}

        _, h_py = _run_driver(task, cfg, "python", rounds=9, eval_fn=ev,
                              eval_every=4)
        _, h_sc = _run_driver(task, cfg, "scan", rounds=9, eval_fn=ev,
                              eval_every=4)
        assert h_py["eval_round"] == [1, 4, 8]
        assert h_sc["eval_round"] == h_py["eval_round"]
        np.testing.assert_allclose(h_sc["probe"], h_py["probe"], rtol=1e-6)

    def test_chunk_batch_provider_matches_stacking(self, task):
        cfg = _cfg(task, fading=True)
        _, h_stack = _run_driver(task, cfg, "scan")
        s2 = setup(cfg, task["params0"], task["dim"])
        _, h_chunk = run(cfg, s2, task["grad_fn"], task["provider"], ROUNDS,
                         driver="scan", chunk_size=4,
                         chunk_batch_provider=task["provider_chunk"])
        for k in rt.DIAG_KEYS:
            np.testing.assert_allclose(h_chunk[k], h_stack[k], rtol=1e-6,
                                       err_msg=k)

    def test_caller_params_survive_donation(self, task):
        """The scan engine donates param buffers chunk-to-chunk; the caller's
        original pytree must stay readable (benchmarks reuse params0)."""
        cfg = _cfg(task)
        before = [np.asarray(l).copy() for l in
                  jax.tree_util.tree_leaves(task["params0"])]
        _run_driver(task, cfg, "scan")
        for l, want in zip(jax.tree_util.tree_leaves(task["params0"]), before):
            np.testing.assert_array_equal(np.asarray(l), want)


class TestScenarioAxesParity:
    """The new spec axes — server optimizer, multi-local-step clients,
    partial participation — run on the scan engine and are held to
    scan-vs-python parity (params AND every history key), individually and
    composed."""

    AXES = [
        {"server_opt": "adamw"},
        {"server_opt": "sgd", "server_momentum": 0.9},
        {"local_steps": 3, "local_lr": 0.05},
        {"participation": 0.5},
        {"participation": 0.5, "participation_mode": "fixed"},
        {"server_opt": "adamw", "local_steps": 2, "participation": 0.6},
    ]

    @pytest.mark.parametrize("axes", AXES,
                             ids=lambda a: ",".join(f"{k}={v}"
                                                    for k, v in a.items()))
    def test_scan_matches_python(self, task, axes):
        cfg = _cfg(task, **axes)
        s_py, h_py = _run_driver(task, cfg, "python")
        s_sc, h_sc = _run_driver(task, cfg, "scan")
        assert_params_equal(s_sc.params, s_py.params, rtol=2e-6, atol=1e-7)
        for k in rt.DIAG_KEYS:
            np.testing.assert_allclose(h_sc[k], h_py[k], rtol=2e-6,
                                       atol=1e-9, err_msg=k)

    def test_fixed_participation_schedules_exact_fraction(self, task):
        cfg = _cfg(task, participation=0.5, participation_mode="fixed")
        _, hist = _run_driver(task, cfg, "scan")
        assert all(n == K // 2 for n in hist["num_participants"])

    def test_participation_cuts_tx_energy(self, task):
        """eq.-8 accounting: with the normalized scheme every participant
        spends b_k^2, so masked rounds spend proportionally less than the
        full-cohort sum."""
        cfg = _cfg(task, participation=0.5, participation_mode="fixed")
        state = setup(cfg, task["params0"], task["dim"])
        full = float(np.sum(np.square(state.b)))
        _, hist = _run_driver(task, cfg, "scan")
        assert all(0 < e < full for e in hist["tx_energy"])

    def test_baseline_scheme_respects_mask(self, task):
        """The 'mean' baseline bypasses the channel, so the mask cannot
        reach it through b — the ideal reference must still average over
        the PARTICIPANTS only (one round, checked against the masked mean
        computed by hand)."""
        cfg = _cfg(task, scheme="mean", participation=0.5,
                   participation_mode="fixed")
        state = setup(cfg, task["params0"], task["dim"])
        state, hist = run(cfg, state, task["grad_fn"], task["provider"], 1,
                          driver="python")
        key = jax.random.PRNGKey(cfg.seed + 1)
        mask = np.asarray(rt._participation_mask(cfg, key, jnp.asarray(1)))
        stacked = jax.vmap(lambda db: task["grad_fn"](task["params0"], db))(
            task["provider"](1))
        w = mask / mask.sum()
        eta = 1.0   # case I, t = 1
        for p0, p1, g in zip(jax.tree_util.tree_leaves(task["params0"]),
                             jax.tree_util.tree_leaves(state.params),
                             jax.tree_util.tree_leaves(stacked)):
            want = np.asarray(p0) - eta * np.tensordot(
                w, np.asarray(g, np.float32), axes=(0, 0))
            np.testing.assert_allclose(np.asarray(p1), want, rtol=1e-5,
                                       atol=1e-6)
        assert hist["num_participants"] == [K // 2]

    def test_empty_round_is_a_true_noop(self, task, monkeypatch):
        """A round in which nobody transmits must leave params AND the
        server-optimizer state untouched — even for a stateful optimizer
        (adam moments / weight decay would otherwise still move the model)."""
        monkeypatch.setattr(rt, "_participation_mask",
                            lambda cfg, key, t: jnp.zeros((cfg.num_devices,),
                                                          jnp.float32))
        cfg = _cfg(task, server_opt="adamw", server_weight_decay=0.1,
                   participation=0.123)   # unique value -> cold jit cache
        state = setup(cfg, task["params0"], task["dim"])
        state, hist = run(cfg, state, task["grad_fn"], task["provider"], 2,
                          driver="python")
        assert_params_equal(state.params, task["params0"], rtol=0, atol=0)
        assert int(state.opt_state.step) == 0
        for l in jax.tree_util.tree_leaves(state.opt_state.mu):
            np.testing.assert_array_equal(np.asarray(l), 0.0)
        assert hist["update_norm"] == [0.0, 0.0]
        assert hist["num_participants"] == [0.0, 0.0]

    def test_server_momentum_changes_trajectory(self, task):
        _, h_plain = _run_driver(task, _cfg(task), "scan")
        _, h_mom = _run_driver(task, _cfg(task, server_momentum=0.9), "scan")
        assert not np.allclose(h_mom["update_norm"], h_plain["update_norm"])

    def test_default_axes_unchanged_from_legacy(self, task):
        """server_opt='sgd', local_steps=1, participation=1.0 IS the paper's
        round: the explicit defaults produce the identical trajectory to a
        config that never mentions the axes."""
        _, h_a = _run_driver(task, _cfg(task), "scan")
        _, h_b = _run_driver(task, _cfg(task, server_opt="sgd",
                                        local_steps=1, participation=1.0),
                             "scan")
        assert h_a == h_b


class TestActiveGather:
    """Fixed-mode active-set gather (``FLConfig.active_gather``): gradient
    compute shrinks to the m = round(p K) scheduled devices, but the round
    must stay BITWISE the dense masked round on params and the participant
    count (the scatter-back + fusion-fence contract).  The eq.-8 tx_energy
    total is held to fp32 resolution instead: per-device N-reductions
    vectorize shape-dependently ([m]- vs [K]-row stacks pick different lane
    tilings), so individual energies can carry 1-ulp noise even though the
    masked sum runs over the identical scattered [K] layout."""

    @pytest.mark.parametrize("backend", ["vmap", "kernels"])
    @pytest.mark.parametrize("scheme", ["normalized", "benchmark2", "mean"])
    def test_bitwise_vs_dense_masked(self, task, backend, scheme):
        import dataclasses
        dense = _cfg(task, backend=backend, scheme=scheme, participation=0.5,
                     participation_mode="fixed")
        gather = dataclasses.replace(dense, active_gather=True)
        s_d, h_d = _run_driver(task, dense, "scan")
        s_g, h_g = _run_driver(task, gather, "scan")
        assert_params_equal(s_g.params, s_d.params, rtol=0, atol=0)
        np.testing.assert_allclose(h_g["tx_energy"], h_d["tx_energy"],
                                   rtol=1e-6)
        np.testing.assert_array_equal(h_g["num_participants"],
                                      h_d["num_participants"])

    def test_exact_participant_accounting(self, task):
        cfg = _cfg(task, participation=0.5, participation_mode="fixed",
                   active_gather=True)
        state = setup(cfg, task["params0"], task["dim"])
        full = float(np.sum(np.square(state.b)))
        _, hist = _run_driver(task, cfg, "scan")
        assert all(n == K // 2 for n in hist["num_participants"])
        # eq. 8: every scheduled device spends b_k^2 (normalized scheme), so
        # a half cohort spends strictly less than the full-cohort sum
        assert all(0 < e < full for e in hist["tx_energy"])

    def test_requires_fixed_mode(self, task):
        with pytest.raises(ValueError, match="fixed"):
            _cfg(task, participation=0.5, active_gather=True)
        with pytest.raises(ValueError, match="participation"):
            _cfg(task, active_gather=True)

    def test_streaming_empty_round_is_a_true_noop(self, task, monkeypatch):
        """The streaming round's empty-round gate: zero masks everywhere
        must leave params and optimizer state untouched, exactly like the
        dense empty round."""
        monkeypatch.setattr(rt, "_participation_mask",
                            lambda cfg, key, t: jnp.zeros((cfg.num_devices,),
                                                          jnp.float32))
        monkeypatch.setattr(
            rt, "_participation_mask_block",
            lambda cfg, key, t, lo, hi: jnp.zeros((hi - lo,), jnp.float32))
        cfg = _cfg(task, server_opt="adamw", server_weight_decay=0.1,
                   participation=0.321, k_block=3)
        state = setup(cfg, task["params0"], task["dim"])
        state, hist = run(cfg, state, task["grad_fn"], task["provider"], 2,
                          driver="python")
        assert_params_equal(state.params, task["params0"], rtol=0, atol=0)
        assert int(state.opt_state.step) == 0
        assert hist["update_norm"] == [0.0, 0.0]
        assert hist["num_participants"] == [0.0, 0.0]


class TestChunkPlan:
    def test_eval_rounds_end_chunks(self):
        chunks = rt._plan_chunks(0, 10, eval_every=4, chunk_size=100)
        assert chunks == [[1], [2, 3, 4], [5, 6, 7, 8], [9, 10]]

    def test_chunk_size_cap(self):
        chunks = rt._plan_chunks(0, 7, eval_every=None, chunk_size=3)
        assert chunks == [[1, 2, 3], [4, 5, 6], [7]]

    def test_resume_offset(self):
        chunks = rt._plan_chunks(12, 6, eval_every=8, chunk_size=100)
        assert chunks == [[13, 14, 15, 16], [17, 18]]


class TestJaxSolverVsScipy:
    """jax-native Algorithm 1 (lax.while_loop bisection + closed-form
    water-filling inner program) vs the float64 SciPy reference."""

    def rayleigh(self, seed, k, mean=1e-3):
        rng = np.random.default_rng(seed)
        return rng.rayleigh(mean / math.sqrt(math.pi / 2), k)

    @pytest.mark.parametrize("seed,k,n", [(0, 20, 1000), (1, 3, 50),
                                          (2, 8, 100000), (3, 12, 10)])
    def test_matches_scipy(self, seed, k, n):
        h = self.rayleigh(seed, k)
        ref = amp.solve_problem3(h, 1e-7, n, math.sqrt(5))
        got = amp.solve_problem3_jax(jnp.asarray(h, jnp.float32), 1e-7, n,
                                     math.sqrt(5))
        np.testing.assert_allclose(float(got.Z), ref.Z, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(got.b), ref.b, atol=5e-3)

    def test_jittable_and_feasible(self):
        h = jnp.asarray(self.rayleigh(5, 9), jnp.float32)
        sol = jax.jit(lambda hh: amp.solve_problem3_jax(hh, 1e-7, 500, 2.0))(h)
        b = np.asarray(sol.b)
        assert (b >= -1e-7).all() and (b <= 2.0 + 1e-6).all()
        assert float(sol.Z) > 0

    def test_noiseless_edge_equalizes(self):
        """c -> 0: the optimum equalizes h_k b_k (same structure the SciPy
        solver is tested for) instead of degenerating to b = 0."""
        h = jnp.asarray([1.0, 2.0, 4.0])
        sol = amp.solve_problem3_jax(h, 0.0, 1, 10.0)
        hb = np.asarray(h) * np.asarray(sol.b)
        assert np.std(hb) / np.mean(hb) < 0.05

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(2, 16),
           log_noise=st.floats(-9, -4), n=st.integers(1, 200_000))
    def test_property_matches_scipy(self, seed, k, log_noise, n):
        """Satellite acceptance: jax solver matches SciPy to tol on random
        channels, property-style over K and N."""
        h = self.rayleigh(seed, k)
        noise = 10.0 ** log_noise
        ref = amp.solve_problem3(h, noise, n, 2.0)
        got = amp.solve_problem3_jax(jnp.asarray(h, jnp.float32), noise, n,
                                     2.0)
        np.testing.assert_allclose(float(got.Z), ref.Z, rtol=2e-4)


@pytest.mark.slow
class TestMeshDriverParity:
    """Mesh backend needs >= K local devices -> subprocess with forced host
    devices; the scan engine must wrap shard_map rounds unchanged, and the
    declarative facade must reproduce the hand-wired run on mesh too."""

    def test_scan_vs_python(self):
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.channel import ChannelConfig
        from repro.fed.runtime import FLConfig, run, setup
        from repro.fl import (DataSpec, Experiment, EvalSpec, ExperimentSpec,
                              ModelSpec, build_task)

        K = 4
        chan = ChannelConfig(num_devices=K, channel_mean=1e-3,
                             block_fading=True)
        cfg = FLConfig(num_devices=K, scheme="normalized", channel=chan,
                       grad_bound=10.0, smoothness_L=5.0,
                       expected_loss_drop=2.0, seed=0, backend="mesh")
        data = DataSpec(num_train=300, num_test=0, batch_size=8, seed=0)
        model = ModelSpec(hidden=8)

        out = {}
        for driver in ("python", "scan"):
            spec = ExperimentSpec(fl=cfg, data=data, model=model,
                                  eval=EvalSpec(enabled=False),
                                  driver=driver, chunk_size=3)
            e = Experiment(spec)
            e.run(6)
            out[driver] = (e.state.params, e.history)

        # the facade wires the identical task the hand-wired path would
        task = build_task(data, model, K)
        state = setup(cfg, task.params0, task.model_dim)
        state, hist = run(cfg, state, task.grad_fn, task.batch_provider, 6,
                          driver="python", chunk_size=3)
        for g, w in zip(jax.tree_util.tree_leaves(out["python"][0]),
                        jax.tree_util.tree_leaves(state.params)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-6, atol=1e-7)

        for g, w in zip(jax.tree_util.tree_leaves(out["scan"][0]),
                        jax.tree_util.tree_leaves(out["python"][0])):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-6, atol=1e-7)
        print("MESH_ENGINE_PARITY_OK")
        """
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert "MESH_ENGINE_PARITY_OK" in r.stdout, r.stderr[-2500:]
