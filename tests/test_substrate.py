"""Substrate tests: data pipeline, optimizers, checkpointing, channel."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional: the compat module skips only @given tests
# when it is missing instead of failing collection for the whole file
from hypothesis_compat import given, settings, st

from repro.checkpoint import store
from repro.core.channel import ChannelConfig, channel_for_round, draw_channel
from repro.data.datasets import (FederatedSplit, device_batches,
                                 device_batches_many, ridge_data,
                                 split_dirichlet, split_iid, synthetic_mnist,
                                 token_stream)
from repro.optim.optimizers import (adamw, constant_schedule, cosine_schedule,
                                    inverse_power_schedule, sgd)

KEY = jax.random.PRNGKey(0)


class TestChannel:
    def test_rayleigh_mean(self):
        cfg = ChannelConfig(num_devices=200_000, channel_mean=1e-3)
        h = draw_channel(KEY, cfg)
        assert abs(float(jnp.mean(h)) - 1e-3) / 1e-3 < 0.02
        assert float(jnp.min(h)) >= 0.0

    def test_static_vs_block_fading(self):
        static = ChannelConfig(num_devices=8, block_fading=False)
        fading = ChannelConfig(num_devices=8, block_fading=True)
        h1 = channel_for_round(KEY, static, 1)
        h2 = channel_for_round(KEY, static, 2)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
        f1 = channel_for_round(KEY, fading, 1)
        f2 = channel_for_round(KEY, fading, 2)
        assert not np.allclose(np.asarray(f1), np.asarray(f2))


class TestData:
    def test_iid_split_partitions(self):
        split = split_iid(KEY, 1000, 7)
        all_idx = np.concatenate(split.indices)
        assert len(all_idx) == 1000
        assert len(np.unique(all_idx)) == 1000

    def test_dirichlet_split_partitions_and_skews(self):
        labels = np.asarray(jax.random.randint(KEY, (2000,), 0, 10))
        split = split_dirichlet(jax.random.fold_in(KEY, 1), labels, 10,
                                alpha=0.2)
        all_idx = np.concatenate(split.indices)
        assert len(np.unique(all_idx)) == 2000
        assert all(len(i) > 0 for i in split.indices)
        # low alpha => skewed label marginals on at least some devices
        skews = []
        for idx in split.indices:
            counts = np.bincount(labels[idx], minlength=10) / len(idx)
            skews.append(counts.max())
        assert max(skews) > 0.3     # some device is label-dominated

    def test_weights_sum_to_one(self):
        split = split_dirichlet(KEY, np.asarray(
            jax.random.randint(KEY, (500,), 0, 10)), 5, 0.5)
        np.testing.assert_allclose(split.weights().sum(), 1.0)

    def test_device_batches_deterministic(self):
        split = split_iid(KEY, 400, 4)
        b1 = device_batches(jax.random.PRNGKey(5), split, 16, round_idx=3)
        b2 = device_batches(jax.random.PRNGKey(5), split, 16, round_idx=3)
        np.testing.assert_array_equal(b1, b2)
        b3 = device_batches(jax.random.PRNGKey(5), split, 16, round_idx=4)
        assert not np.array_equal(b1, b3)
        # every device samples from ITS shard only
        for k in range(4):
            assert np.isin(b1[k], split.indices[k]).all()

    def test_device_batches_matches_per_device_reference(self):
        """The vectorized single-dispatch sampler must be bit-identical to
        the historical per-device fold_in/randint loop."""
        split = split_dirichlet(KEY, np.asarray(
            jax.random.randint(KEY, (700,), 0, 10)), 5, 0.7)
        for t in (1, 9, 250):
            got = device_batches(jax.random.PRNGKey(5), split, 12, t)
            want = np.stack([
                idx[np.asarray(jax.random.randint(
                    jax.random.fold_in(jax.random.fold_in(
                        jax.random.PRNGKey(5), t), k),
                    (12,), 0, len(idx)))]
                for k, idx in enumerate(split.indices)])
            np.testing.assert_array_equal(got, want)

    def test_device_batches_many_matches_per_round(self):
        """[T, K, B] chunk sampling (the scan engine's data path) stacks the
        exact per-round draws."""
        split = split_iid(KEY, 400, 4)
        ts = [3, 4, 11]
        got = device_batches_many(jax.random.PRNGKey(5), split, 16, ts)
        want = np.stack([device_batches(jax.random.PRNGKey(5), split, 16, t)
                         for t in ts])
        np.testing.assert_array_equal(got, want)

    def test_synthetic_mnist_learnable_structure(self):
        x, y = synthetic_mnist(KEY, 500)
        assert x.shape == (500, 784)
        # class-conditional means must differ (signal exists)
        m0 = x[y == 0].mean(0)
        m1 = x[y == 1].mean(0)
        assert float(jnp.linalg.norm(m0 - m1)) > 1.0

    def test_token_stream_in_vocab(self):
        toks = token_stream(KEY, 4, 128, vocab=97)
        assert toks.shape == (4, 128)
        assert int(toks.min()) >= 0 and int(toks.max()) < 97


class TestOptimizers:
    def test_sgd_matches_manual(self):
        opt = sgd(0.1)
        p = {"w": jnp.ones((3,))}
        s = opt.init(p)
        g = {"w": jnp.full((3,), 2.0)}
        p2, s2 = opt.update(g, s, p)
        np.testing.assert_allclose(np.asarray(p2["w"]), 0.8, rtol=1e-6)
        assert int(s2.step) == 1

    def test_sgd_momentum(self):
        opt = sgd(0.1, momentum=0.9)
        p = {"w": jnp.zeros((1,))}
        s = opt.init(p)
        g = {"w": jnp.ones((1,))}
        p, s = opt.update(g, s, p)
        p, s = opt.update(g, s, p)
        # m1 = 1, m2 = 1.9 -> w = -(0.1 + 0.19)
        np.testing.assert_allclose(np.asarray(p["w"]), -0.29, rtol=1e-6)

    def test_adamw_step_direction(self):
        opt = adamw(1e-2, weight_decay=0.0)
        p = {"w": jnp.zeros((4,))}
        s = opt.init(p)
        g = {"w": jnp.asarray([1.0, -1.0, 2.0, -0.5])}
        p2, _ = opt.update(g, s, p)
        assert np.all(np.sign(np.asarray(p2["w"])) == -np.sign(np.asarray(g["w"])))

    def test_paper_schedule(self):
        sched = inverse_power_schedule(0.75)
        for t in (1, 2, 10, 100):
            assert abs(float(sched(jnp.asarray(t))) - t ** -0.75) < 1e-6
        with pytest.raises(ValueError):
            inverse_power_schedule(0.4)

    def test_cosine_schedule_shape(self):
        sched = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-5
        assert abs(float(sched(jnp.asarray(100))) - 0.1) < 1e-5


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
                "t": (jnp.zeros((2,)), jnp.asarray(3, jnp.int32))}
        path = str(tmp_path / "ck.msgpack")
        store.save(path, tree, {"round": 7})
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        restored, meta = store.restore(path, like)
        assert meta["round"] == 7
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_retention(self, tmp_path):
        d = str(tmp_path)
        for r in range(6):
            store.save_round(d, r, {"w": jnp.zeros((1,))}, keep=3)
        files = sorted(os.listdir(d))
        assert len(files) == 3
        assert store.latest_round(d).endswith("round_00000005.msgpack")

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "ck.msgpack")
        store.save(path, {"w": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            store.restore(path, {"w": jnp.zeros((4,))})


@settings(max_examples=20, deadline=None)
@given(n=st.integers(50, 500), k=st.integers(2, 10), seed=st.integers(0, 99))
def test_property_split_is_partition(n, k, seed):
    split = split_iid(jax.random.PRNGKey(seed), n, k)
    all_idx = np.concatenate(split.indices)
    assert len(all_idx) == n and len(np.unique(all_idx)) == n
