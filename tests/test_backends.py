"""Backend-parity and batched-kernel tests for the scheme registry.

The registry's contract: a scheme defined once in ``repro.core.schemes`` runs
on all three execution backends (vmap / kernels / mesh) with allclose-equal
update directions y, noiseless AND noisy (the backends share one per-leaf
noise key schedule).  The ``clipped`` scheme — registered only in
core/schemes.py, mentioned in no backend module — is the living proof of the
one-module extension path.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota
from repro.core import schemes as S
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(11)
GRAD_BOUND = 7.5


def stacked(key, k=6, shapes=((9, 5), (33,), (4, 3, 2))):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(ki, (k,) + s)
            for i, (ki, s) in enumerate(zip(keys, shapes))}


def channel(key, k=6):
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (k,))) + 0.1
    b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (k,))) + 0.5
    return h, b


def make_cfg(scheme, noisy, backend="vmap"):
    return ota.OTAConfig(scheme=scheme, a=1.3,
                         noise_var=2.5e-3 if noisy else 0.0,
                         grad_bound=GRAD_BOUND, noiseless=not noisy,
                         backend=backend)


def assert_trees_close(got, want, rtol=2e-4, atol=2e-5):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.fixture
def scratch_registry():
    """Schemes registered inside a test are removed on teardown so the
    process-global registry (and the live ota.SCHEMES view) stays clean for
    every other test."""
    before = set(S.names())
    yield
    for name in set(S.names()) - before:
        S._REGISTRY.pop(name, None)


class TestVmapVsKernelsParity:
    @pytest.mark.parametrize("scheme", ota.SCHEMES)
    @pytest.mark.parametrize("noisy", [False, True])
    def test_parity(self, scheme, noisy):
        g = stacked(KEY)
        h, b = channel(KEY)
        nkey = jax.random.fold_in(KEY, 9)
        want = ota.aggregate(make_cfg(scheme, noisy, "vmap"), g, h, b, nkey)
        got = ota.aggregate(make_cfg(scheme, noisy, "kernels"), g, h, b, nkey)  # tracelint: disable=TL002 shared noise key IS the contract: backends must agree bitwise on one draw
        assert_trees_close(got, want)


@pytest.mark.slow
class TestMeshBackendParity:
    """Mesh needs >= K local devices -> subprocess with forced host devices
    (the XLA flag must be set before jax initializes)."""

    @pytest.mark.parametrize("noisy", [False, True])
    def test_all_schemes(self, noisy):
        code = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ota

        K = 8
        key = jax.random.PRNGKey(11)
        keys = jax.random.split(key, 3)
        g = {{f"p{{i}}": jax.random.normal(ki, (K,) + s) for i, (ki, s) in
             enumerate(zip(keys, ((9, 5), (33,), (4, 3, 2))))}}
        h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (K,))) + 0.1
        b = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (K,))) + 0.5
        nkey = jax.random.fold_in(key, 9)
        noisy = {noisy!r}
        for scheme in ota.SCHEMES:
            mk = lambda bk: ota.OTAConfig(
                scheme=scheme, a=1.3, noise_var=2.5e-3 if noisy else 0.0,
                grad_bound=7.5, noiseless=not noisy, backend=bk)
            want = ota.aggregate(mk("vmap"), g, h, b, nkey)
            got = ota.aggregate(mk("mesh"), g, h, b, nkey)
            for gl, wl in zip(jax.tree_util.tree_leaves(got),
                              jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(np.asarray(gl, np.float32),
                                           np.asarray(wl, np.float32),
                                           rtol=2e-4, atol=2e-5,
                                           err_msg=scheme)
        print("MESH_PARITY_OK")
        """
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=env,
                           timeout=400, cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert "MESH_PARITY_OK" in r.stdout, r.stderr[-2500:]


class TestClippedSchemeOneModuleExtension:
    """Acceptance: the truncated/clipped-norm scheme exists ONLY in
    core/schemes.py yet is picked up by validation, SCHEMES, power accounting
    and (via the parity tests above, which iterate ota.SCHEMES) every
    backend."""

    def test_registered(self):
        assert "clipped" in ota.SCHEMES
        sch = S.get("clipped")
        assert sch.requires_grad_bound

    def test_register_new_scheme_at_runtime_runs_on_backends(
            self, scratch_registry):
        """The strongest form of the one-module contract: a scheme registered
        HERE (never seen by any backend module) immediately aggregates on the
        vmap and kernels backends and validates in OTAConfig."""
        name = "halfnorm_test_only"
        if name not in S.names():
            S.register(S.Scheme(
                name=name,
                doc="x_k = g_k / (2 ||g_k||) — test-only",
                device_scale=lambda st, gb: 0.5 / (st.norm + S.EPS),
                transmit_sq_norm=lambda st, gb: 0.25 * jnp.ones_like(st.sq_norm),
            ))
        g = stacked(KEY)
        h, b = channel(KEY)
        cfg = ota.OTAConfig(scheme=name, a=2.0, noiseless=True)
        want = ota.aggregate(cfg, g, h, b, None)
        # y must be half the normalized scheme's y
        y_norm = ota.aggregate(ota.OTAConfig(scheme="normalized", a=2.0,
                                             noiseless=True), g, h, b, None)
        assert_trees_close(want, jax.tree_util.tree_map(lambda l: 0.5 * l,
                                                        y_norm))
        import dataclasses
        got = ota.aggregate(dataclasses.replace(cfg, backend="kernels"),
                            g, h, b, None)
        assert_trees_close(got, want)

    def test_transmit_norm_is_clipped(self):
        g = stacked(KEY)
        norms = np.asarray(ota.per_device_norm(g))
        got = np.asarray(ota.transmit_norms("clipped", g, GRAD_BOUND))
        np.testing.assert_allclose(got, np.minimum(norms / GRAD_BOUND, 1.0),
                                   rtol=1e-5)

    def test_requires_grad_bound_everywhere(self):
        with pytest.raises(ValueError, match="grad_bound"):
            ota.OTAConfig(scheme="clipped")

    def test_energy_accounting(self):
        g = stacked(KEY)
        h, b = channel(KEY)
        e = np.asarray(ota.transmit_energy("clipped", g, b, GRAD_BOUND))
        x_norms = np.asarray(ota.transmit_norms("clipped", g, GRAD_BOUND))
        np.testing.assert_allclose(e, np.asarray(b) ** 2 * x_norms ** 2,
                                   rtol=1e-4)


class TestSchemeRegistrationValidation:
    """Registering IS the whole extension step, so incomplete schemes must
    fail at register time — never diverge silently between backends."""

    def test_missing_device_scale_rejected(self):
        with pytest.raises(ValueError, match="device_scale"):
            S.Scheme(name="broken1",
                     transmit_sq_norm=lambda st, gb: st.sq_norm)

    def test_missing_energy_accounting_rejected(self):
        with pytest.raises(ValueError, match="transmit_sq_norm"):
            S.Scheme(name="broken2",
                     device_scale=lambda st, gb: 1.0 / (st.norm + S.EPS))

    def test_per_tensor_needs_tensor_scale(self):
        with pytest.raises(ValueError, match="tensor_scale"):
            S.Scheme(name="broken3", per_tensor=True,
                     transmit_sq_norm=lambda st, gb: st.sq_norm)

    def test_per_tensor_sign_scheme_backend_parity(self, scratch_registry):
        """pre-transform must apply BEFORE tensor scales on every backend
        (a sign pre would otherwise erase the scales in the fused kernel)."""
        name = "sign_per_tensor_test_only"
        if name not in S.names():
            S.register(S.Scheme(
                name=name, per_tensor=True, pre="sign",
                tensor_scale=lambda st, gb: tuple(
                    1.0 / ((jnp.sqrt(t) + S.EPS)
                           * np.sqrt(len(st.tensor_sq_norms)))
                    for t in st.tensor_sq_norms),
                transmit_sq_norm=lambda st, gb: jnp.ones_like(st.sq_norm)))
        g = stacked(KEY)
        h, b = channel(KEY)
        import dataclasses
        cfg = ota.OTAConfig(scheme=name, a=1.1, noiseless=True)
        want = ota.aggregate(cfg, g, h, b, None)
        got = ota.aggregate(dataclasses.replace(cfg, backend="kernels"),
                            g, h, b, None)
        assert_trees_close(got, want)
        # the tensor scales must actually be present (not erased by sign)
        leaves = jax.tree_util.tree_leaves(want)
        assert not all(float(jnp.max(jnp.abs(l))) < 1e-6 for l in leaves)


class TestGradBoundValidation:
    """Satellite: the mesh path must reject grad_bound=None for schemes that
    need it (it used to pass None into benchmark1 and emit NaNs)."""

    @pytest.mark.parametrize("scheme", ["benchmark1", "clipped"])
    def test_ota_psum_raises(self, scheme):
        from repro.distribution.ota_collectives import ota_psum
        with pytest.raises(ValueError, match="grad_bound"):
            ota_psum({"w": jnp.ones((4,))}, scheme=scheme, axes=("data",),
                     h=jnp.ones((4,)), b=jnp.ones((4,)), a=1.0, noise_var=0.0)

    @pytest.mark.parametrize("scheme", ["benchmark1", "clipped"])
    def test_otaconfig_raises_identically(self, scheme):
        with pytest.raises(ValueError, match="grad_bound"):
            ota.OTAConfig(scheme=scheme)


class TestBatchedMomentsKernel:
    """Shape/grid sweeps for the batched [K, N] grad-norm/moments kernel:
    one pallas_call over a (K, blocks) grid, any N (zero padding is
    moment-neutral), block_rows-invariant."""

    # (2, 269312) -> rows = 263, prime and > 256: exercises the row padding
    # that keeps full blocks instead of degrading block_rows to 1
    @pytest.mark.parametrize("k,n", [(1, 1024), (3, 4096), (8, 5000),
                                     (20, 12345), (5, 257), (2, 269312)])
    def test_matches_ref(self, k, n):
        g = jax.random.normal(KEY, (k, n))
        sumsq, sums = ops.batched_moments(g, interpret=True)
        want_sq, want_s = ref.batched_moments_ref(g)
        np.testing.assert_allclose(np.asarray(sumsq), np.asarray(want_sq),
                                   rtol=2e-5)
        np.testing.assert_allclose(np.asarray(sums), np.asarray(want_s),
                                   rtol=2e-4, atol=1e-3)

    @pytest.mark.parametrize("block_rows", [1, 3, 64, 256])
    def test_block_shape_invariance(self, block_rows):
        g = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 9000))
        got = ops.batched_grad_norms(g, block_rows=block_rows, interpret=True)
        want = jnp.sqrt(jnp.sum(g * g, axis=1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)

    def test_agrees_with_single_vector_kernel(self):
        """The batched kernel replaces K single-device grad_norm launches."""
        k, n = 7, 3000
        g = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n))
        batched = ops.batched_grad_norms(g, interpret=True)
        singles = jnp.stack([ops.grad_norm(g[i], interpret=True)
                             for i in range(k)])
        np.testing.assert_allclose(np.asarray(batched), np.asarray(singles),
                                   rtol=1e-5)

    def test_bf16_input(self):
        g = jax.random.normal(KEY, (3, 2048)).astype(jnp.bfloat16)
        got = ops.batched_grad_norms(g, interpret=True)
        want = jnp.sqrt(jnp.sum(g.astype(jnp.float32) ** 2, axis=1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3)


class TestOtaSuperposeKernel:
    @pytest.mark.parametrize("pre", ["identity", "sign"])
    @pytest.mark.parametrize("k,n", [(2, 1024), (8, 3333)])
    def test_matches_ref(self, pre, k, n):
        g = jax.random.normal(KEY, (k, n))
        scale = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 1), (k,))) + 0.1
        noise = jax.random.normal(jax.random.fold_in(KEY, 2), (n,))
        got = ops.ota_superpose(g, scale, noise, 1.7, pre=pre, interpret=True)
        want = ref.ota_superpose_ref(g, scale, noise, jnp.float32(1.7), pre=pre)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_rejects_unknown_pre(self):
        from repro.kernels.ota_aggregate import ota_aggregate_blocked
        with pytest.raises(ValueError, match="pre-transform"):
            ota_aggregate_blocked(jnp.ones((2, 8)), jnp.ones((2,)),
                                  jnp.zeros((8,)), jnp.ones(()), pre="cube")


class TestKernelPathHasNoDeviceLoop:
    def test_no_python_loop_over_devices(self):
        """Acceptance criterion: per-device norms come from one batched
        pallas_call; fed/kernel_path.py contains no `for i in range(k)`."""
        import inspect
        from repro.fed import kernel_path
        src = inspect.getsource(kernel_path)
        assert "for i in range(k)" not in src
        assert "range(k)" not in src
