"""TL001 known-bad: host coercion on traced values inside traced contexts."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _round_math(cfg, params, grads):
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    total = np.sum(grads)              # BAD: host numpy on a tracer (fixable)
    scale = float(norm)                # BAD: concretizes a tracer
    flag = bool(norm > 0)              # BAD: host bool of a tracer
    host = norm.item()                 # BAD: forces a device sync
    return params - scale * total * flag * host


@functools.partial(jax.jit, static_argnames=("n",))
def _jitted_update(x, n):
    return np.mean(x) / n              # BAD: np.mean in a jitted body


def _scan_driver(xs):
    def body(carry, x):
        return carry + np.abs(x), None  # BAD: np.abs inside a scan body

    out, _ = jax.lax.scan(body, jnp.zeros(()), xs)
    return out
