"""TL002 known-good: fold_in / split discipline (the PR 6 blocking idiom)."""
import jax
import jax.numpy as jnp


def per_device_streams(key, dev_idx):
    # fold_in derives a fresh stream per device; the parent stays usable
    h = jax.vmap(lambda i: jax.random.normal(jax.random.fold_in(key, i), ()))(
        dev_idx)
    z = jax.random.normal(jax.random.fold_in(key, -1), dev_idx.shape)
    return h + z


def split_then_draw(key, shape):
    k_chan, k_noise = jax.random.split(key)
    h = jax.random.normal(k_chan, shape)
    z = jax.random.normal(k_noise, shape)
    return h + z


def rebind_between_draws(key, shape):
    a = jax.random.normal(key, shape)
    key = jax.random.fold_in(key, 1)
    b = jax.random.normal(key, shape)
    return a + b


def exclusive_branches(key, shape, streaming):
    if streaming:
        return jax.random.normal(key, shape)
    return jax.random.uniform(key, shape)   # other arm: exclusive path
