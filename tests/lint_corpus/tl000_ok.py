"""TL000 known-good: every suppression documents its waiver."""
import jax
import jax.numpy as jnp


def correlated(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.uniform(key, shape)  # tracelint: disable=TL002 fixture needs identical draws
    return a + b
