"""TL007 known-bad: reading a buffer after donating it."""
import jax
import jax.numpy as jnp


def _make_run_chunk():
    def run_chunk(params, opt_state, xs):
        return params + jnp.sum(xs), opt_state

    return jax.jit(run_chunk, donate_argnums=(0, 1))


def drive(params, opt_state, chunks):
    run_chunk = _make_run_chunk()
    for xs in chunks:
        new_params, new_opt = run_chunk(params, opt_state, xs)
        drift = jnp.sum(params)        # BAD: params' buffer was donated
        params, opt_state = new_params, new_opt
    return params, drift


def direct_jit(params, xs):
    step = jax.jit(lambda p, x: p + x, donate_argnums=(0,))
    out = step(params, xs)
    return out + params                # BAD: params donated by step()
