"""TL003 known-good: static gates and traced selects."""
import jax
import jax.numpy as jnp

from repro.core import schemes


def _round_math(cfg, params, grads, mask, noise_var):
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    # traced select, not a Python branch
    grads = jnp.where(norm > 1.0, grads / norm, grads)
    # None-ness is Python identity: static by definition
    if mask is not None:
        grads = grads * mask
    # maybe_positive resolves a possibly-traced scalar at trace time (the
    # engine's documented gate for the batched noise axis)
    if schemes.maybe_positive(noise_var):
        grads = grads + noise_var
    # config reads are static
    if cfg.num_devices > 1:
        grads = grads / cfg.num_devices
    return params - grads
