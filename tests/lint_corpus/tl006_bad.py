"""TL006 known-bad: diag dicts drifted from DIAG_KEYS in every direction."""
import jax.numpy as jnp

DIAG_KEYS = ("grad_norm_mean", "eta", "update_norm", "tx_energy")


def _round_math(cfg, norms, eta, y):
    diag_core = {
        "grad_norm_mean": jnp.mean(norms),
        "tx_energy": jnp.sum(norms),
        "peak_norm": jnp.max(norms),     # BAD: key not in DIAG_KEYS
    }
    diag = {
        **diag_core,
        "eta": eta,
        # BAD: update_norm missing — the history recorder will KeyError
    }
    return diag
