"""TL009 known-good: telemetry emitted host-side at chunk boundaries."""
import jax
import jax.numpy as jnp

from repro import obs


@jax.jit
def _round_step(params, grads):
    return params - 0.01 * grads, jnp.sqrt(jnp.sum(jnp.square(grads)))


def run(params, batches, recorder=None):
    # the engine pattern: dispatch the compiled step, transfer at the chunk
    # boundary, THEN hand host floats to the recorder
    hist = []
    for i, grads in enumerate(batches):
        params, norm = _round_step(params, grads)
        norm = float(jax.device_get(norm))
        hist.append(norm)
        if recorder is not None:
            recorder.on_round(i, {"grad_norm_mean": norm})
    return params, hist


def dump(params, path):
    # host-side manifest assembly is fine anywhere untraced
    rec = obs.make("jsonl", path=path)
    rec.on_manifest({"params_sha256": obs.params_sha256(params)})
    rec.close()
    return path
