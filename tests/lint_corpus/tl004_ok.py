"""TL004 known-good: fp32 accumulators, explicit-axis block reductions."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stream_kernel(g_ref, out_ref):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        out_ref[0, :] = jnp.zeros_like(out_ref[0, :])

    g = g_ref[...].astype(jnp.float32)
    partial = jnp.sum(g, axis=0)        # explicit axis: K-block collapsed,
    out_ref[0, :] += partial            # N-block preserved


def _tile_kernel(g_ref, out_ref):
    # one output tile per grid step (no accumulation): a full-tile
    # reduction is the POINT of this kernel, and that is legal
    g = g_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(g)


def aggregate(stacked, k_block, blk):
    k, n = stacked.shape
    grid = (n // blk, k // k_block)
    return pl.pallas_call(
        _stream_kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
    )(stacked)


def tile_sums(stacked, blk):
    k, n = stacked.shape
    return pl.pallas_call(
        _tile_kernel,
        grid=(k, n // blk),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
    )(stacked)
