"""TL004 known-bad: accumulator dtype and full-axis reduction hazards."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stream_kernel(g_ref, out_ref):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        out_ref[0, :] = jnp.zeros_like(out_ref[0, :])

    g = g_ref[...].astype(jnp.float32)
    partial = jnp.sum(g)                # BAD: axis-less reduction in a
    out_ref[0, :] += partial            # (N-block, K-block) gridded body


def aggregate(stacked, k_block, blk):
    k, n = stacked.shape
    grid = (n // blk, k // k_block)
    return pl.pallas_call(
        _stream_kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.bfloat16),  # BAD: bf16 acc
    )(stacked)
