"""TL007 known-good: the engine's rebind-in-the-same-statement discipline."""
import jax
import jax.numpy as jnp


def _make_run_chunk():
    def run_chunk(params, opt_state, xs):
        return params + jnp.sum(xs), opt_state

    return jax.jit(run_chunk, donate_argnums=(0, 1))


def drive(state, chunks):
    run_chunk = _make_run_chunk()
    # copy once so the CALLER's pytrees survive the donation chain
    params = jax.tree_util.tree_map(jnp.copy, state.params)
    opt_state = jax.tree_util.tree_map(jnp.copy, state.opt_state)
    for xs in chunks:
        params, opt_state = run_chunk(params, opt_state, xs)
    return params, opt_state
