"""TL008 known-bad: scan carry arity drift between init, unpack, return."""
import jax
import jax.numpy as jnp


def _make_chunk_scan(params, opt_state, h, b, a):
    def body(carry, t):
        params, opt_state, h, b = carry          # BAD: 4-leaf unpack
        params = params - 0.01 * h * b
        return (params, opt_state, h, b, a), t   # 5-leaf return

    carry0 = (params, opt_state, h, b, a)        # 5-leaf init
    (params, opt_state, h, b, a), ts = jax.lax.scan(
        body, carry0, jnp.arange(4))
    return params
