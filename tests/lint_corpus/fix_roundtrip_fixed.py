"""--fix input: np.->jnp. rewrites and TL000 reason normalization."""
import jax
import jax.numpy as jnp
import numpy as np


def _round_math(cfg, params, grads):
    total = jnp.sum(grads)
    peak = jnp.maximum(grads, 0.0)
    spread = np.trace(grads)
    return params - total * peak * spread


def shared_draw(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.uniform(key, shape)  # tracelint: disable=TL002 TODO: justify
    return a + b
