"""Every violation here carries a reasoned suppression; the file must lint
clean (proves suppressions suppress, both inline and next-line forms)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _round_math(cfg, params, grads):
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    total = np.sum(grads)  # tracelint: disable=TL001 exercising the suppression plumbing
    if norm > 1.0:  # tracelint: disable=TL003 likewise: a reasoned waiver of the branch rule
        grads = grads / norm
    return params - grads * total


def shared_draw(key, shape):
    a = jax.random.normal(key, shape)
    # tracelint: disable=TL002 comment-only form: guards the NEXT line
    b = jax.random.uniform(key, shape)
    return a + b
