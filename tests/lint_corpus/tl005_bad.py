"""TL005 known-bad: config-classification drift, every failure mode.

A miniature of the engine's FLConfig / ClientConfig / structural_config
layout with six seeded bugs: an unclassified field (on each class), a
doubly-claimed field, a batched field structural_config forgot to collapse
(on each class), and a stale table entry.
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    seed: int = 0
    eta: float = 0.01
    theta_th: float = 0.6
    momentum: float = 0.9         # BAD: in neither table (silently unbatched)
    p: float = 0.75               # BAD: claimed by BOTH tables below


BATCHED_FL_FIELDS = ("seed", "eta", "theta_th", "p")
STRUCTURAL_FL_FIELDS = ("num_devices", "scheme", "p",
                        "local_steps")          # BAD: stale entry


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    algo: str = "sgd"
    mu: float = 0.0
    alpha: float = 0.01           # BAD: in neither client table


BATCHED_CLIENT_FIELDS = ("mu",)
STRUCTURAL_CLIENT_FIELDS = ("algo",)


def structural_config(cfg: FLConfig) -> FLConfig:
    # BAD: theta_th is batched but NOT collapsed here, and neither is the
    # batched ClientConfig.mu (no replace(cfg.client, ...) at all)
    return dataclasses.replace(cfg, seed=0, eta=0.01)
