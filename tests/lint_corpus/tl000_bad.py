"""TL000 known-bad: suppressions without reason strings."""
import jax
import jax.numpy as jnp


def correlated(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.uniform(key, shape)  # tracelint: disable=TL002
    return a + b
