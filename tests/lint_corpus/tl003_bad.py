"""TL003 known-bad: Python control flow on tracer-derived values."""
import jax
import jax.numpy as jnp


def _round_math(cfg, params, grads):
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    if norm > 1.0:                      # BAD: Python if on a tracer
        grads = grads / norm
    while norm > 2.0:                   # BAD: Python while on a tracer
        norm = norm / 2.0
    assert norm >= 0.0                  # BAD: assert concretizes the tracer
    return params - grads
