"""TL002 known-bad: the same PRNG key consumed by two draws."""
import jax
import jax.numpy as jnp


def correlated_noise(key, shape):
    z1 = jax.random.normal(key, shape)
    z2 = jax.random.uniform(key, shape)     # BAD: same key, correlated draws
    return z1 + z2


def parent_reuse_after_split(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.normal(k2, shape)
    c = jax.random.normal(key, shape)        # BAD: parent reused after split
    return a + b + c
