"""TL008 known-good: a stable scan carry (and a non-tuple carry, skipped)."""
import jax
import jax.numpy as jnp


def _make_chunk_scan(params, opt_state, h, b, a):
    def body(carry, t):
        params, opt_state, h, b, a = carry
        params = params - 0.01 * h * b
        return (params, opt_state, h, b, a), t

    carry0 = (params, opt_state, h, b, a)
    (params, opt_state, h, b, a), ts = jax.lax.scan(
        body, carry0, jnp.arange(4))
    return params


def dict_carry(acc, xs):
    # pytree (dict) carries are out of static reach: the rule must stay
    # quiet rather than guess
    def body(carry, x):
        return {"acc": carry["acc"] + x}, None

    out, _ = jax.lax.scan(body, {"acc": acc}, xs)
    return out["acc"]
