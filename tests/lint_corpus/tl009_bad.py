"""TL009 known-bad: flight-recorder calls inside traced contexts."""
import jax
import jax.numpy as jnp

from repro import obs


@jax.jit
def _jitted_update(params, grads, recorder):
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    recorder.on_round(0, {"grad_norm_mean": norm})   # BAD: tracer -> sink
    return params - 0.01 * grads


@jax.jit
def _jitted_make(x):
    rec = obs.make("memory")                         # BAD: obs API in trace
    rec.emit({"event": "round", "x": x})             # BAD: recorder method
    return x * 2


def _scan_driver(xs, rec):
    def body(carry, x):
        rec.emit({"event": "round", "x": x})         # BAD: scan body emit
        return carry + x, None

    out, _ = jax.lax.scan(body, jnp.zeros(()), xs)
    return out
