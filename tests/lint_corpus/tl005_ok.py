"""TL005 known-good: a complete, consistent classification partition,
including a nested ClientConfig collapsed via replace(cfg.client, ...) and
rebuilt through the outer replace (the exempted structural kwarg)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    algo: str = "sgd"
    mu: float = 0.0


BATCHED_CLIENT_FIELDS = ("mu",)
STRUCTURAL_CLIENT_FIELDS = ("algo",)


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    seed: int = 0
    eta: float = 0.01
    theta_th: float = 0.6
    client: ClientConfig = None


BATCHED_FL_FIELDS = ("seed", "eta", "theta_th")
STRUCTURAL_FL_FIELDS = ("num_devices", "scheme", "client")


def structural_config(cfg: FLConfig) -> FLConfig:
    client = dataclasses.replace(cfg.client, mu=0.0)
    return dataclasses.replace(cfg, seed=0, eta=0.01, theta_th=0.6,
                               client=client)
