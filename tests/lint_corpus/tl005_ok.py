"""TL005 known-good: a complete, consistent classification partition."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_devices: int = 20
    scheme: str = "normalized"
    seed: int = 0
    eta: float = 0.01
    theta_th: float = 0.6


BATCHED_FL_FIELDS = ("seed", "eta", "theta_th")
STRUCTURAL_FL_FIELDS = ("num_devices", "scheme")


def structural_config(cfg: FLConfig) -> FLConfig:
    return dataclasses.replace(cfg, seed=0, eta=0.01, theta_th=0.6)
