"""TL001 known-good: host calls on static config and pure-jnp traced math."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _round_math(cfg, params, grads):
    # float() of static config is host-side by design (the engine's
    # `float(cfg.num_devices)` idiom)
    k = float(cfg.num_devices)
    norm = jnp.sqrt(jnp.sum(jnp.square(grads)))
    return params - norm / k


@functools.partial(jax.jit, static_argnames=("n",))
def _jitted_update(x, n):
    return jnp.mean(x) / float(n)      # n is static_argnames: host float ok


def host_side_setup(cfg):
    # not a traced context at all: np is the right tool for setup arrays
    return np.full((cfg.num_devices,), float(cfg.num_devices))


def _scan_driver(xs):
    def body(carry, x):
        # shape metadata concretizes without touching tracer VALUES
        return carry + jnp.abs(x) / x.shape[0], None

    out, _ = jax.lax.scan(body, jnp.zeros(()), xs)
    return out
