"""TL006 known-good: diag assembly in lockstep with DIAG_KEYS."""
import jax.numpy as jnp

DIAG_KEYS = ("grad_norm_mean", "eta", "update_norm", "tx_energy")


def _round_math(cfg, norms, eta, y):
    diag_core = {
        "grad_norm_mean": jnp.mean(norms),
        "tx_energy": jnp.sum(norms),
    }
    diag = {
        **diag_core,
        "eta": eta,
        "update_norm": jnp.sqrt(jnp.sum(jnp.square(y))),
    }
    return diag
